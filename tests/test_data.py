"""Data substrate: tokenizer properties, synthetic world, pipeline.

Hypothesis-based tokenizer fuzzing lives in test_data_properties.py (behind
``importorskip``) so this module collects on bare environments.
"""
import numpy as np

from repro.data import (Tokenizer, caption_corpus, classification_prompts,
                        contrastive_batch, host_rng, make_world)
from repro.data.pipeline import Prefetcher


_CACHE = {}


def _tok():
    if "wt" not in _CACHE:
        rng = np.random.default_rng(0)
        world = make_world(rng, n_classes=16)
        _CACHE["wt"] = (world, Tokenizer.train(
            caption_corpus(world, rng, 500), vocab_size=512))
    return _CACHE["wt"]


def test_tokenizer_vocab_and_determinism():
    _, tok = _tok()
    assert tok.vocab_size <= 512
    a = tok.encode("a photo of a red cat")
    b = tok.encode("a photo of a red cat")
    assert a == b
    assert all(0 <= i < tok.vocab_size for i in a)


def test_encode_truncation_preserves_eos():
    """Regression: truncating a long caption at max_len used to drop the
    EOS; it must stay the final token (ids[:max_len-1] + [EOS])."""
    from repro.data.tokenizer import BOS, EOS
    _, tok = _tok()
    long_caption = " ".join(["red cat blue dog green bird"] * 10)
    full = tok.encode(long_caption, max_len=512)
    assert len(full) < 512 and full[-1] == EOS      # untruncated keeps EOS
    for max_len in (8, 16, 31):
        ids = tok.encode(long_caption, max_len=max_len)
        assert len(ids) == max_len
        assert ids[0] == BOS and ids[-1] == EOS, (max_len, ids[-4:])
        # the truncated body is a prefix of the untruncated encoding
        assert ids[:-1] == full[:max_len - 1]
    # no specials: plain prefix truncation, no EOS to preserve
    raw = tok.encode(long_caption, max_len=8, add_special=False)
    assert len(raw) == 8 and raw[-1] != EOS


def test_contrastive_stream_rejects_indivisible_global_batch():
    """Regression: global_batch % n_hosts != 0 used to silently shrink the
    global batch (local = B // n_hosts); it must raise instead."""
    from repro.data.pipeline import contrastive_stream
    world, tok = _tok()
    with np.testing.assert_raises_regex(ValueError, "divisible"):
        contrastive_stream(world, tok, 10, n_hosts=3)
    # the divisible case still streams
    pf = contrastive_stream(world, tok, 8, n_hosts=2, host_id=1)
    batch = next(pf)
    pf.close()
    assert batch["images"]["image"].shape[0] == 4


def test_pad_batch_shapes():
    _, tok = _tok()
    toks, mask = tok.pad_batch([[2, 5, 6], [2, 5]], max_len=8)
    assert toks.shape == (2, 8) and mask.shape == (2, 8)
    assert mask[0].sum() == 3 and mask[1].sum() == 2


def test_world_determinism_and_separability():
    """Same seed -> identical data; images of the same class are closer to
    their class mean than to other classes (so transfer is learnable)."""
    rng1, rng2 = np.random.default_rng(7), np.random.default_rng(7)
    w1, w2 = make_world(rng1), make_world(rng2)
    np.testing.assert_array_equal(w1.concept_vecs, w2.concept_vecs)

    world, tok = _tok()
    rng = np.random.default_rng(1)
    batch, cls = contrastive_batch(world, tok, 64, rng)
    raw = batch["images"]["image"]                 # (64, H, W, C) raw pixels
    assert raw.shape[1:] == (world.image_size, world.image_size,
                             world.channels)
    imgs = raw.reshape(raw.shape[0], -1)
    # class centroids
    cents = {c: imgs[cls == c].mean(0) for c in set(cls.tolist())
             if (cls == c).sum() > 1}
    correct = 0
    total = 0
    for i, c in enumerate(cls):
        if c not in cents:
            continue
        dists = {cc: np.linalg.norm(imgs[i] - v) for cc, v in cents.items()}
        correct += (min(dists, key=dists.get) == c)
        total += 1
    assert correct / total > 0.6


def test_classification_prompts_cover_all_classes():
    world, tok = _tok()
    prompts = classification_prompts(world, tok)
    assert prompts["tokens"].shape[0] == world.n_classes


def test_host_rng_streams_disjoint():
    a = host_rng(0, 0, 0).integers(0, 1 << 30, 8)
    b = host_rng(0, 1, 0).integers(0, 1 << 30, 8)
    c = host_rng(0, 0, 1).integers(0, 1 << 30, 8)
    assert not np.array_equal(a, b) and not np.array_equal(a, c)
    np.testing.assert_array_equal(a, host_rng(0, 0, 0).integers(0, 1 << 30, 8))


def test_prefetcher_yields_deterministic_batches():
    world, tok = _tok()

    def make(step):
        rng = host_rng(3, 0, step)
        batch, _ = contrastive_batch(world, tok, 8, rng)
        return batch

    pf = Prefetcher(make, depth=2)
    b0 = next(pf)
    next(pf)
    pf.close()
    expect, _ = contrastive_batch(world, tok, 8, host_rng(3, 0, 0))
    np.testing.assert_array_equal(b0["texts"]["tokens"],
                                  expect["texts"]["tokens"])


def test_prefetcher_close_ends_iteration_instead_of_hanging():
    """Regression: ``__next__`` after ``close()`` used to block forever on
    the drained queue; it must raise StopIteration promptly, and close()
    must be idempotent."""
    import threading
    import time

    pf = Prefetcher(lambda step: step, depth=2)
    next(pf)
    pf.close()
    pf.close()                    # idempotent
    # drain whatever was prefetched, then the stream must END
    t0 = time.time()
    tail = list(pf)
    assert time.time() - t0 < 5.0
    assert len(tail) <= 2         # at most `depth` buffered batches
    with np.testing.assert_raises(StopIteration):
        next(pf)

    # a consumer already blocked in next() must wake up after close()
    pf2 = Prefetcher(lambda step: step, depth=2)
    for _ in range(3):
        next(pf2)                 # queue momentarily drained
    got = {}

    def consume():
        try:
            while True:
                next(pf2)
        except StopIteration:
            got["stopped"] = True

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.1)
    pf2.close()
    t.join(timeout=5.0)
    assert got.get("stopped") and not t.is_alive()


def test_prefetcher_surfaces_worker_crash():
    """A make_batch exception must re-raise at the consumer (not hang the
    training loop on an empty queue with a dead producer)."""
    def bad(step):
        raise ValueError(f"boom at {step}")

    pf = Prefetcher(bad, depth=2)
    with np.testing.assert_raises(ValueError):
        next(pf)
    pf.close()                    # still idempotent after a crash
