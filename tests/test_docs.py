"""Tier-1 wiring of scripts/check_docs.py: every public symbol in core/,
kernels/*/ops.py and serving/embed/ must carry a docstring (ISSUE-3)."""
import io
import os
import sys
from contextlib import redirect_stderr

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "scripts"))

import check_docs  # noqa: E402


def test_public_api_is_documented():
    err = io.StringIO()
    with redirect_stderr(err):
        rc = check_docs.main([])
    assert rc == 0, f"undocumented public symbols:\n{err.getvalue()}"


def test_checker_sees_the_covered_surface():
    """The gate must actually cover the three module families — an empty
    glob (e.g. after a rename) would silently pass everything."""
    files = check_docs.covered_files()
    rels = {os.path.relpath(f, check_docs._DEFAULT_ROOT) for f in files}
    assert any("core" in os.path.dirname(r) for r in rels), rels
    assert any(r.endswith(os.path.join("contrastive_loss", "ops.py"))
               for r in rels), rels
    assert any(os.path.join("serving", "embed") in r for r in rels), rels


def test_checker_flags_missing(tmp_path):
    """Sanity: an undocumented public def is reported."""
    bad = tmp_path / "src" / "repro" / "core"
    bad.mkdir(parents=True)
    (bad / "mod.py").write_text('"""doc."""\ndef public(x):\n    return x\n')
    rc = check_docs.main(["--root", str(tmp_path)])
    assert rc == 1
