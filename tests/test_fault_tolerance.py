"""Crash/preemption fault-injection harness (DESIGN.md §10.4).

The end-to-end acceptance check lives in tests/distributed_checks.py
``ckpt_fault`` and runs in a SUBPROCESS on the 8-device simulated mesh
(device-count pinning — see tests/conftest.py): a training run hard-killed
mid-checkpoint-write (its own grandchild process dying by ``os._exit``
through the write fault hook), whose newest surviving checkpoint is then
bit-rotted, must ``--resume auto`` from the older verified step and replay
the uninterrupted run's per-step losses bit-exactly; a SIGTERM-preempted
run must write a final sync checkpoint and resume bit-exactly as well.

The in-process tests cover the trainer-facing recovery pieces that don't
need a multi-device mesh: async-write failure degrading to sync saves, and
``--resume off/latest`` semantics.
"""
import os
import subprocess
import sys
import types

import numpy as np

_CHECKS = os.path.join(os.path.dirname(__file__), "distributed_checks.py")


def test_killed_and_resumed_run_replays_losses_bit_exactly():
    """ISSUE-6 acceptance: kill mid-save -> torn tmp + corrupt newest ->
    auto-resume from the verified step -> bit-exact losses; plus the
    SIGTERM preemption leg. Slowest check in the suite (three training
    runs + a victim subprocess)."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    proc = subprocess.run([sys.executable, _CHECKS, "ckpt_fault"],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, (
        f"distributed_checks.py ckpt_fault failed\n--- stdout ---\n"
        f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    assert "PASS ckpt_fault" in proc.stdout


def _lm_args(**kw):
    base = dict(arch="llama3.2-1b", smoke=True, objective="lm", steps=3,
                batch=4, seq=32, lr=1e-3, seed=0, sharding="basic_ws",
                remat="basic", model_parallel=1, log_every=100,
                ckpt_dir=None, ckpt_every=0, stop_after=None)
    base.update(kw)
    return types.SimpleNamespace(**base)


def test_async_write_failure_degrades_to_sync(tmp_path, capsys):
    """A persistent async-write failure must not lose the run: the trainer
    flips the manager to sync mode and re-writes the step blocking, so
    every checkpoint still lands on disk."""
    from repro import checkpoint as ckpt
    from repro.checkpoint import faults
    from repro.launch.train_distributed import train

    import pytest

    d = str(tmp_path / "ck")
    # every async attempt fails (manager retries 3 times per write), but
    # the fallback sync path heals because the fault budget runs out
    with faults.failing_writes(4, message="disk went away"):
        train(_lm_args(ckpt_dir=d, ckpt_every=1))
    out = capsys.readouterr().out
    assert "degrading to sync" in out
    assert ckpt.latest_verified_step(d) == 3
    # step 1's async write died after retries; the failure surfaced at the
    # step-2 save, which the trainer re-wrote SYNC — step 1 is superseded,
    # not silently torn
    with pytest.raises(ckpt.CheckpointError):
        ckpt.verify(d, 1)
    for step in (2, 3):
        ckpt.verify(d, step)


def test_resume_off_ignores_checkpoints(tmp_path):
    from repro import checkpoint as ckpt
    from repro.launch.train_distributed import train

    d = str(tmp_path / "ck")
    full = train(_lm_args(ckpt_dir=d, ckpt_every=1))
    assert ckpt.latest_verified_step(d) == 3
    fresh = train(_lm_args(ckpt_dir=d, ckpt_every=0, resume="off"))
    np.testing.assert_array_equal(np.asarray(fresh), np.asarray(full))


def test_resume_auto_skips_corrupt_latest_in_trainer(tmp_path, capsys):
    """Trainer-level: --resume auto lands on the verified step when the
    newest checkpoint is corrupt; --resume latest would have tried (and
    failed on) the corrupt one."""
    import pytest

    from repro import checkpoint as ckpt
    from repro.checkpoint import faults
    from repro.launch.train_distributed import train

    d = str(tmp_path / "ck")
    train(_lm_args(ckpt_dir=d, ckpt_every=1))
    faults.truncate_leaf(d, 3)
    with pytest.raises(ckpt.CheckpointError):
        # trusting mode restores the newest dir blindly — and fails loudly
        train(_lm_args(ckpt_dir=d, steps=4, resume="latest"))
    # ... auto mode skips it (its final save then re-writes/heals step 3)
    train(_lm_args(ckpt_dir=d, steps=3))
    assert "resumed from step 2 (--resume auto)" in capsys.readouterr().out