"""Single-pass fused contrastive kernels (DESIGN.md §2.3-§2.4).

Covers what tests/test_kernels.py's long-standing sweeps do not: the exact
launch count (forward + backward = 2 pallas_calls), bf16 gradient parity,
rectangular blocks through the public op, the block autotuner's VMEM model
and its non-multiple-of-8 error, old-vs-new path equivalence, and the
check_bench regression gate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.contrastive_loss import kernel, ops
from repro.kernels.contrastive_loss import ref as cl_ref


def _unit(key, b, d, dtype=jnp.float32):
    z = jax.random.normal(key, (b, d), jnp.float32)
    z = z / jnp.linalg.norm(z, axis=1, keepdims=True)
    return z.astype(dtype)


def _pair(b, d, dtype=jnp.float32, seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed + b * d))
    return _unit(k1, b, d, dtype), _unit(k2, b, d, dtype)


# ---------------------------------------------------------------------------
# launch count: one forward sweep + one backward sweep
# ---------------------------------------------------------------------------


def test_loss_and_grad_use_exactly_two_pallas_launches(monkeypatch):
    calls = []
    real = kernel.pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(kernel.pl, "pallas_call", counting)
    x, y = _pair(32, 16)
    lt = jnp.asarray(-1.0)
    loss, grads_ = jax.value_and_grad(
        lambda x, y, t: ops.fused_contrastive_loss(x, y, t, True),
        argnums=(0, 1, 2))(x, y, lt)
    assert len(calls) == 2, f"expected 2 launches, saw grids {calls}"
    assert np.isfinite(float(loss))


def test_legacy_path_uses_four_launches(monkeypatch):
    calls = []
    real = kernel.pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(kernel.pl, "pallas_call", counting)
    x, y = _pair(32, 16)
    ops.fused_contrastive_loss_4pass(x, y, jnp.asarray(-1.0), True)
    assert len(calls) == 4, f"expected 4 launches, saw grids {calls}"


# ---------------------------------------------------------------------------
# value/gradient parity: bf16, rectangular blocks, old-vs-new
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,d", [(32, 16), (64, 32), (128, 48)])
def test_bf16_value_and_grad_parity(b, d):
    x, y = _pair(b, d, jnp.bfloat16)
    lt = jnp.asarray(-0.8)
    ref_loss = cl_ref.loss_ref(x, y, lt)
    gx_r, gy_r, gt_r = cl_ref.contrastive_grads_ref(x, y, lt)
    loss, (gx, gy, gt) = jax.value_and_grad(
        lambda x, y, t: ops.fused_contrastive_loss(x, y, t, True),
        argnums=(0, 1, 2))(x, y, lt)
    assert gx.dtype == jnp.bfloat16 and gy.dtype == jnp.bfloat16
    np.testing.assert_allclose(float(loss), float(ref_loss),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(gx, np.float32),
                               np.asarray(gx_r), atol=2e-2)
    np.testing.assert_allclose(np.asarray(gy, np.float32),
                               np.asarray(gy_r), atol=2e-2)
    np.testing.assert_allclose(float(gt), float(gt_r), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("bm,bn", [(16, 32), (32, 8), (8, 64), (64, 64)])
def test_rectangular_blocks_match_reference(bm, bn):
    b, d = 64, 24
    x, y = _pair(b, d)
    lt = jnp.asarray(-1.2)
    loss, (gx, gy, gt) = jax.value_and_grad(
        lambda x, y, t: ops.fused_contrastive_loss(x, y, t, True, bm, bn),
        argnums=(0, 1, 2))(x, y, lt)
    np.testing.assert_allclose(float(loss), float(cl_ref.loss_ref(x, y, lt)),
                               rtol=1e-5, atol=1e-5)
    gx_r, gy_r, gt_r = cl_ref.contrastive_grads_ref(x, y, lt)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(gy_r), atol=1e-5)
    np.testing.assert_allclose(float(gt), float(gt_r), rtol=1e-4, atol=1e-6)


def test_single_pass_matches_legacy_4pass():
    x, y = _pair(96, 32)
    lt = jnp.asarray(-0.5)
    l_new, (gx, gy, gt) = jax.value_and_grad(
        lambda x, y, t: ops.fused_contrastive_loss(x, y, t, True),
        argnums=(0, 1, 2))(x, y, lt)
    l_old, dx, dy, dtau = ops.fused_contrastive_loss_4pass(x, y, lt, True)
    np.testing.assert_allclose(float(l_new), float(l_old), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(dx), atol=1e-6)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(dy), atol=1e-6)
    np.testing.assert_allclose(float(gt), float(dtau), rtol=1e-5, atol=1e-7)


def test_fused_loss_and_lse_matches_reference():
    x, y = _pair(48, 16)
    lt = jnp.asarray(-1.0)
    loss, rlse, clse = ops.fused_loss_and_lse(x, y, lt, True)
    ref_loss, rlse_r, clse_r, _ = cl_ref.contrastive_fwd_ref(x, y, lt)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(rlse), np.asarray(rlse_r),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(clse), np.asarray(clse_r),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# block autotuner
# ---------------------------------------------------------------------------


def test_pick_blocks_rejects_non_multiple_of_8():
    with pytest.raises(ValueError, match="multiple of 8"):
        ops.pick_blocks(12, 64)
    with pytest.raises(ValueError, match="multiple of 8"):
        ops.pick_blocks(500, 64)


def test_pick_blocks_rejects_bad_overrides():
    with pytest.raises(ValueError, match="bm=48"):
        ops.pick_blocks(64, 16, bm=48)
    with pytest.raises(ValueError, match="bn=12"):
        ops.pick_blocks(64, 16, bn=12)


def test_pick_blocks_prefers_large_tiles_within_budget():
    bm, bn = ops.pick_blocks(8192, 256)
    assert (bm, bn) == (512, 256)
    # larger D shrinks the feasible tile; blocks divide B; model stays in budget
    bm2, bn2 = ops.pick_blocks(8192, 4096)
    assert 8192 % bm2 == 0 and 8192 % bn2 == 0
    assert ops.block_bytes(bm2, bn2, 4096, 4) <= ops.DEFAULT_VMEM_BUDGET
    assert bm2 * bn2 <= bm * bn
    # explicit overrides win
    assert ops.pick_blocks(8192, 256, bm=128, bn=128) == (128, 128)


def test_pick_blocks_small_batches_stay_blockwise():
    for b in (8, 16, 24, 48, 104):
        bm, bn = ops.pick_blocks(b, 32)
        assert b % bm == 0 and b % bn == 0 and bm >= 8 and bn >= 8


def test_autotune_timed_sweep_returns_feasible_pair():
    bm, bn = ops.autotune_blocks(32, 16, timed=True, interpret=True, iters=1)
    assert 32 % bm == 0 and 32 % bn == 0
    # cached on second call (same key, iters included)
    assert ops.autotune_blocks(32, 16, timed=True, interpret=True,
                               iters=1) == (bm, bn)


# ---------------------------------------------------------------------------
# plumbing: core.contrastive and gradaccum overrides
# ---------------------------------------------------------------------------


def test_fused_kernel_loss_autodetects_cpu_interpret():
    from repro.core.contrastive import contrastive_loss, fused_kernel_loss
    x, y = _pair(32, 16)
    loss, _ = fused_kernel_loss(x, y, 0.3)        # interpret=None -> detect
    ref_loss, _ = contrastive_loss(x, y, 0.3)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)


def test_gradaccum_plumbs_block_overrides_to_kernel():
    from repro.core.contrastive import contrastive_loss, fused_kernel_loss
    from repro.core.gradaccum import contrastive_step

    key = jax.random.key(3)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    b, din, d = 32, 12, 16
    params = {"wi": 0.3 * jax.random.normal(k1, (din, d)),
              "wt": 0.3 * jax.random.normal(k2, (din, d)),
              "log_tau": jnp.asarray(-1.0)}
    batch = {"images": jax.random.normal(k3, (b, din)),
             "texts": jax.random.normal(k4, (b, din))}

    def norm(z):
        return z / jnp.linalg.norm(z, axis=-1, keepdims=True)

    enc_i = lambda p, x: norm(jnp.tanh(x @ p["wi"]))   # noqa: E731
    enc_t = lambda p, y: norm(jnp.tanh(y @ p["wt"]))   # noqa: E731

    l_ref, _, g_ref = contrastive_step(enc_i, enc_t, params, batch, 4,
                                       loss_fn=contrastive_loss)
    l_k, _, g_k = contrastive_step(
        enc_i, enc_t, params, batch, 4, loss_fn=fused_kernel_loss,
        loss_opts={"interpret": True, "bm": 8, "bn": 16})
    np.testing.assert_allclose(float(l_ref), float(l_k), rtol=1e-5)
    for k in g_ref:
        np.testing.assert_allclose(np.asarray(g_ref[k]), np.asarray(g_k[k]),
                                   rtol=1e-4, atol=1e-6, err_msg=k)


# ---------------------------------------------------------------------------
# perf-regression gate (scripts/check_bench.py)
# ---------------------------------------------------------------------------


def _bench(us_by_name):
    # x1000 puts synthetic entries above check_bench's 50ms gating floor
    return {"entries": {k: {"us": v * 1000.0, "gbps": 1.0}
                        for k, v in us_by_name.items()}}


def test_check_bench_ignores_sub_floor_entries():
    from scripts.check_bench import THRESHOLD, compare
    base = {"entries": {"tiny/fwd": {"us": 1000.0, "gbps": 1.0}}}
    new = {"entries": {"tiny/fwd": {"us": 9000.0, "gbps": 1.0}}}
    assert compare(new, base, THRESHOLD) == []   # 9x, but below 50ms floor


def test_check_bench_flags_only_regressions():
    from scripts.check_bench import compare
    base = _bench({"fused2/B512_D256/fwd": 100.0,
                   "fused2/B512_D256/fwdbwd": 200.0,
                   "old4/B512_D256/fwd": 150.0})
    ok = _bench({"fused2/B512_D256/fwd": 129.9,       # < 1.3x: fine
                 "fused2/B512_D256/fwdbwd": 150.0,    # faster: fine
                 "new/path/fwd": 9999.0})             # unmatched: ungated
    assert compare(ok, base) == []
    bad = _bench({"fused2/B512_D256/fwd": 131.0})
    failures = compare(bad, base)
    assert len(failures) == 1 and "fused2/B512_D256/fwd" in failures[0]


def test_check_bench_cli_roundtrip(tmp_path):
    import json

    from scripts.check_bench import main
    base = tmp_path / "base.json"
    new = tmp_path / "new.json"
    base.write_text(json.dumps(_bench({"k/fwd": 100.0})))
    new.write_text(json.dumps(_bench({"k/fwd": 105.0})))
    assert main([str(new), "--baseline", str(base)]) == 0
    new.write_text(json.dumps(_bench({"k/fwd": 250.0})))
    assert main([str(new), "--baseline", str(base)]) == 1
    assert main([str(new), "--baseline", str(tmp_path / "none.json")]) == 0


def test_check_bench_normalizes_uniform_host_drift():
    from scripts.check_bench import compare
    names = [f"{p}/B512_D256/{t}" for p in ("ref", "old4", "fused2")
             for t in ("fwd", "fwdbwd")]
    base = _bench({n: 100.0 for n in names})
    # everything uniformly 1.6x slower (host drift, >= 6 entries): no failure
    drifted = _bench({n: 160.0 for n in names})
    assert compare(drifted, base) == []
    # one path regresses 2x on top of the drift: only those entries flagged
    drifted["entries"]["fused2/B512_D256/fwd"]["us"] = 320_000.0
    drifted["entries"]["fused2/B512_D256/fwdbwd"]["us"] = 320_000.0
    failures = compare(drifted, base)
    assert len(failures) == 2
    assert all("fused2" in f for f in failures)


def test_check_bench_ref_anchor_catches_shared_path_regression():
    from scripts.check_bench import compare
    names = [f"{p}/B2048_D{dd}/{t}" for p in ("ref", "old4", "fused2")
             for dd in (256, 1024) for t in ("fwd", "fwdbwd")]
    base = _bench({n: 100.0 for n in names})
    # a shared kernel helper slows BOTH Pallas paths 2x; ref is untouched.
    # 2/3 of entries move, but the ref-anchored host factor stays ~1.0.
    new = _bench({n: (100.0 if n.startswith("ref/") else 200.0)
                  for n in names})
    failures = compare(new, base)
    assert len(failures) == 8
    assert all("ref/" not in f for f in failures)


def test_check_bench_no_floor_for_compiled_baselines():
    from scripts.check_bench import compare
    # sub-50ms entries, but both sides ran compiled (interpret False):
    # accelerator timings are stable, so they must gate.
    base = {"meta": {"interpret": False},
            "entries": {"fused2/B8192_D1024/fwdbwd": {"us": 4000.0}}}
    new = {"meta": {"interpret": False},
           "entries": {"fused2/B8192_D1024/fwdbwd": {"us": 8000.0}}}
    assert len(compare(new, base)) == 1
    # same numbers under interpret mode stay advisory (below the floor)
    base["meta"]["interpret"] = True
    assert compare(new, base) == []


def test_bwd_fused_vmem_fallback_threshold():
    # paper-scale shard: (B, D) fp32 dY carrier alone exceeds VMEM
    assert not ops.bwd_fits_fused(65536, 1024, 512, 256, 4)
    assert not ops.bwd_fits_fused(8192, 1024, 512, 256, 4)
    # bench/test scales fit comfortably
    assert ops.bwd_fits_fused(2048, 256, 256, 256, 4)
    assert ops.bwd_fits_fused(512, 1024, 128, 128, 4)
