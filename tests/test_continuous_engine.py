"""Slot-level parity suite: continuous engine vs legacy Engine run alone.

The contract (DESIGN.md §12): for greedy decoding, a request's tokens from
``ContinuousEngine`` are BIT-IDENTICAL to ``Engine.generate`` run alone on
that request — for any arrival order, any slot assignment, staggered
prompt lengths, and slots reused after EOS (no stale-cache leak)."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch, smoke_variant
from repro.models import transformer as tf
from repro.serving import ContinuousEngine, Engine

MOE = {"dispatch": "dense"}
CACHE_LEN = 64


@pytest.fixture(scope="module")
def model():
    cfg = smoke_variant(get_arch("llama3.2-1b"))
    params = tf.init_params(cfg, jax.random.key(0))
    return cfg, params


@pytest.fixture(scope="module")
def oracle(model):
    """Legacy engine + per-request alone-run memo (prefill/decode programs
    are shared across tests via the module scope)."""
    cfg, params = model
    eng = Engine(cfg, params, cache_len=CACHE_LEN, moe_args=MOE)
    memo = {}

    def run_alone(prompt, max_new):
        key = (prompt.tobytes(), prompt.size, max_new)
        if key not in memo:
            out = eng.generate(prompt[None, :], max_new, temperature=0.0)[0]
            memo[key] = legacy_tokens(out, eng.eos_id)
        return memo[key]

    return eng, run_alone


def legacy_tokens(row, eos_id):
    """Legacy output rows pad with 0 AFTER EOS; a request's true token
    stream is everything up to and including the EOS."""
    toks = []
    for t in row:
        toks.append(int(t))
        if t == eos_id:
            break
    return np.asarray(toks, np.int32)


def _prompts(seed, n, vocab, lens):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, vocab, (lens[i % len(lens)],)).astype(np.int32)
            for i in range(n)]


def test_single_request_matches_legacy(model, oracle):
    cfg, params = model
    _, run_alone = oracle
    ce = ContinuousEngine(cfg, params, cache_len=CACHE_LEN, num_slots=1,
                          moe_args=MOE)
    (prompt,) = _prompts(0, 1, cfg.vocab, [8])
    got = ce.run([(prompt, 6, 0)])
    np.testing.assert_array_equal(got[0], run_alone(prompt, 6))


@pytest.mark.parametrize("num_slots", [1, 2, 4])
def test_staggered_lengths_any_slot_count(model, oracle, num_slots):
    """Six requests with staggered prompt lengths and budgets, pushed
    through 1/2/4 slots: every request matches its alone-run oracle
    regardless of how admission packs them."""
    cfg, params = model
    _, run_alone = oracle
    prompts = _prompts(1, 6, cfg.vocab, [8, 5, 11, 3, 7, 8])
    budgets = [6, 4, 8, 5, 1, 6]
    ce = ContinuousEngine(cfg, params, cache_len=CACHE_LEN,
                          num_slots=num_slots, moe_args=MOE)
    got = ce.run([(p, m, i) for i, (p, m) in enumerate(zip(prompts, budgets))])
    assert set(got) == set(range(6))
    for i, (p, m) in enumerate(zip(prompts, budgets)):
        np.testing.assert_array_equal(got[i], run_alone(p, m))


def test_arrival_order_is_irrelevant(model, oracle):
    """The same request set in three different submission orders — and a
    late-arrival schedule where half the stream shows up only after the
    engine has been decoding for several ticks — always produces the same
    per-request tokens."""
    cfg, params = model
    _, run_alone = oracle
    prompts = _prompts(2, 5, cfg.vocab, [6, 9, 4, 8, 5])
    budgets = [5, 3, 7, 4, 6]
    reqs = [(p, m, i) for i, (p, m) in enumerate(zip(prompts, budgets))]

    for order in [reqs, reqs[::-1], reqs[2:] + reqs[:2]]:
        ce = ContinuousEngine(cfg, params, cache_len=CACHE_LEN, num_slots=2,
                              moe_args=MOE)
        got = ce.run(order)
        for i, (p, m) in enumerate(zip(prompts, budgets)):
            np.testing.assert_array_equal(got[i], run_alone(p, m))

    # late arrivals: submit 2, tick a few times, then submit the rest
    ce = ContinuousEngine(cfg, params, cache_len=CACHE_LEN, num_slots=2,
                          moe_args=MOE)
    got = {}
    for p, m, i in reqs[:2]:
        ce.submit(p, m, i)
    for _ in range(3):
        for fin in ce.step():
            got[fin.request_id] = fin.tokens
    for p, m, i in reqs[2:]:
        ce.submit(p, m, i)
    while ce.pending:
        for fin in ce.step():
            got[fin.request_id] = fin.tokens
    for i, (p, m) in enumerate(zip(prompts, budgets)):
        np.testing.assert_array_equal(got[i], run_alone(p, m))


def test_slot_reuse_after_eos_no_stale_leak(model, oracle):
    """More requests than slots with wildly different budgets, so every
    slot is retired and re-admitted several times mid-run: the new tenant
    of a reused slot must decode exactly as if the cache were fresh (the
    per-slot length mask zeroes the previous tenant's stale rows)."""
    cfg, params = model
    eng, run_alone = oracle
    prompts = _prompts(3, 8, cfg.vocab, [10, 4, 7, 12, 5, 9, 6, 8])
    budgets = [2, 9, 3, 8, 2, 7, 3, 6]
    ce = ContinuousEngine(cfg, params, cache_len=CACHE_LEN, num_slots=2,
                          moe_args=MOE)
    got = ce.run([(p, m, i) for i, (p, m) in enumerate(zip(prompts, budgets))])
    assert ce.registry.counter("decode/admissions").value >= 8
    for i, (p, m) in enumerate(zip(prompts, budgets)):
        np.testing.assert_array_equal(got[i], run_alone(p, m))


def test_eos_retires_immediately_and_first_token_eos(model, oracle):
    """A request whose first (prefill-sampled) token is EOS finishes at
    admission without ever occupying a slot; EOS mid-stream truncates the
    stream at the EOS token, exactly like the legacy engine."""
    cfg, params = model
    eng, run_alone = oracle
    (prompt,) = _prompts(4, 1, cfg.vocab, [8])
    first = int(eng.generate(prompt[None, :], 1, temperature=0.0)[0, 0])
    ce = ContinuousEngine(cfg, params, cache_len=CACHE_LEN, num_slots=2,
                          moe_args=MOE, eos_id=first)
    got = ce.run([(prompt, 6, 0)])
    np.testing.assert_array_equal(got[0], np.asarray([first], np.int32))
    assert all(not s.active for s in ce._slots)   # never occupied a slot
    assert ce.registry.gauge("decode/slot_occupancy").value == 0.0


def test_max_new_tokens_budget_exact(model, oracle):
    """No EOS hit -> exactly max_new_tokens tokens, no pad tail."""
    cfg, params = model
    _, run_alone = oracle
    prompts = _prompts(5, 3, cfg.vocab, [7, 7, 7])
    ce = ContinuousEngine(cfg, params, cache_len=CACHE_LEN, num_slots=3,
                          moe_args=MOE)
    got = ce.run([(p, 5, i) for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        want = run_alone(p, 5)
        np.testing.assert_array_equal(got[i], want)
        assert got[i].size <= 5
        # 0 is the legacy PAD sentinel; it may only appear as a genuinely
        # sampled token, never as trailing fill
        if want.size == 5:
            assert got[i].size == 5


def test_capacity_validation_and_occupancy_metrics(model):
    import dataclasses

    cfg, params = model
    # the smoke llama variant runs a sliding-window ring cache, which
    # legitimately admits prompt+budget > cache_len; disable it to hit
    # the hard capacity check
    strict = ContinuousEngine(
        dataclasses.replace(cfg, sliding_window=None), params,
        cache_len=CACHE_LEN, num_slots=2, moe_args=MOE)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        strict.submit(np.ones((60,), np.int32), 10)
    ce = ContinuousEngine(cfg, params, cache_len=CACHE_LEN, num_slots=2,
                          moe_args=MOE)
    prompts = _prompts(6, 4, cfg.vocab, [6, 6, 6, 6])
    for i, p in enumerate(prompts):
        ce.submit(p, 4, i)
    assert ce.pending == 4
    occupancies = []
    while ce.pending:
        ce.step()
        occupancies.append(sum(s.active for s in ce._slots))
    assert max(occupancies) <= 2          # never exceeds slot capacity
    assert max(occupancies) == 2          # and actually packs both slots
    snap = ce.stats()
    assert snap["derived"]["tokens_per_sec"] > 0
    assert ce.registry.counter("decode/tokens").value >= 4 * 4 - 3
    assert ce.registry.counter("decode/requests").value == 4


def test_mamba_ssm_cache_slot_parity(oracle):
    """The slot insert is a generic axis-1 splice over the cache pytree —
    it must carry SSM/conv state rows (Mamba) just like KV rows."""
    cfg = smoke_variant(get_arch("mamba2-130m"))
    params = tf.init_params(cfg, jax.random.key(0))
    eng = Engine(cfg, params, cache_len=CACHE_LEN, moe_args=MOE)
    prompts = _prompts(7, 4, cfg.vocab, [8, 5, 11, 6])
    budgets = [5, 4, 6, 3]
    ce = ContinuousEngine(cfg, params, cache_len=CACHE_LEN, num_slots=2,
                          moe_args=MOE)
    got = ce.run([(p, m, i) for i, (p, m) in enumerate(zip(prompts, budgets))])
    for i, (p, m) in enumerate(zip(prompts, budgets)):
        alone = legacy_tokens(
            eng.generate(p[None, :], m, temperature=0.0)[0], eng.eos_id)
        np.testing.assert_array_equal(got[i], alone)


def test_sampled_decode_is_reproducible_per_request(model):
    """temperature>0: outputs are drawn from a per-request rng seeded by
    (seed, request_id), so the same engine seed reproduces the same stream
    under a DIFFERENT arrival order too."""
    cfg, params = model
    prompts = _prompts(8, 3, cfg.vocab, [6, 8, 5])
    reqs = [(p, 5, i) for i, p in enumerate(prompts)]
    outs = []
    for order in [reqs, reqs[::-1]]:
        ce = ContinuousEngine(cfg, params, cache_len=CACHE_LEN, num_slots=2,
                              moe_args=MOE, temperature=1.5, seed=42)
        outs.append(ce.run(order))
    for i in range(3):
        np.testing.assert_array_equal(outs[0][i], outs[1][i])
