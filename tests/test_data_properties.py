"""Hypothesis property tests for the data substrate (paper §7.1).

Kept separate from test_data.py and guarded with ``importorskip`` so the
suite collects cleanly on bare environments without ``hypothesis``; the
property tests still run wherever it is installed.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.data import Tokenizer, caption_corpus, make_world  # noqa: E402

_CACHE = {}


def _tok():
    if "tok" not in _CACHE:
        rng = np.random.default_rng(0)
        world = make_world(rng, n_classes=16)
        _CACHE["tok"] = Tokenizer.train(
            caption_corpus(world, rng, 500), vocab_size=512)
    return _CACHE["tok"]


@settings(max_examples=40, deadline=None)
@given(hst.text(alphabet="abcdefghij z.,", min_size=0, max_size=200))
def test_tokenizer_length_filter_and_bounds(text):
    """Paper §7.1: sequences are capped at 64 tokens; ids stay in-vocab."""
    tok = _tok()
    ids = tok.encode(text, max_len=64)
    assert len(ids) <= 64
    assert all(0 <= i < tok.vocab_size for i in ids)
