"""Cross-shard global-batch loss == single-device fused loss (DESIGN.md §7).

The real multi-shard assertions live in tests/distributed_checks.py and run
in a SUBPROCESS with 8 simulated host devices (jax pins the device count at
first init; the tier-1 process must keep seeing the single real CPU device,
tests/conftest.py). Here we spawn them and additionally cover the pieces
that don't need a multi-device mesh in-process.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

_CHECKS = os.path.join(os.path.dirname(__file__), "distributed_checks.py")


def _run_checks(mode):
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    proc = subprocess.run([sys.executable, _CHECKS, mode],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, (
        f"distributed_checks.py {mode} failed\n--- stdout ---\n"
        f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    assert f"PASS {mode}" in proc.stdout


def test_distributed_loss_matches_single_device():
    """Acceptance: mesh with data-axis size >= 2 (up to 8), allgather AND
    chunked paths, loss + dX/dY/dtau within fp32 tolerance of the
    single-device fused loss at the same global batch."""
    _run_checks("loss")


def test_gradaccum_composes_with_distributed_loss():
    """Algorithm-1 GradAccum x data-parallel x tensor-parallel under one
    jit: weight grads match the single-device step."""
    _run_checks("gradaccum")


def test_make_global_loss_fn_single_extent_falls_back():
    """On a 1-device data extent the factory returns the plain fused loss
    (no shard_map) — values and grads still match the reference."""
    from repro.core import distributed_loss as dl
    from repro.core.contrastive import fused_kernel_loss

    mesh = jax.make_mesh((1,), ("data",))
    kx, ky = jax.random.split(jax.random.key(3))
    x = jax.random.normal(kx, (32, 16))
    x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
    y = jax.random.normal(ky, (32, 16))
    y = y / jnp.linalg.norm(y, axis=-1, keepdims=True)
    tau = jnp.asarray(0.5)

    loss_fn = dl.make_global_loss_fn(mesh, "chunked")
    got = jax.jit(lambda x, y, t: loss_fn(x, y, t)[0])(x, y, tau)
    want = fused_kernel_loss(x, y, tau, interpret=True)[0]
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_make_global_loss_fn_rejects_unknown_method():
    mesh = jax.make_mesh((1,), ("data",))
    from repro.core import distributed_loss as dl
    with pytest.raises(ValueError, match="method"):
        dl.make_global_loss_fn(mesh, "ring")


def test_chunk_grads_nodiag_matches_manual():
    """ops.chunk_grads with with_diag=False + b_norm reproduces the manual
    no-diagonal softmax-gradient formula for a remote chunk."""
    from repro.kernels.contrastive_loss import ops

    b_l, d, b_g = 16, 8, 64
    kx, ky = jax.random.split(jax.random.key(11))
    x = jax.random.normal(kx, (b_l, d), jnp.float32)
    y = jax.random.normal(ky, (b_l, d), jnp.float32)
    inv_tau = jnp.asarray(2.0)
    a = (x @ y.T) * inv_tau
    # arbitrary (global-looking) LSE vectors: the kernel only consumes them
    row_lse = jax.nn.logsumexp(a, axis=1) + 0.3
    col_lse = jax.nn.logsumexp(a, axis=0) + 0.1

    da = (jnp.exp(a - row_lse[:, None]) + jnp.exp(a - col_lse[None, :])) \
        / (2.0 * b_g)
    want_dx, want_dy = da @ y * inv_tau, da.T @ x * inv_tau
    want_dtau = -jnp.sum(da * a)

    dx, dy, dtau = ops.chunk_grads(x, y, inv_tau, row_lse, col_lse,
                                   b_norm=b_g, with_diag=False,
                                   interpret=True)
    np.testing.assert_allclose(dx, want_dx, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dy, want_dy, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dtau, want_dtau, rtol=1e-5, atol=1e-6)
