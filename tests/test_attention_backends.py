"""Attention backend registry: cross-backend parity + precision policy.

The tower runtime's three full-sequence backends (naive / chunked / pallas)
must agree to fp32 tolerance — values AND gradients — on every mask shape
the BASIC towers use: bidirectional (causal=False), causal, sliding-window,
key-padding, bf16 inputs, and GQA head layouts (DESIGN.md §8).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_variant
from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import precision as prec_lib
from repro.models import transformer as tf


def _cfg(heads=4, kv=2, d=64, causal=False, window=None,
         impl="naive") -> ArchConfig:
    return ArchConfig(
        name="t", family="encoder", n_layers=2, d_model=d, n_heads=heads,
        n_kv_heads=kv, d_ff=4 * d, vocab=64, head_dim=d // heads,
        causal=causal, sliding_window=window, attn_impl=impl, attn_block=32)


def _qkv_params(cfg, seed=0):
    return attn_lib.init_attn_params(jax.random.key(seed), cfg)


def _run(cfg, p, x, impl, key_mask=None):
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    return attn_lib.attention(p, cfg, x, pos, impl=impl, key_mask=key_mask)


CASES = [
    # (heads, kv, seq, causal, window, masked, dtype)
    (4, 2, 48, False, None, False, jnp.float32),     # bidirectional GQA
    (4, 4, 48, False, None, True, jnp.float32),      # padded MHA (towers)
    (4, 2, 48, False, None, True, jnp.bfloat16),     # padded GQA bf16
    (4, 1, 64, True, None, False, jnp.float32),      # causal max-group GQA
    (4, 2, 64, True, 16, False, jnp.float32),        # sliding window
]


@pytest.mark.parametrize("heads,kv,seq,causal,window,masked,dtype", CASES)
def test_backends_agree_values_and_grads(heads, kv, seq, causal, window,
                                         masked, dtype):
    cfg = _cfg(heads=heads, kv=kv, causal=causal, window=window)
    p = _qkv_params(cfg)
    rng = np.random.default_rng(seq + heads)
    x = jnp.asarray(rng.standard_normal((2, seq, cfg.d_model)),
                    jnp.float32).astype(dtype)
    key_mask = None
    if masked:
        lens = np.array([seq - 3, seq // 2])
        key_mask = jnp.asarray(np.arange(seq)[None, :] < lens[:, None])

    outs, grads = {}, {}
    for impl in ("naive", "chunked", "pallas"):
        def f(p):
            o = _run(cfg, p, x, impl, key_mask)
            return jnp.sum(jnp.sin(o.astype(jnp.float32)))
        outs[impl] = _run(cfg, p, x, impl, key_mask)
        grads[impl] = jax.grad(f)(p)

    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    gtol = 2e-4 if dtype == jnp.float32 else 1e-1
    for impl in ("chunked", "pallas"):
        np.testing.assert_allclose(
            np.asarray(outs[impl], np.float32),
            np.asarray(outs["naive"], np.float32), rtol=tol, atol=tol,
            err_msg=impl)
        for (path, a), (_, b) in zip(
                jax.tree_util.tree_leaves_with_path(grads[impl]),
                jax.tree_util.tree_leaves_with_path(grads["naive"])):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=gtol, atol=gtol, err_msg=f"{impl} {path}")


def test_padded_keys_do_not_leak_into_outputs():
    """Changing a padded position's content must not change any valid
    query's output, under every backend."""
    cfg = _cfg(causal=False)
    p = _qkv_params(cfg, seed=1)
    rng = np.random.default_rng(3)
    s, valid = 32, 20
    x = jnp.asarray(rng.standard_normal((1, s, cfg.d_model)), jnp.float32)
    key_mask = jnp.asarray(np.arange(s)[None, :] < valid)
    x2 = x.at[0, valid:, :].set(jnp.asarray(
        rng.standard_normal((s - valid, cfg.d_model)), jnp.float32))
    for impl in ("naive", "chunked", "pallas"):
        o1 = _run(cfg, p, x, impl, key_mask)
        o2 = _run(cfg, p, x2, impl, key_mask)
        np.testing.assert_allclose(np.asarray(o1[0, :valid]),
                                   np.asarray(o2[0, :valid]),
                                   atol=1e-5, err_msg=impl)


def test_registry_resolution_and_fallback():
    assert set(attn_lib.available_backends()) == {"naive", "chunked",
                                                  "pallas"}
    # auto: accelerator -> pallas, cpu host -> chunked
    assert attn_lib.resolve_backend("auto", seq=128, head_dim=128,
                                    platform="tpu") == "pallas"
    assert attn_lib.resolve_backend(None, seq=128, head_dim=128,
                                    platform="cpu") == "chunked"
    # explicit pallas falls back on shapes Mosaic can't tile (compiled mode)
    assert attn_lib.resolve_backend("pallas", seq=128, head_dim=64,
                                    platform="tpu") == "chunked"
    assert attn_lib.resolve_backend("pallas", seq=127, head_dim=128,
                                    platform="tpu") == "chunked"
    # ... but interpret mode on CPU has no tiling constraint
    assert attn_lib.resolve_backend("pallas", seq=127, head_dim=40,
                                    platform="cpu") == "pallas"
    with pytest.raises(KeyError):
        attn_lib.resolve_backend("nope", seq=8, head_dim=8)


def test_encoder_tower_parity_through_encode():
    """Whole-tower parity: tf.encode output identical across backends on a
    real (smoke) text tower with a padding mask."""
    base = smoke_variant(get_arch("basic-s").text_tower)
    params = tf.init_params(base, jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, base.vocab, (3, 24)), jnp.int32)
    mask = jnp.asarray(np.arange(24)[None, :] < np.array([[24], [9], [16]]))
    batch = {"tokens": toks, "attn_mask": mask}
    outs = {impl: tf.encode(dataclasses.replace(base, attn_impl=impl),
                            params, batch)
            for impl in ("naive", "chunked", "pallas")}
    for impl in ("chunked", "pallas"):
        np.testing.assert_allclose(np.asarray(outs[impl]),
                                   np.asarray(outs["naive"]),
                                   rtol=2e-5, atol=2e-5, err_msg=impl)


# ---------------------------------------------------------------------------
# precision policy
# ---------------------------------------------------------------------------


def test_precision_registry_and_resolve():
    assert set(prec_lib.list_policies()) == {"f32", "bf16", "bf16_pure"}
    assert prec_lib.resolve("bf16").compute_dtype == jnp.bfloat16
    assert prec_lib.resolve(None).name == "f32"
    # legacy bare-dtype call sites map onto the named policies
    assert prec_lib.resolve(None, jnp.bfloat16) is prec_lib.POLICIES["bf16"]
    assert prec_lib.resolve(jnp.float32) is prec_lib.POLICIES["f32"]
    assert prec_lib.resolve("bf16_pure").fp32_projections is False
    with pytest.raises(KeyError):
        prec_lib.resolve("fp8")


def test_bf16_policy_keeps_fp32_islands():
    """Under the bf16 policy the tower computes in bf16 but embeddings /
    logits land in fp32 and stay close to the full-fp32 result."""
    from repro.models import dual_encoder as de
    from repro.configs import smoke_dual_variant
    cfg = smoke_dual_variant(get_arch("basic-s"))
    params = de.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    it = cfg.image_tower
    images = {"image": jnp.asarray(rng.standard_normal(
        (4, it.image_size, it.image_size, it.channels)), jnp.float32)}
    x32 = de.encode_image(cfg, params, images, precision="f32")
    x16 = de.encode_image(cfg, params, images, precision="bf16")
    assert x16.dtype == jnp.float32          # fp32 projection island
    assert float(jnp.max(jnp.abs(x16 - x32))) < 0.05
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(x16, axis=-1)),
                               1.0, rtol=1e-3)
    # lm path: bf16 compute, fp32 logits
    lcfg = smoke_variant(get_arch("llama3.2-1b"))
    lp = tf.init_params(lcfg, jax.random.key(1))
    toks = jnp.asarray(rng.integers(0, lcfg.vocab, (2, 16)), jnp.int32)
    out = tf.prefill(lcfg, lp, {"tokens": toks}, precision="bf16")
    assert out.dtype == jnp.float32
    l32, _ = tf.lm_loss(lcfg, lp, {"tokens": toks}, precision="f32")
    l16, _ = tf.lm_loss(lcfg, lp, {"tokens": toks}, precision="bf16")
    assert abs(float(l32) - float(l16)) < 0.1
