"""AdaFactorW: factored moments, bf16 m1, decoupled WD, microbatch folding."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adafactorw import AdaFactorW, apply_updates


def test_state_shapes_factored_and_full():
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((16, 8)),
              "vec": jnp.zeros((300,))}
    opt = AdaFactorW(factored_threshold=128)
    st = opt.init(params)
    assert st.m["big"].dtype == jnp.bfloat16
    assert st.v_row["big"].shape == (256,)       # factored
    assert st.v_col["big"].shape == (512,)
    assert st.v_row["small"].shape == (16, 8)    # full second moment
    assert st.v_col["small"].shape == ()
    assert st.v_row["vec"].shape == (300,)


def test_converges_on_quadratic():
    key = jax.random.key(0)
    target = jax.random.normal(key, (64, 32))
    params = {"w": jnp.zeros((64, 32))}
    opt = AdaFactorW(weight_decay=0.0)
    st = opt.init(params)

    @jax.jit
    def step(params, st):
        g = jax.grad(lambda p: jnp.mean((p["w"] - target) ** 2))(params)
        up, st = opt.update(g, st, params, 0.05)
        return apply_updates(params, up), st

    loss0 = float(jnp.mean((params["w"] - target) ** 2))
    for _ in range(300):
        params, st = step(params, st)
    loss1 = float(jnp.mean((params["w"] - target) ** 2))
    assert loss1 < 0.05 * loss0, (loss0, loss1)


def test_weight_decay_decoupled():
    """With zero gradient, weight decay still shrinks the weights (AdamW
    semantics, not L2-through-moments)."""
    params = {"w": jnp.ones((4, 4))}
    opt = AdaFactorW(weight_decay=0.1)
    st = opt.init(params)
    zero_g = {"w": jnp.zeros((4, 4))}
    up, st = opt.update(zero_g, st, params, 1e-2)
    new = apply_updates(params, up)
    assert float(jnp.max(new["w"])) < 1.0


def test_microbatch_update_close_to_mean_grad_update():
    """update_from_microbatches (paper §4.2 path) must approximate the
    standard update on the averaged gradient; first step is exact for m1 and
    differs in v2 only by Var[c]."""
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((192, 160)), jnp.float32)}
    opt = AdaFactorW(weight_decay=0.0, store_m_bf16=False)
    # microbatch gradients with small spread around a common mean
    gmean = rng.standard_normal((192, 160)).astype(np.float32)
    c = jnp.asarray(gmean[None] + 0.01 * rng.standard_normal(
        (4, 192, 160)).astype(np.float32))

    st1 = opt.init(params)
    up_ref, _ = opt.update({"w": jnp.mean(c, 0)}, st1, params, 1e-3)
    st2 = opt.init(params)
    up_mb, _ = opt.update_from_microbatches({"w": c}, st2, params, 1e-3)
    denom = float(jnp.mean(jnp.abs(up_ref["w"]))) + 1e-12
    rel = float(jnp.mean(jnp.abs(up_mb["w"] - up_ref["w"]))) / denom
    assert rel < 0.05, rel


def test_bf16_first_moment_used_as_f32():
    params = {"w": jnp.ones((256, 256))}
    opt = AdaFactorW()
    st = opt.init(params)
    g = {"w": jnp.full((256, 256), 1e-3)}
    up, st2 = opt.update(g, st, params, 1e-3)
    assert st2.m["w"].dtype == jnp.bfloat16
    assert np.all(np.isfinite(np.asarray(up["w"], np.float32)))
