"""Paper §4.2 moment-slot accumulation: v1 exact, v2 variance-corrected."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moment_accum import (accumulate_first_moment,
                                     accumulate_second_moment,
                                     exact_second_moment, replica_variance)


def _stream(seed=0, K=6, shape=(5, 4)):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.standard_normal((K, *shape)), jnp.float32)}


def test_first_moment_exact():
    c = _stream()
    v1 = {"w": jnp.asarray(np.random.default_rng(1).standard_normal((5, 4)),
                           jnp.float32)}
    beta1 = 0.9
    got = accumulate_first_moment(v1, c, beta1)
    gbar = jnp.mean(c["w"], 0)
    want = beta1 * v1["w"] + (1 - beta1) * gbar
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_second_moment_correction_reduces_bias():
    """E[c^2] over-estimates gbar^2 by Var[c]; subtracting the per-replica
    estimate must land closer to the exact slot than the uncorrected value."""
    rng = np.random.default_rng(2)
    K, R, M_over_R = 8, 4, 16
    shape = (6, 3)
    # per-replica gradients: d ~ mean g + noise/sqrt(M/R)
    g_true = rng.standard_normal(shape).astype(np.float32)
    d = g_true + rng.standard_normal((K, R, *shape)).astype(np.float32) * 0.5
    c = {"w": jnp.asarray(d.mean(axis=1))}
    d_stream = {"w": jnp.asarray(d)}

    v2 = {"w": jnp.zeros(shape, jnp.float32)}
    beta2 = 0.9
    exact = exact_second_moment(v2, c, beta2)
    uncorrected = accumulate_second_moment(v2, c, beta2)
    var_hat = replica_variance(d_stream, R)
    corrected = accumulate_second_moment(v2, c, beta2, var_hat=var_hat)

    err_unc = float(jnp.mean(jnp.abs(uncorrected["w"] - exact["w"])))
    err_cor = float(jnp.mean(jnp.abs(corrected["w"] - exact["w"])))
    assert err_cor < err_unc, (err_cor, err_unc)


def test_uncorrected_overestimates():
    """E[c^2] >= (E[c])^2 always (Jensen) — the uncorrected slot is an
    overestimate, never under."""
    c = _stream(seed=3)
    v2 = {"w": jnp.zeros((5, 4), jnp.float32)}
    exact = exact_second_moment(v2, c, 0.9)
    unc = accumulate_second_moment(v2, c, 0.9)
    assert bool(jnp.all(unc["w"] >= exact["w"] - 1e-7))


def test_replica_variance_identity():
    """Var[c] = Var[d]/R (paper Eq. 4 applied to the replica split)."""
    rng = np.random.default_rng(4)
    K, R = 200, 8
    d = rng.standard_normal((K, R, 2)).astype(np.float32)
    vh = replica_variance({"w": jnp.asarray(d)}, R)
    c = d.mean(axis=1)
    emp_var_c = c.var(axis=0)
    np.testing.assert_allclose(np.asarray(vh["w"]), emp_var_c,
                               rtol=0.35)  # statistical agreement
