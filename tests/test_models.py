"""Per-arch smoke tests (reduced variants) + decode/remat/SWA equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import applicable_shapes, get_arch, list_archs, smoke_variant
from repro.core.remat import get_policy
from repro.models import frontends, transformer as tf

ASSIGNED = [a for a in list_archs() if not a.startswith("basic-")]
MOE_DENSE = {"dispatch": "dense"}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    """One forward + one grad step on a 2-layer reduced variant: finite loss,
    correct output shapes, finite grads."""
    cfg = smoke_variant(get_arch(arch))
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = tf.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = frontends.synthetic_inputs(cfg, 2, 32, rng)

    def loss_fn(p):
        loss, m = tf.lm_loss(cfg, p, batch, moe_args=MOE_DENSE)
        return loss, m

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert np.isfinite(float(loss)), arch
    assert float(loss) < 20.0
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_output_logit_shapes(arch):
    cfg = smoke_variant(get_arch(arch))
    params = tf.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    batch = frontends.synthetic_inputs(cfg, 2, 32, rng)
    out = tf.prefill(cfg, params, batch, dtype=jnp.float32,
                     moe_args=MOE_DENSE)
    assert out.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(out)))


DECODERS = [a for a in ASSIGNED if get_arch(a).causal]


@pytest.mark.parametrize("arch", DECODERS)
def test_decode_matches_teacher_forcing(arch):
    """serve_step over a cached prefix reproduces the full forward's logits."""
    cfg = smoke_variant(get_arch(arch))
    params = tf.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(0)
    toks = rng.integers(4, cfg.vocab, (2, 24)).astype(np.int32)
    full = tf.prefill(cfg, params, {"tokens": jnp.asarray(toks)},
                      dtype=jnp.float32, moe_args=MOE_DENSE)
    _, caches = tf.prefill(cfg, params, {"tokens": jnp.asarray(toks[:, :-1])},
                           dtype=jnp.float32, moe_args=MOE_DENSE,
                           collect_cache_len=48)
    dec, _ = tf.decode_step(cfg, params, jnp.asarray(toks[:, -1:]),
                            jnp.int32(23), caches, dtype=jnp.float32,
                            moe_args=MOE_DENSE)
    np.testing.assert_allclose(np.asarray(full[:, 0]), np.asarray(dec[:, 0]),
                               rtol=1e-3, atol=2e-4)


def test_sliding_window_ring_cache_equals_linear_when_window_covers_seq():
    """With window >= seq the SWA arch must match its full-attention twin."""
    base = smoke_variant(get_arch("llama3.2-1b"))
    cfg_win = dataclasses.replace(base, sliding_window=64)
    cfg_full = dataclasses.replace(base, sliding_window=None)
    params = tf.init_params(cfg_full, jax.random.key(2))
    rng = np.random.default_rng(2)
    toks = rng.integers(4, base.vocab, (2, 32)).astype(np.int32)
    o1 = tf.prefill(cfg_win, params, {"tokens": jnp.asarray(toks)},
                    dtype=jnp.float32)
    o2 = tf.prefill(cfg_full, params, {"tokens": jnp.asarray(toks)},
                    dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_sliding_window_masks_distant_tokens():
    """Changing a token outside the window must not change the logits; inside
    must."""
    base = smoke_variant(get_arch("llama3.2-1b"))
    cfg = dataclasses.replace(base, sliding_window=8, n_layers=2)
    params = tf.init_params(cfg, jax.random.key(3))
    rng = np.random.default_rng(3)
    toks = rng.integers(4, cfg.vocab, (1, 32)).astype(np.int32)
    out = tf.prefill(cfg, params, {"tokens": jnp.asarray(toks)},
                     dtype=jnp.float32)
    far = toks.copy()
    far[0, 2] = (far[0, 2] + 7) % cfg.vocab        # > 8+1 tokens before the end
    out_far = tf.prefill(cfg, params, {"tokens": jnp.asarray(far)},
                         dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_far),
                               atol=1e-5)
    near = toks.copy()
    near[0, 30] = (near[0, 30] + 7) % cfg.vocab
    out_near = tf.prefill(cfg, params, {"tokens": jnp.asarray(near)},
                          dtype=jnp.float32)
    assert float(jnp.max(jnp.abs(out - out_near))) > 1e-4


def test_remat_policy_preserves_loss_and_grads():
    """Paper §5.2: rematerialization must not change values (no-regularization
    consistency argument, App. B)."""
    cfg = smoke_variant(get_arch("qwen3-32b"))
    params = tf.init_params(cfg, jax.random.key(4))
    rng = np.random.default_rng(4)
    batch = frontends.synthetic_inputs(cfg, 2, 16, rng)

    def loss_with(policy):
        def f(p):
            loss, _ = tf.lm_loss(cfg, p, batch, remat_policy=policy)
            return loss
        return jax.value_and_grad(f)(params)

    l0, g0 = loss_with(None)
    for name in ("basic", "full", "dots"):
        l1, g1 = loss_with(get_policy(name))
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6,
                                   err_msg=name)
        for (p0, a), (p1, b) in zip(
                jax.tree_util.tree_leaves_with_path(g0),
                jax.tree_util.tree_leaves_with_path(g1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6,
                                       err_msg=f"{name} {p0}")


def test_gqa_reduces_to_mha_when_kv_equals_heads():
    """hubert (kv == heads) exercises the degenerate GQA group=1 path."""
    cfg = smoke_variant(get_arch("hubert-xlarge"))
    assert cfg.n_kv_heads == cfg.n_heads
    params = tf.init_params(cfg, jax.random.key(5))
    rng = np.random.default_rng(5)
    batch = frontends.synthetic_inputs(cfg, 2, 16, rng)
    loss, _ = tf.lm_loss(cfg, params, batch)
    assert np.isfinite(float(loss))


def test_encoder_bidirectional_vs_causal_differ():
    cfg = smoke_variant(get_arch("hubert-xlarge"))
    cfg_causal = dataclasses.replace(cfg, causal=True)
    params = tf.init_params(cfg, jax.random.key(6))
    rng = np.random.default_rng(6)
    batch = frontends.synthetic_inputs(cfg, 1, 16, rng)
    l1, _ = tf.lm_loss(cfg, params, batch)
    l2, _ = tf.lm_loss(cfg_causal, params, batch)
    assert abs(float(l1) - float(l2)) > 1e-6


def test_applicable_shapes_matrix():
    """The DESIGN.md §4 skip matrix is enforced by the config system."""
    names = {a: [s.name for s in applicable_shapes(get_arch(a))]
             for a in ASSIGNED}
    assert names["hubert-xlarge"] == ["train_4k", "prefill_32k"]
    for a in ("mamba2-130m", "jamba-1.5-large-398b", "mixtral-8x22b",
              "llama3.2-1b"):
        assert "long_500k" in names[a], a
    for a in ("internvl2-76b", "minitron-4b", "internlm2-20b", "qwen3-32b",
              "arctic-480b"):
        assert "long_500k" not in names[a], a
        assert "decode_32k" in names[a], a
    total = sum(len(v) for v in names.values())
    assert total == 33  # 10*2 + 9 decode + 4 long
