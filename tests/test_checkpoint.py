"""Checkpoint save/restore roundtrip + atomicity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import get_arch, smoke_variant
from repro.models import transformer as tf


def test_roundtrip(tmp_path):
    cfg = smoke_variant(get_arch("qwen3-32b"))
    params = tf.init_params(cfg, jax.random.key(0))
    path = ckpt.save(str(tmp_path), 7, params)
    assert os.path.isdir(path)
    assert ckpt.latest_step(str(tmp_path)) == 7

    like = jax.eval_shape(lambda: params)
    restored = ckpt.restore(str(tmp_path), 7, like)
    for (pa, a), (pb, b) in zip(jax.tree_util.tree_leaves_with_path(params),
                                jax.tree_util.tree_leaves_with_path(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_rejects_wrong_structure(tmp_path):
    tree = {"a": jnp.ones((3,)), "b": jnp.zeros((2, 2))}
    ckpt.save(str(tmp_path), 1, tree)
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.ones((3,))})
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1,
                     {"a": jnp.ones((4,)), "b": jnp.zeros((2, 2))})


def test_multiple_steps_latest(tmp_path):
    tree = {"w": jnp.ones((2,))}
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 10, tree)
    ckpt.save(str(tmp_path), 5, tree)
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_optimizer_state_roundtrip(tmp_path):
    from repro.optim import AdaFactorW
    cfg = smoke_variant(get_arch("llama3.2-1b"))
    params = tf.init_params(cfg, jax.random.key(1))
    opt = AdaFactorW()
    st = opt.init(params)
    ckpt.save(str(tmp_path), 2, {"params": params, "opt": st})
    like = jax.eval_shape(lambda: {"params": params, "opt": st})
    restored = ckpt.restore(str(tmp_path), 2, like)
    assert restored["opt"].m["final_norm"].dtype == jnp.bfloat16
