"""Checkpoint save/restore roundtrip, atomicity, integrity verification,
retention/GC, and the async manager (DESIGN.md §10)."""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.checkpoint import faults
from repro.checkpoint import io as ckpt_io
from repro.configs import get_arch, smoke_variant
from repro.models import transformer as tf


def _steps_on_disk(d):
    return sorted(int(x.split("_")[1]) for x in os.listdir(d)
                  if x.startswith("step_"))


def test_roundtrip(tmp_path):
    cfg = smoke_variant(get_arch("qwen3-32b"))
    params = tf.init_params(cfg, jax.random.key(0))
    path = ckpt.save(str(tmp_path), 7, params)
    assert os.path.isdir(path)
    assert ckpt.latest_step(str(tmp_path)) == 7
    assert ckpt.latest_verified_step(str(tmp_path)) == 7

    like = jax.eval_shape(lambda: params)
    restored = ckpt.restore(str(tmp_path), 7, like)
    for (pa, a), (pb, b) in zip(jax.tree_util.tree_leaves_with_path(params),
                                jax.tree_util.tree_leaves_with_path(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_rejects_wrong_structure(tmp_path):
    """Validation raises CheckpointError (NOT assert — must survive
    ``python -O``) naming the leaf/count mismatch."""
    tree = {"a": jnp.ones((3,)), "b": jnp.zeros((2, 2))}
    ckpt.save(str(tmp_path), 1, tree)
    with pytest.raises(ckpt.CheckpointError, match="2 leaves.*has 1"):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.ones((3,))})
    with pytest.raises(ckpt.CheckpointError, match="leaf 0 shape mismatch"):
        ckpt.restore(str(tmp_path), 1,
                     {"a": jnp.ones((4,)), "b": jnp.zeros((2, 2))})


def test_restore_missing_step_and_leaf_raise_checkpoint_error(tmp_path):
    tree = {"a": jnp.ones((3,))}
    ckpt.save(str(tmp_path), 1, tree)
    with pytest.raises(ckpt.CheckpointError, match="no checkpoint"):
        ckpt.restore(str(tmp_path), 9, tree)
    os.remove(tmp_path / "step_00000001" / "arr_0.npy")
    with pytest.raises(ckpt.CheckpointError, match="leaf 0 unreadable"):
        ckpt.restore(str(tmp_path), 1, tree)


def test_multiple_steps_latest(tmp_path):
    tree = {"w": jnp.ones((2,))}
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 10, tree)
    ckpt.save(str(tmp_path), 5, tree)
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_optimizer_state_roundtrip(tmp_path):
    from repro.optim import AdaFactorW
    cfg = smoke_variant(get_arch("llama3.2-1b"))
    params = tf.init_params(cfg, jax.random.key(1))
    opt = AdaFactorW()
    st = opt.init(params)
    ckpt.save(str(tmp_path), 2, {"params": params, "opt": st})
    like = jax.eval_shape(lambda: {"params": params, "opt": st})
    restored = ckpt.restore(str(tmp_path), 2, like)
    assert restored["opt"].m["final_norm"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# integrity: verify / latest_verified_step on corrupted checkpoints
# ---------------------------------------------------------------------------


_TREE = {"a": np.arange(64, dtype=np.float32).reshape(8, 8),
         "b": np.ones((16,), np.float32)}


def test_index_records_hash_and_size(tmp_path):
    ckpt.save(str(tmp_path), 1, _TREE)
    with open(tmp_path / "step_00000001" / "index.json") as f:
        index = json.load(f)
    assert index["format"] == 2
    for i, leaf in enumerate(index["leaves"]):
        path = tmp_path / "step_00000001" / f"arr_{i}.npy"
        assert leaf["bytes"] == os.path.getsize(path)
        assert len(leaf["sha256"]) == 64


def test_verify_rejects_truncated_leaf(tmp_path):
    ckpt.save(str(tmp_path), 1, _TREE)
    faults.truncate_leaf(str(tmp_path), 1, leaf=0)
    with pytest.raises(ckpt.CheckpointError, match="leaf 0 truncated"):
        ckpt.verify(str(tmp_path), 1)


def test_verify_rejects_flipped_byte(tmp_path):
    """Bit rot keeps the size right — only the sha256 catches it."""
    ckpt.save(str(tmp_path), 1, _TREE)
    assert ckpt.verify(str(tmp_path), 1)["n"] == 2
    faults.flip_byte(str(tmp_path), 1, leaf=1)
    with pytest.raises(ckpt.CheckpointError, match="leaf 1 content hash"):
        ckpt.verify(str(tmp_path), 1)


def test_verify_rejects_tampered_index_hash(tmp_path):
    ckpt.save(str(tmp_path), 1, _TREE)
    faults.tamper_index_hash(str(tmp_path), 1, leaf=0)
    with pytest.raises(ckpt.CheckpointError, match="leaf 0 content hash"):
        ckpt.verify(str(tmp_path), 1)


def test_verify_rejects_missing_leaf_and_index(tmp_path):
    ckpt.save(str(tmp_path), 1, _TREE)
    os.remove(tmp_path / "step_00000001" / "arr_1.npy")
    with pytest.raises(ckpt.CheckpointError, match="leaf 1 missing"):
        ckpt.verify(str(tmp_path), 1)
    os.remove(tmp_path / "step_00000001" / "index.json")
    with pytest.raises(ckpt.CheckpointError, match="missing index.json"):
        ckpt.verify(str(tmp_path), 1)
    with pytest.raises(ckpt.CheckpointError, match="no checkpoint dir"):
        ckpt.verify(str(tmp_path), 42)


def test_latest_verified_skips_bad_newest_to_good_older(tmp_path):
    """Auto-resume must land on the newest GOOD checkpoint: a corrupt
    newest step and a truncated middle step are both skipped."""
    for s in (1, 2, 3):
        ckpt.save(str(tmp_path), s, _TREE)
    faults.flip_byte(str(tmp_path), 3)
    assert ckpt.latest_verified_step(str(tmp_path)) == 2
    faults.truncate_leaf(str(tmp_path), 2)
    assert ckpt.latest_verified_step(str(tmp_path)) == 1
    faults.tamper_index_hash(str(tmp_path), 1)
    assert ckpt.latest_verified_step(str(tmp_path)) is None


def test_latest_verified_gcs_leftover_tmp_dirs(tmp_path):
    """A crash mid-save leaks ``.tmp_ckpt_*``; resume GCs it and never
    mistakes it for a checkpoint."""
    ckpt.save(str(tmp_path), 4, _TREE)
    tmp = faults.leftover_tmp(str(tmp_path))
    assert os.path.isdir(tmp)
    assert ckpt.latest_verified_step(str(tmp_path)) == 4
    assert not os.path.isdir(tmp)
    # gc=False leaves alien dirs alone (an in-flight writer may own them)
    tmp2 = faults.leftover_tmp(str(tmp_path))
    assert ckpt.latest_verified_step(str(tmp_path), gc=False) == 4
    assert os.path.isdir(tmp2)


def test_verify_accepts_legacy_index_without_hashes(tmp_path):
    """Format-1 checkpoints (pre-integrity) still verify on existence +
    leaf count, so old runs stay resumable."""
    ckpt.save(str(tmp_path), 1, _TREE)
    ipath = tmp_path / "step_00000001" / "index.json"
    with open(ipath) as f:
        index = json.load(f)
    for leaf in index["leaves"]:
        leaf.pop("sha256"), leaf.pop("bytes")
    index.pop("format")
    with open(ipath, "w") as f:
        json.dump(index, f)
    assert ckpt.verify(str(tmp_path), 1)["n"] == 2
    assert ckpt.latest_verified_step(str(tmp_path)) == 1


# ---------------------------------------------------------------------------
# retention / GC keep policy
# ---------------------------------------------------------------------------


def test_gc_keep_last_k(tmp_path):
    for s in range(1, 7):
        ckpt.save(str(tmp_path), s, _TREE)
    removed = ckpt.gc_steps(str(tmp_path), keep_last=2)
    assert removed == [1, 2, 3, 4]
    assert _steps_on_disk(tmp_path) == [5, 6]


def test_gc_keep_last_1_never_removes_newest(tmp_path):
    """K=1 edge: everything but the newest goes; K=0 is rejected."""
    for s in (3, 9):
        ckpt.save(str(tmp_path), s, _TREE)
    assert ckpt.gc_steps(str(tmp_path), keep_last=1) == [3]
    assert _steps_on_disk(tmp_path) == [9]
    assert ckpt.gc_steps(str(tmp_path), keep_last=1) == []
    with pytest.raises(ckpt.CheckpointError, match="keep_last"):
        ckpt.gc_steps(str(tmp_path), keep_last=0)


def test_gc_keep_every_n_boundary(tmp_path):
    """keep-every-N: multiples of N survive forever, including step N
    itself exactly at the boundary; non-multiples outside the K window
    go."""
    for s in range(1, 11):
        ckpt.save(str(tmp_path), s, _TREE)
    ckpt.gc_steps(str(tmp_path), keep_last=2, keep_every=5)
    assert _steps_on_disk(tmp_path) == [5, 9, 10]  # 5,10 kept; 9,10 last-2
    ckpt.save(str(tmp_path), 11, _TREE)
    ckpt.gc_steps(str(tmp_path), keep_last=2, keep_every=5)
    assert _steps_on_disk(tmp_path) == [5, 10, 11]


# ---------------------------------------------------------------------------
# async manager
# ---------------------------------------------------------------------------


def test_manager_async_save_matches_sync(tmp_path):
    """Async and sync paths must byte-agree: same index hashes, same
    restored values, meta riding the same atomic rename."""
    a = ckpt.AsyncCheckpointManager(str(tmp_path / "a"))
    a.save_async(1, _TREE, meta={"k": 1})
    a.close()
    s = ckpt.AsyncCheckpointManager(str(tmp_path / "s"), sync=True)
    s.save(1, _TREE, meta={"k": 1})
    with open(tmp_path / "a" / "step_00000001" / "index.json") as f:
        ia = json.load(f)
    with open(tmp_path / "s" / "step_00000001" / "index.json") as f:
        ib = json.load(f)
    assert [x["sha256"] for x in ia["leaves"]] == \
        [x["sha256"] for x in ib["leaves"]]
    assert ckpt.load_meta(str(tmp_path / "a"), 1) == {"k": 1}
    got = ckpt.restore(str(tmp_path / "a"), 1, _TREE)
    np.testing.assert_array_equal(np.asarray(got["a"]), _TREE["a"])
    assert a.stats["async_saves"] == 1 and s.stats["sync_saves"] == 1


def test_manager_joins_inflight_write_before_next_save(tmp_path):
    """A second save (or shutdown) joins the in-flight write — step dirs
    appear in order and at most one background writer exists."""
    gate = threading.Event()
    orig = ckpt_io.write_snapshot

    def slow(directory, step, arrs, treedef, meta=None):
        if step == 1:
            gate.wait(timeout=10.0)
        return orig(directory, step, arrs, treedef, meta=meta)

    m = ckpt.AsyncCheckpointManager(str(tmp_path))
    ckpt_io_write, ckpt_io.write_snapshot = \
        ckpt_io.write_snapshot, slow
    try:
        m.save_async(1, _TREE)
        assert m.in_flight
        gate.set()
        m.save_async(2, _TREE)  # joins step 1 first
        assert ckpt.verify(str(tmp_path), 1)
        m.close()
        assert ckpt.verify(str(tmp_path), 2)
    finally:
        ckpt_io.write_snapshot = ckpt_io_write


def test_manager_surfaces_write_error_on_next_call_then_heals(tmp_path):
    """A failed background write raises on the NEXT wait()/save; the
    manager retries transient OSErrors with backoff before giving up, and
    keeps working once the fault clears."""
    m = ckpt.AsyncCheckpointManager(str(tmp_path), max_retries=2,
                                    backoff_s=0.005)
    with faults.failing_writes(100) as fired:
        m.save_async(1, _TREE)
        with pytest.raises(ckpt.CheckpointError, match="step 1 failed"):
            m.wait()
    assert fired["fired"] == 3          # 1 try + 2 retries, capped backoff
    assert m.stats["retried_writes"] == 2 and m.stats["failed_writes"] == 1
    # no torn step dir was published
    assert ckpt.latest_verified_step(str(tmp_path)) is None
    m.save_async(2, _TREE)              # healed: works again
    m.close()
    assert ckpt.latest_verified_step(str(tmp_path)) == 2


def test_manager_transient_fault_retries_through(tmp_path):
    """A fault that clears within the retry budget never surfaces."""
    m = ckpt.AsyncCheckpointManager(str(tmp_path), max_retries=3,
                                    backoff_s=0.005)
    with faults.failing_writes(2):
        m.save_async(1, _TREE)
        m.wait()                        # no raise: retries absorbed it
    assert m.stats["retried_writes"] == 2
    assert ckpt.verify(str(tmp_path), 1)


def test_manager_retention_rides_saves(tmp_path):
    m = ckpt.AsyncCheckpointManager(str(tmp_path), keep_last=2,
                                    keep_every=4)
    for s in range(1, 7):
        m.save(s, _TREE)
    m.close()
    assert _steps_on_disk(tmp_path) == [4, 5, 6]
    assert m.stats["gc_removed"] == 3
