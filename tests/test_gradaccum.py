"""Algorithm 1 (paper §4.2): microbatched contrastive gradients are EXACT."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.contrastive import contrastive_loss
from repro.core.gradaccum import contrastive_step, microbatch_grads


def _setup(b=24, din=12, d=8, seed=0):
    key = jax.random.key(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "wi": 0.3 * jax.random.normal(k1, (din, d)),
        "wt": 0.3 * jax.random.normal(k2, (din, d)),
        "log_tau": jnp.asarray(-1.0),
    }
    batch = {"images": jax.random.normal(k3, (b, din)),
             "texts": jax.random.normal(k4, (b, din))}

    def norm(z):
        return z / jnp.linalg.norm(z, axis=-1, keepdims=True)

    enc_i = lambda p, x: norm(jnp.tanh(x @ p["wi"]))   # noqa: E731
    enc_t = lambda p, y: norm(jnp.tanh(y @ p["wt"]))   # noqa: E731

    def direct(p):
        x, y = enc_i(p, batch["images"]), enc_t(p, batch["texts"])
        return contrastive_loss(x, y, jnp.exp(p["log_tau"]))

    return params, batch, enc_i, enc_t, direct


@pytest.mark.parametrize("num_micro", [1, 2, 4, 8, 24])
def test_gradaccum_exact_for_any_microbatch_count(num_micro):
    params, batch, enc_i, enc_t, direct = _setup()
    (l0, _), g0 = jax.value_and_grad(direct, has_aux=True)(params)
    l1, _, g1 = contrastive_step(enc_i, enc_t, params, batch, num_micro)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
    for k in g0:
        np.testing.assert_allclose(np.asarray(g0[k]), np.asarray(g1[k]),
                                   rtol=2e-5, atol=1e-7, err_msg=k)


def test_stream_mean_equals_exact_grad():
    params, batch, enc_i, enc_t, direct = _setup()
    (_, _), g0 = jax.value_and_grad(direct, has_aux=True)(params)
    _, _, c = microbatch_grads(enc_i, enc_t, params, batch, 4)
    gm = jax.tree.map(lambda x: jnp.mean(x, 0), c)
    for k in g0:
        np.testing.assert_allclose(np.asarray(g0[k]), np.asarray(gm[k]),
                                   rtol=2e-5, atol=1e-7, err_msg=k)


def test_gradaccum_under_jit_and_matches_monolithic_loss_value():
    params, batch, enc_i, enc_t, direct = _setup(b=16)
    fn = jax.jit(lambda p, b: contrastive_step(enc_i, enc_t, p, b, 4))
    l1, metrics, g1 = fn(params, batch)
    (l0, m0), _ = jax.value_and_grad(direct, has_aux=True)(params)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
    np.testing.assert_allclose(float(m0["i2t_top1"]),
                               float(metrics["i2t_top1"]))


def test_gradaccum_with_dual_encoder_towers():
    """End-to-end Algorithm 1 on the real dual-encoder model."""
    import dataclasses

    from repro.configs import get_arch, smoke_variant
    from repro.models import dual_encoder as de

    cfg = get_arch("basic-s")
    cfg = dataclasses.replace(
        cfg, image_tower=smoke_variant(cfg.image_tower),
        text_tower=smoke_variant(cfg.text_tower), embed_dim=32)
    params = de.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    b = 8
    it = cfg.image_tower
    batch = {
        "images": {"image": jnp.asarray(
            rng.standard_normal((b, it.image_size, it.image_size,
                                 it.channels)), jnp.float32)},
        "texts": {"tokens": jnp.asarray(
            rng.integers(0, cfg.text_tower.vocab, (b, 12)), jnp.int32)},
    }
    enc_i = lambda p, im: de.encode_image(cfg, p, im)   # noqa: E731
    enc_t = lambda p, tx: de.encode_text(cfg, p, tx)    # noqa: E731

    def direct(p):
        return contrastive_loss(enc_i(p, batch["images"]),
                                enc_t(p, batch["texts"]),
                                jnp.exp(p["log_tau"]))

    (l0, _), g0 = jax.value_and_grad(direct, has_aux=True)(params)
    l1, _, g1 = contrastive_step(enc_i, enc_t, params, batch, 4)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
    flat0 = jax.tree_util.tree_leaves_with_path(g0)
    flat1 = dict(jax.tree_util.tree_leaves_with_path(g1))
    for path, leaf in flat0:
        np.testing.assert_allclose(
            np.asarray(leaf), np.asarray(flat1[path]), rtol=5e-4, atol=5e-6,
            err_msg=str(path))
