"""Theory module (paper §6): bound shape + empirical gap machinery."""
import numpy as np

from repro.core.theory import bound_terms, empirical_gap, norm_product


def _params():
    rng = np.random.default_rng(0)
    return {"blocks": [{"w": rng.standard_normal((4, 16, 16))}],
            "proj": rng.standard_normal((16, 8))}


def test_bound_decreases_in_B_and_m():
    p = _params()
    b1 = bound_terms(None, p, p, m=1000, B=64)
    b2 = bound_terms(None, p, p, m=1000, B=1024)
    b3 = bound_terms(None, p, p, m=16000, B=64)
    assert b2["term_1_over_sqrt_2B"] < b1["term_1_over_sqrt_2B"]
    assert b3["term_1_over_sqrt_m"] < b1["term_1_over_sqrt_m"]
    assert b2["gap_shape"] < b1["gap_shape"]
    assert b3["gap_shape"] < b1["gap_shape"]


def test_bound_rate_is_one_over_sqrt_B():
    p = _params()
    t = [bound_terms(None, p, p, m=1000, B=b)["term_1_over_sqrt_2B"]
         for b in (64, 256, 1024)]
    np.testing.assert_allclose(t[0] / t[1], 2.0, rtol=0.05)
    np.testing.assert_allclose(t[1] / t[2], 2.0, rtol=0.05)


def test_norm_product_counts_matrices():
    p = _params()
    out = norm_product(p)
    assert out["depth"] == 5  # 4 stacked + 1 proj
    assert np.isfinite(out["log_prod"])


def test_empirical_gap_near_zero_for_same_distribution():
    rng = np.random.default_rng(1)

    def unit(n, d):
        z = rng.standard_normal((n, d)).astype(np.float32)
        return z / np.linalg.norm(z, axis=1, keepdims=True)

    x, y = unit(256, 16), unit(256, 16)
    gap = empirical_gap(x, y, x, y)
    assert abs(gap) < 0.2
