"""Vision frontend: patchify geometry, spec/synthetic alignment, and the
raw-image contrastive training path (DESIGN.md §8)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs, smoke_variant
from repro.configs.base import INPUT_SHAPES, InputShape
from repro.models import frontends, transformer as tf

VISION_ARCHS = [a for a in list_archs() if not a.startswith("basic-")
                and get_arch(a).frontend == "vision"]


def test_patchify_is_inverse_of_render_grid():
    """The synthetic world assembles images patch-by-patch; the model's
    patchify must recover exactly those patch pixel vectors."""
    from repro.data.synthetic import make_world, render_images
    rng = np.random.default_rng(0)
    world = make_world(rng, n_classes=4, image_size=16, patch_size=4)
    cls = rng.integers(0, 4, 5)
    imgs = render_images(world, cls, rng)
    assert imgs.shape == (5, 16, 16, 3)
    patches = frontends.patchify(jnp.asarray(imgs), 4)
    assert patches.shape == (5, 16, 48)
    # re-render the expected patch pixels: latent -> camera, same stream
    rng2 = np.random.default_rng(0)
    world2 = make_world(rng2, n_classes=4, image_size=16, patch_size=4)
    assert np.array_equal(rng2.integers(0, 4, 5), cls)   # replay cls draw
    z = world2.concept_vecs[cls][:, None, :] + \
        world2.noise * rng2.standard_normal((5, 16, 32))
    np.testing.assert_allclose(np.asarray(patches),
                               (z @ world2.camera).astype(np.float32),
                               rtol=1e-5, atol=1e-6)


def test_vision_configs_geometry_consistent():
    """frontend_len must equal the patch-grid size for every vision arch
    (incl. the basic towers) and survive smoke_variant shrinking."""
    checked = 0
    for name in list_archs():
        cfg = get_arch(name)
        towers = [cfg] if hasattr(cfg, "family") else \
            [cfg.image_tower, cfg.text_tower]
        for t in towers:
            if t.frontend != "vision":
                continue
            assert (t.image_size // t.patch_size) ** 2 == t.frontend_len, t
            sm = smoke_variant(t)
            assert (sm.image_size // sm.patch_size) ** 2 == sm.frontend_len
            checked += 1
    assert checked >= 4          # internvl2 + 3 basic image towers


@pytest.mark.parametrize("arch", VISION_ARCHS)
def test_synthetic_inputs_match_train_spec(arch):
    """Regression for the historical drift: synthetic_inputs must produce
    exactly train_inputs_spec's keys/shapes/dtypes (the spec pins
    frontend_len; the old synthetic path used min(frontend_len, seq//4))."""
    cfg = smoke_variant(get_arch(arch))
    shape = InputShape("t", seq_len=48, global_batch=2, kind="train")
    spec = frontends.train_inputs_spec(cfg, shape, dtype=jnp.float32)
    got = frontends.synthetic_inputs(cfg, 2, 48, np.random.default_rng(0))
    assert set(spec) == set(got)
    for k in spec:
        assert tuple(spec[k].shape) == tuple(np.shape(got[k])), k
        assert spec[k].dtype == got[k].dtype, k


def test_train_spec_matches_synthetic_for_all_archs():
    """Same alignment across every assigned arch at the smoke shape."""
    for arch in [a for a in list_archs() if not a.startswith("basic-")]:
        cfg = smoke_variant(get_arch(arch))
        shape = InputShape("t", seq_len=32, global_batch=2, kind="train")
        spec = frontends.train_inputs_spec(cfg, shape, dtype=jnp.float32)
        got = frontends.synthetic_inputs(cfg, 2, 32, np.random.default_rng(1))
        assert set(spec) == set(got), arch
        for k in spec:
            assert tuple(spec[k].shape) == tuple(np.shape(got[k])), (arch, k)


def test_contrastive_smoke_step_consumes_raw_images():
    """Acceptance: a contrastive train step runs end-to-end on raw synthetic
    images through the patchify frontend (no precomputed patch embeddings
    anywhere in the batch), and the frontend weights receive gradient."""
    from repro.configs import smoke_dual_variant
    from repro.data import (Tokenizer, caption_corpus, contrastive_batch,
                            world_for_tower)
    from repro.launch import steps as st

    cfg = smoke_dual_variant(get_arch("basic-s"))
    rng = np.random.default_rng(0)
    world = world_for_tower(rng, cfg.image_tower, n_classes=8, noise=0.2)
    tok = Tokenizer.train(caption_corpus(world, rng, 200), vocab_size=300)
    batch, _ = contrastive_batch(world, tok, 8, rng)
    assert set(batch["images"]) == {"image"}
    assert batch["images"]["image"].ndim == 4
    batch = jax.tree.map(jnp.asarray, batch)

    from repro.models import dual_encoder as de
    params = de.init_params(cfg, jax.random.key(0))
    step, opt = st.make_contrastive_step(cfg, num_micro=2, attn="pallas")
    opt_state = opt.init(params)
    p0 = params["image"]["tower"]["frontend"]["patch_proj"]
    params2, opt_state, loss, _ = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(loss))
    delta = float(jnp.max(jnp.abs(
        params2["image"]["tower"]["frontend"]["patch_proj"] - p0)))
    assert delta > 0.0           # the frontend actually trains


def test_image_tower_rejects_patch_embedding_stub():
    """The training path no longer accepts the retired stub key."""
    cfg = smoke_variant(get_arch("basic-s").image_tower)
    params = tf.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    stub = {"patch_embeddings": jnp.asarray(
        rng.standard_normal((2, cfg.frontend_len, cfg.d_model)),
        jnp.float32)}
    with pytest.raises(KeyError):
        tf.encode(cfg, params, stub)
