"""Decode-attention kernel vs oracle: shape/dtype sweep + ring-mask cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


@pytest.mark.parametrize("b,h,kv,t,d", [
    (2, 8, 2, 256, 64),
    (1, 4, 4, 128, 32),
    (3, 6, 2, 512, 128),
    (1, 16, 1, 256, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, h, kv, t, d, dtype):
    ks = jax.random.split(jax.random.key(b * t + h), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, t, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, t, d), jnp.float32).astype(dtype)
    valid = jnp.arange(t) < (t * 3 // 4)      # partially-filled cache
    ref = decode_attention_ref(q, k, v, valid)
    got = decode_attention(q, k, v, valid, block_k=64, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_decode_attention_full_ring():
    """Ring fully wrapped: every slot valid."""
    ks = jax.random.split(jax.random.key(0), 3)
    b, h, kv, t, d = 2, 4, 2, 128, 32
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kv, t, d))
    v = jax.random.normal(ks[2], (b, kv, t, d))
    valid = jnp.ones((t,), bool)
    ref = decode_attention_ref(q, k, v, valid)
    got = decode_attention(q, k, v, valid, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_decode_attention_single_valid_slot():
    """Only one live slot -> output must equal that slot's value row."""
    ks = jax.random.split(jax.random.key(1), 3)
    b, h, kv, t, d = 1, 2, 2, 64, 16
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kv, t, d))
    v = jax.random.normal(ks[2], (b, kv, t, d))
    valid = (jnp.arange(t) == 5)
    got = decode_attention(q, k, v, valid, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got[0, 0]), np.asarray(v[0, 0, 5]),
                               atol=2e-5)


@pytest.mark.parametrize("b,h,kv,t,d", [
    (4, 8, 2, 256, 64),
    (3, 4, 4, 128, 32),
    (2, 16, 1, 256, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_per_slot_ragged(b, h, kv, t, d, dtype):
    """Continuous-batching shape: every slot has its OWN live length —
    including the edge lengths 0 (a free slot: must return zeros) and t
    (a fully wrapped slot) — and the kernel must match the per-slot
    einsum oracle row for row."""
    ks = jax.random.split(jax.random.key(7 * b + t + h), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, t, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, t, d), jnp.float32).astype(dtype)
    # staggered lengths: 0 (free slot), 1, ragged middles, full cache
    lengths = np.array([0, 1, t // 2 - 3, t][:b] + [t // 3] * max(0, b - 4))
    valid = jnp.arange(t)[None, :] < jnp.asarray(lengths)[:, None]
    ref = decode_attention_ref(q, k, v, valid)
    got = decode_attention(q, k, v, valid, block_k=64, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
    # the length-0 row is exactly zero in both
    np.testing.assert_array_equal(np.asarray(got[0], np.float32),
                                  np.zeros((h, d), np.float32))


def test_decode_attention_per_slot_matches_shared_mask():
    """A (b, t) mask with identical rows must reproduce the legacy (t,)
    shared-mask result bit for bit (same kernel schedule either way)."""
    ks = jax.random.split(jax.random.key(11), 3)
    b, h, kv, t, d = 3, 6, 2, 128, 32
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kv, t, d))
    v = jax.random.normal(ks[2], (b, kv, t, d))
    shared = jnp.arange(t) < 77
    per_slot = jnp.broadcast_to(shared[None, :], (b, t))
    a = decode_attention(q, k, v, shared, block_k=32, interpret=True)
    bb = decode_attention(q, k, v, per_slot, block_k=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


def test_decode_attention_per_slot_stale_rows_never_leak():
    """Slots beyond a row's live length carry STALE data from a retired
    request; poisoning them with huge values must not move the output
    (exp(NEG_INF - m) underflows to exactly 0)."""
    ks = jax.random.split(jax.random.key(12), 3)
    b, h, kv, t, d = 2, 4, 2, 128, 32
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kv, t, d))
    v = jax.random.normal(ks[2], (b, kv, t, d))
    lengths = jnp.asarray([5, 100])
    valid = jnp.arange(t)[None, :] < lengths[:, None]
    clean = decode_attention(q, k, v, valid, block_k=32, interpret=True)
    poison = jnp.where(valid[:, None, :, None], v, 1e6)
    kp = jnp.where(valid[:, None, :, None], k, 1e6)
    dirty = decode_attention(q, kp, poison, valid, block_k=32,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))


def test_decode_attention_matches_model_decode_path():
    """Kernel agrees with models.attention.decode_attention's einsum math."""
    ks = jax.random.split(jax.random.key(2), 3)
    b, h, kv, t, d = 2, 8, 4, 128, 32
    q = jax.random.normal(ks[0], (b, h, d))
    k = jax.random.normal(ks[1], (b, kv, t, d))
    v = jax.random.normal(ks[2], (b, kv, t, d))
    pos = 100
    valid = jnp.arange(t) <= pos
    # model-path math (inline): grouped softmax over valid slots
    ref = decode_attention_ref(q, k, v, valid)
    got = decode_attention(q, k, v, valid, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
