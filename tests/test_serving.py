"""Serving engine: generation correctness + EOS handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_variant
from repro.models import transformer as tf
from repro.serving import Engine


def _engine(arch="llama3.2-1b", cache_len=64):
    cfg = smoke_variant(get_arch(arch))
    params = tf.init_params(cfg, jax.random.key(0))
    return cfg, params, Engine(cfg, params, cache_len=cache_len,
                               moe_args={"dispatch": "dense"})


def test_greedy_generation_matches_manual_decode():
    cfg, params, eng = _engine()
    rng = np.random.default_rng(0)
    prompts = rng.integers(4, cfg.vocab, (2, 8)).astype(np.int32)
    out = eng.generate(prompts, 4, temperature=0.0)

    # manual: extend via teacher-forced prefill each step
    cur = prompts.copy()
    for i in range(4):
        logits = tf.prefill(cfg, params, {"tokens": jnp.asarray(cur)},
                            dtype=jnp.float32, moe_args={"dispatch": "dense"})
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], -1), np.int32)
        stopped = np.any(cur == 3, axis=1)
        for b in range(2):
            np.testing.assert_equal(out[b, i], 0 if stopped[b] else nxt[b])
        cur = np.concatenate([cur, nxt[:, None]], axis=1)


def test_generation_stops_at_eos():
    cfg, params, eng = _engine()
    # craft prompt; force eos by patching eos_id to the first generated token
    rng = np.random.default_rng(1)
    prompts = rng.integers(4, cfg.vocab, (1, 8)).astype(np.int32)
    first = eng.generate(prompts, 1, temperature=0.0)[0, 0]
    eng.eos_id = int(first)
    out = eng.generate(prompts, 6, temperature=0.0)
    assert out[0, 0] == first
    assert np.all(out[0, 1:] == 0)  # padded after stop


@pytest.mark.parametrize("arch", ["mamba2-130m", "jamba-1.5-large-398b"])
def test_engine_with_state_space_archs(arch):
    cfg, params, eng = _engine(arch)
    rng = np.random.default_rng(2)
    prompts = rng.integers(4, cfg.vocab, (2, 8)).astype(np.int32)
    out = eng.generate(prompts, 5, temperature=0.0)
    assert out.shape == (2, 5)
    assert np.all(out >= 0) and np.all(out < cfg.vocab)


def test_engine_precision_policy_and_pallas_decode():
    """ISSUE-5 satellite: the engine takes a models.precision policy (not a
    bare dtype) and attn='pallas' routes decode through the
    kernels/decode_attention cache sweep — greedy outputs must match the
    einsum path token for token, and the legacy dtype= argument must keep
    resolving onto a policy."""
    cfg, params, eng = _engine()
    rng = np.random.default_rng(3)
    prompts = rng.integers(4, cfg.vocab, (2, 8)).astype(np.int32)
    ref = eng.generate(prompts, 5, temperature=0.0)

    pal = Engine(cfg, params, cache_len=64, attn="pallas",
                 moe_args={"dispatch": "dense"})
    np.testing.assert_array_equal(pal.generate(prompts, 5, temperature=0.0),
                                  ref)
    assert pal.cfg.attn_impl == "pallas"

    legacy = Engine(cfg, params, cache_len=64, dtype=jnp.float32,
                    moe_args={"dispatch": "dense"})
    assert legacy.precision.name == "f32"
    np.testing.assert_array_equal(
        legacy.generate(prompts, 5, temperature=0.0), ref)

    bf = Engine(cfg, params, cache_len=64, precision="bf16",
                moe_args={"dispatch": "dense"})
    assert bf.precision.compute_dtype == jnp.bfloat16
    assert bf.precision.fp32_projections
    out = bf.generate(prompts, 5, temperature=0.0)
    assert out.shape == ref.shape

    # typos fail at construction, not at the first compiled generate()
    with pytest.raises(KeyError, match="palas"):
        Engine(cfg, params, cache_len=64, attn="palas")


def test_decode_backend_resolution():
    """resolve_decode_backend: 'auto' is platform-aware, full-sequence
    names map to einsum, pallas falls back on untileable caches."""
    from repro.models.attention import resolve_decode_backend as r
    assert r("auto", cache_len=256, head_dim=64, platform="cpu") == "einsum"
    assert r("auto", cache_len=512, head_dim=128, platform="tpu") == "pallas"
    assert r("pallas", cache_len=256, head_dim=64, platform="cpu") == "pallas"
    assert r("naive", cache_len=256, head_dim=64) == "einsum"
    assert r("chunked", cache_len=256, head_dim=64) == "einsum"
    # 300 % min(256, 300) != 0: kernel can't tile, fall back
    assert r("pallas", cache_len=300, head_dim=64) == "einsum"
    # lane-alignment on a real accelerator
    assert r("pallas", cache_len=256, head_dim=64, platform="tpu") == "einsum"
    with pytest.raises(KeyError):
        r("bogus", cache_len=256, head_dim=64)


def test_engine_rejects_encoder_only():
    cfg = smoke_variant(get_arch("hubert-xlarge"))
    params = tf.init_params(cfg, jax.random.key(0))
    with pytest.raises(AssertionError):
        Engine(cfg, params, cache_len=32)


def test_sampling_temperature_changes_output_distribution():
    cfg, params, eng = _engine()
    rng = np.random.default_rng(3)
    prompts = rng.integers(4, cfg.vocab, (1, 8)).astype(np.int32)
    a = eng.generate(prompts, 8, temperature=5.0, seed=0)
    b = eng.generate(prompts, 8, temperature=5.0, seed=1)
    assert not np.array_equal(a, b)
