"""Zero-shot inference subsystem: micro-batcher flush behavior, registry
caching/invalidation/persistence, and the ZeroShotService end-to-end."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_variant
from repro.data import Tokenizer, caption_corpus, world_for_tower
from repro.data.synthetic import render_images
from repro.models import dual_encoder as de
from repro.serving import MicroBatcher, ZeroShotService
from repro.serving.embed.registry import (ClassEmbeddingRegistry,
                                          params_fingerprint)

_CACHE = {}


def _world():
    if "w" not in _CACHE:
        cfg = get_arch("basic-s")
        cfg = dataclasses.replace(
            cfg, image_tower=smoke_variant(cfg.image_tower),
            text_tower=smoke_variant(cfg.text_tower), embed_dim=32)
        rng = np.random.default_rng(0)
        world = world_for_tower(rng, cfg.image_tower, n_classes=10,
                                noise=0.2)
        tok = Tokenizer.train(caption_corpus(world, rng, 300), vocab_size=400)
        params = de.init_params(cfg, jax.random.key(0))
        _CACHE["w"] = (cfg, world, tok, params)
    return _CACHE["w"]


# ---------------------------------------------------------------------------
# micro-batcher
# ---------------------------------------------------------------------------


def _sum_encoder(batch):
    """Deterministic stand-in encoder: per-example, batch-size invariant."""
    return jnp.stack([jnp.sum(batch["v"], axis=1),
                      jnp.max(batch["v"], axis=1)], axis=1)


def test_batcher_flush_on_size():
    mb = MicroBatcher({"t": _sum_encoder}, buckets=(1, 2, 4),
                      max_delay_ms=60_000.0)  # deadline can't fire
    try:
        futs = [mb.submit("t", {"v": np.full((3,), i, np.float32)})
                for i in range(4)]
        out = [f.result(timeout=10.0) for f in futs]
    finally:
        mb.stop()
    np.testing.assert_allclose(np.stack(out)[:, 0], [0.0, 3.0, 6.0, 9.0])
    assert mb.stats["size_flushes"] >= 1
    assert mb.stats["deadline_flushes"] == 0


def test_batcher_flush_on_deadline_pads_to_bucket():
    mb = MicroBatcher({"t": _sum_encoder}, buckets=(1, 2, 4, 8),
                      max_delay_ms=30.0)
    try:
        t0 = time.monotonic()
        futs = [mb.submit("t", {"v": np.full((3,), i, np.float32)})
                for i in range(3)]  # 3 < largest bucket: only time flushes
        out = [f.result(timeout=10.0) for f in futs]
        dt = time.monotonic() - t0
    finally:
        mb.stop()
    np.testing.assert_allclose(np.stack(out)[:, 0], [0.0, 3.0, 6.0])
    assert mb.stats["deadline_flushes"] >= 1
    assert dt >= 0.03  # not before the deadline
    # 3 requests padded into the 4-bucket
    assert mb.stats["padded_examples"] == 1
    ((key, _),) = mb.compiled_shapes().items()
    assert key[1] == 4


def test_batcher_compiled_shape_cache_reuses_buckets():
    mb = MicroBatcher({"t": _sum_encoder}, buckets=(1, 2, 4),
                      max_delay_ms=60_000.0, autostart=False)
    for n in (3, 4, 3, 1):
        mb.submit_many("t", {"v": np.zeros((n, 3), np.float32)})
        mb.flush_now()
    keys = mb.compiled_shapes()
    assert mb.stats["manual_flushes"] == 4
    # 3→4, 4→4, 3→4, 1→1: exactly two distinct compiled shapes
    assert sorted(k[1] for k in keys) == [1, 4]
    assert keys[("t", 4, ((((3,), "float32")),))] == 3


def test_batcher_oversized_group_slices_through_ladder():
    mb = MicroBatcher({"t": _sum_encoder}, buckets=(1, 2, 4),
                      max_delay_ms=60_000.0, autostart=False)
    fut = mb.submit_many("t", {"v": np.arange(30, dtype=np.float32)
                               .reshape(10, 3)})
    mb.flush_now()
    out = fut.result(timeout=10.0)
    assert out.shape == (10, 2)
    np.testing.assert_allclose(
        out[:, 0], np.arange(30, dtype=np.float32).reshape(10, 3).sum(1))
    assert all(k[1] <= 4 for k in mb.compiled_shapes())


def test_batcher_matches_unbatched_encode():
    """Bucket padding must not leak into real rows."""
    cfg, world, tok, params = _world()
    rng = np.random.default_rng(1)
    imgs = render_images(world, rng.integers(0, 10, 3), rng)
    enc = jax.jit(lambda im: de.encode_image(cfg, params, im))
    mb = MicroBatcher({"image": enc}, buckets=(1, 2, 4, 8),
                      max_delay_ms=60_000.0, autostart=False)
    fut = mb.submit_many("image", {"image": imgs})
    mb.flush_now()
    got = fut.result(timeout=10.0)
    want = np.asarray(enc({"image": jnp.asarray(imgs)}))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_batcher_mixed_payload_structures_do_not_coalesce():
    """Groups with different treedefs/shapes in one flush window must encode
    in separate cohorts, not silently mispair leaves under one treedef."""
    def enc(batch):
        out = jnp.sum(batch["v"], axis=1)
        if "w" in batch:
            out = out + 100.0 * jnp.sum(batch["w"], axis=1)
        return out[:, None]

    mb = MicroBatcher({"t": enc}, buckets=(1, 2, 4),
                      max_delay_ms=60_000.0, autostart=False)
    f1 = mb.submit_many("t", {"v": np.ones((2, 3), np.float32)})
    f2 = mb.submit_many("t", {"v": np.ones((2, 3), np.float32),
                              "w": np.ones((2, 3), np.float32)})
    f3 = mb.submit_many("t", {"v": np.ones((2, 5), np.float32)})
    mb.flush_now()
    np.testing.assert_allclose(f1.result(timeout=10.0)[:, 0], [3.0, 3.0])
    np.testing.assert_allclose(f2.result(timeout=10.0)[:, 0], [303.0, 303.0])
    np.testing.assert_allclose(f3.result(timeout=10.0)[:, 0], [5.0, 5.0])


def test_batcher_delivers_encoder_errors():
    def bad(batch):
        raise RuntimeError("boom")
    mb = MicroBatcher({"t": bad}, buckets=(1, 2), max_delay_ms=60_000.0,
                      autostart=False)
    fut = mb.submit("t", {"v": np.zeros((3,), np.float32)})
    mb.flush_now()
    with pytest.raises(RuntimeError, match="boom"):
        fut.result(timeout=10.0)
    with pytest.raises(KeyError):
        mb.submit("nope", {"v": np.zeros((3,), np.float32)})


def test_batcher_flush_thread_bug_fails_pending_futures(monkeypatch):
    """Regression (ISSUE-6): an exception raised in the flush thread
    OUTSIDE the per-cohort encode path used to kill the worker and leave
    every pending future unresolved — callers blocked forever. Now every
    pending request fails with that exception and the worker survives."""
    from repro.serving.embed import batcher as batcher_mod

    def poisoned(payload):
        raise ValueError("poisoned shape-sig")
    monkeypatch.setattr(batcher_mod, "_shape_sig", poisoned)
    mb = MicroBatcher({"t": _sum_encoder, "u": _sum_encoder},
                      buckets=(1, 2, 4), max_delay_ms=5.0,
                      request_timeout_s=10.0)
    try:
        f1 = mb.submit_many("t", {"v": np.ones((2, 3), np.float32)})
        f2 = mb.submit_many("u", {"v": np.ones((2, 3), np.float32)})
        with pytest.raises(ValueError, match="poisoned"):
            f1.result(timeout=5.0)
        with pytest.raises(ValueError, match="poisoned"):
            f2.result(timeout=5.0)
        assert mb.running            # the worker did not die
    finally:
        mb.stop()
    assert mb.stats["worker_errors"] >= 1


def test_batcher_request_deadline_bounds_bare_result():
    """A blocked encode fn wedges the flush thread where no exception
    plumbing can reach — the per-request deadline still bounds a bare
    ``result()`` so classify/embed_* can never hang indefinitely."""
    from concurrent.futures import TimeoutError as FutTimeout
    release = time.monotonic() + 1.5

    def wedged(batch):
        while time.monotonic() < release:
            time.sleep(0.01)
        return jnp.sum(batch["v"], axis=1)[:, None]

    mb = MicroBatcher({"t": wedged}, buckets=(1, 2), max_delay_ms=1.0,
                      request_timeout_s=0.25)
    t0 = time.monotonic()
    try:
        fut = mb.submit_many("t", {"v": np.ones((1, 3), np.float32)})
        with pytest.raises(FutTimeout):
            fut.result()             # NO timeout argument — must not hang
        assert time.monotonic() - t0 < 1.0
    finally:
        mb.stop()


# ---------------------------------------------------------------------------
# class-embedding registry
# ---------------------------------------------------------------------------


def _fake_compute(calls):
    def compute(names, templates):
        calls.append(tuple(names))
        rng = np.random.default_rng(len(names))
        m = rng.standard_normal((len(names), 8)).astype(np.float32)
        return m / np.linalg.norm(m, axis=1, keepdims=True)
    return compute


def test_registry_cache_hit_and_checkpoint_invalidation(tmp_path):
    calls = []
    reg = ClassEmbeddingRegistry(_fake_compute(calls),
                                 cache_dir=str(tmp_path))
    names, tmpl = ("a b", "c d"), ("a {} {}",)
    m1 = reg.get(names, tmpl, "ckpt-1", embed_dim=8)
    m2 = reg.get(names, tmpl, "ckpt-1", embed_dim=8)
    assert len(calls) == 1 and m2.source == "memory"
    assert m1.version == m2.version == 1
    np.testing.assert_array_equal(m1.matrix, m2.matrix)

    # checkpoint change -> different key -> recompute
    m3 = reg.get(names, tmpl, "ckpt-2", embed_dim=8)
    assert len(calls) == 2 and m3.key != m1.key

    # template change -> different key too
    reg.get(names, ("b {} {}",), "ckpt-1", embed_dim=8)
    assert len(calls) == 3


def test_registry_persists_across_instances(tmp_path):
    calls = []
    reg = ClassEmbeddingRegistry(_fake_compute(calls),
                                 cache_dir=str(tmp_path))
    names, tmpl = ("a b", "c d", "e f"), ("x {} {}",)
    m1 = reg.get(names, tmpl, "ckpt", embed_dim=8)

    calls2 = []
    reg2 = ClassEmbeddingRegistry(_fake_compute(calls2),
                                  cache_dir=str(tmp_path))
    m2 = reg2.get(names, tmpl, "ckpt", embed_dim=8)
    assert calls2 == [] and m2.source == "disk"
    assert m2.version == m1.version
    np.testing.assert_allclose(m2.matrix, m1.matrix)


def test_registry_refresh_bumps_version(tmp_path):
    calls = []
    reg = ClassEmbeddingRegistry(_fake_compute(calls),
                                 cache_dir=str(tmp_path))
    names, tmpl = ("a b",), ("x {} {}",)
    assert reg.get(names, tmpl, "ckpt", embed_dim=8).version == 1
    assert reg.refresh(names, tmpl, "ckpt").version == 2
    assert reg.get(names, tmpl, "ckpt", embed_dim=8).version == 2


def test_params_fingerprint_sensitivity():
    cfg, _, _, params = _world()
    tag = params_fingerprint(params)
    assert tag == params_fingerprint(params)
    bumped = jax.tree.map(lambda a: a, params)
    bumped["log_tau"] = params["log_tau"] + 1e-3
    assert params_fingerprint(bumped) != tag


# ---------------------------------------------------------------------------
# ZeroShotService end-to-end
# ---------------------------------------------------------------------------


def test_service_classify_matches_offline_pipeline(tmp_path):
    from repro.eval import class_embeddings

    cfg, world, tok, params = _world()
    rng = np.random.default_rng(2)
    cls = rng.integers(0, 10, 6)
    imgs = render_images(world, cls, rng)
    with ZeroShotService(cfg, params, tok, registry_dir=str(tmp_path),
                         max_delay_ms=1.0) as svc:
        res = svc.classify(imgs, world.class_names, k=5)
        res2 = svc.classify(imgs, world.class_names, k=5)
        stats = svc.stats()
        inv_tau = svc.inv_tau

    assert res.values.shape == (6, 5) and res.indices.shape == (6, 5)
    assert stats["registry"]["computes"] == 1      # class matrix built once
    assert stats["registry"]["mem_hits"] == 1
    np.testing.assert_array_equal(res.indices, res2.indices)

    cemb = class_embeddings(lambda tx: de.encode_text(cfg, params, tx),
                            tok, world.class_names)
    iemb = de.encode_image(cfg, params,
                           {"image": jnp.asarray(imgs)})
    logits = jnp.asarray(np.asarray(iemb @ cemb.T)) * inv_tau
    order = np.asarray(jnp.argsort(-logits, axis=1, stable=True))[:, :5]
    np.testing.assert_array_equal(res.indices, order)


def test_service_retrieve_and_embed(tmp_path):
    cfg, world, tok, params = _world()
    rng = np.random.default_rng(3)
    imgs = render_images(world, rng.integers(0, 10, 5), rng)
    with ZeroShotService(cfg, params, tok, registry_dir=str(tmp_path),
                         max_delay_ms=1.0) as svc:
        gal = svc.embed_images(imgs)
        assert gal.shape == (5, cfg.embed_dim)
        np.testing.assert_allclose(np.linalg.norm(gal, axis=1), 1.0,
                                   atol=1e-5)
        vals, idx = svc.retrieve(["a photo of a red cat"], gal, k=3)
    assert vals.shape == (1, 3) and idx.shape == (1, 3)
    assert np.all(idx < 5)
    assert np.all(np.diff(vals[0]) <= 1e-7)  # descending


def test_service_eval_consumer(tmp_path):
    """eval.zero_shot.evaluate_with_service: same metric plumbing as
    evaluate_benchmark, served through the subsystem."""
    from repro.eval import evaluate_with_service

    cfg, world, tok, params = _world()
    rng = np.random.default_rng(4)
    cls = rng.integers(0, 10, 20)
    imgs = render_images(world, cls, rng)
    with ZeroShotService(cfg, params, tok, registry_dir=str(tmp_path),
                         max_delay_ms=1.0) as svc:
        out = evaluate_with_service(svc, world.class_names, imgs, cls)
    assert set(out) >= {"top1", "top5", "mean_per_class_recall", "n",
                        "headline", "class_matrix_version"}
    assert 0.0 <= out["top1"] <= out["top5"] <= 1.0
    assert out["n"] == 20 and out["class_matrix_version"] == 1
