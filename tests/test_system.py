"""End-to-end behaviour tests for the BASIC system."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.core.gradaccum import contrastive_step
from repro.data import (Tokenizer, caption_corpus, classification_prompts,
                        contrastive_batch, jft_batch, world_for_tower)
from repro.models import dual_encoder as de
from repro.models import frontends, transformer as tf
from repro.optim import AdaFactorW, apply_updates


def _dual_cfg():
    cfg = get_arch("basic-s")
    return dataclasses.replace(
        cfg, image_tower=smoke_variant(cfg.image_tower),
        text_tower=smoke_variant(cfg.text_tower), embed_dim=32)


def _world_and_tok(cfg, seed=0, n_classes=16):
    rng = np.random.default_rng(seed)
    world = world_for_tower(rng, cfg.image_tower, n_classes=n_classes,
                            noise=0.25)
    tok = Tokenizer.train(caption_corpus(world, rng, 400), vocab_size=500)
    return world, tok, rng


def test_contrastive_training_learns_zero_shot_classification():
    """The paper's headline capability at toy scale: after contrastive
    training, classify fresh images by prompt similarity — accuracy must
    beat chance by a wide margin."""
    cfg = _dual_cfg()
    world, tok, rng = _world_and_tok(cfg)
    params = de.init_params(cfg, jax.random.key(0))
    opt = AdaFactorW()
    opt_state = opt.init(params)

    enc_i = lambda p, im: de.encode_image(cfg, p, im)   # noqa: E731
    enc_t = lambda p, tx: de.encode_text(cfg, p, tx)    # noqa: E731

    @jax.jit
    def step(params, opt_state, batch):
        loss, metrics, grads = contrastive_step(enc_i, enc_t, params, batch, 2)
        updates, opt_state = opt.update(grads, opt_state, params, 2e-3)
        return apply_updates(params, updates), opt_state, loss

    for i in range(60):
        batch, _ = contrastive_batch(world, tok, 32, rng)
        batch = jax.tree.map(jnp.asarray, batch)
        params, opt_state, loss = step(params, opt_state, batch)

    prompts = classification_prompts(world, tok)
    temb = enc_t(params, jax.tree.map(jnp.asarray, prompts))
    test_batch, cls = contrastive_batch(world, tok, 64, rng)
    iemb = enc_i(params, jax.tree.map(jnp.asarray, test_batch["images"]))
    pred = np.asarray(jnp.argmax(iemb @ temb.T, axis=1))
    acc = float(np.mean(pred == cls))
    assert acc > 3.0 / world.n_classes, acc  # >> chance (1/16)


def test_lm_training_reduces_loss():
    cfg = smoke_variant(get_arch("llama3.2-1b"))
    params = tf.init_params(cfg, jax.random.key(0))
    opt = AdaFactorW()
    opt_state = opt.init(params)
    rng = np.random.default_rng(0)
    batch = frontends.synthetic_inputs(cfg, 4, 32, rng)  # fixed batch

    @jax.jit
    def step(params, opt_state):
        def loss_fn(p):
            loss, _ = tf.lm_loss(cfg, p, batch)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = opt.update(grads, opt_state, params, 3e-3)
        return apply_updates(params, updates), opt_state, loss

    losses = []
    for _ in range(25):
        params, opt_state, loss = step(params, opt_state)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_basic_three_phase_recipe_runs():
    """Paper §8: pretrain image tower -> frozen-image contrastive -> joint
    finetune; each phase must run, phase-2 must not move the image tower."""
    cfg = _dual_cfg()
    world, tok, rng = _world_and_tok(cfg, seed=1)
    icfg = cfg.image_tower
    key = jax.random.key(1)

    pre = {"tower": tf.init_params(icfg, key),
           "head": 0.02 * jax.random.normal(key,
                                            (icfg.d_model, world.n_classes))}
    opt = AdaFactorW(weight_decay=0.0)
    st = opt.init(pre)

    @jax.jit
    def p1(pre, st, images, labels):
        def loss_fn(p):
            h = tf.encode(icfg, p["tower"], {"image": images})
            logp = jax.nn.log_softmax(h @ p["head"])
            return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
        loss, g = jax.value_and_grad(loss_fn)(pre)
        up, st = opt.update(g, st, pre, 2e-3)
        return apply_updates(pre, up), st, loss

    for _ in range(10):
        b, _ = jft_batch(world, 16, rng)
        pre, st, l1 = p1(pre, st, jnp.asarray(b["image"]),
                         jnp.asarray(b["labels"]))

    params = de.init_params(cfg, key)
    params["image"]["tower"] = pre["tower"]
    opt2 = AdaFactorW(weight_decay=0.0)
    st2 = opt2.init(params)
    enc_i = lambda p, im: de.encode_image(cfg, p, im)   # noqa: E731
    enc_t = lambda p, tx: de.encode_text(cfg, p, tx)    # noqa: E731

    @jax.jit
    def p2(params, st2, batch):
        loss, _, grads = contrastive_step(enc_i, enc_t, params, batch, 2)
        grads["image"]["tower"] = jax.tree.map(
            jnp.zeros_like, grads["image"]["tower"])
        up, st2 = opt2.update(grads, st2, params, 2e-3)
        return apply_updates(params, up), st2, loss

    before = jax.tree.map(lambda x: x, params["image"]["tower"])
    for _ in range(8):
        batch, _ = contrastive_batch(world, tok, 16, rng)
        params, st2, l2 = p2(params, st2, jax.tree.map(jnp.asarray, batch))
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(before),
            jax.tree_util.tree_leaves_with_path(params["image"]["tower"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(pa))

    @jax.jit
    def p3(params, st2, batch):
        loss, _, grads = contrastive_step(enc_i, enc_t, params, batch, 2)
        up, st2 = opt2.update(grads, st2, params, 5e-4)
        return apply_updates(params, up), st2, loss

    for _ in range(4):
        batch, _ = contrastive_batch(world, tok, 16, rng)
        params, st2, l3 = p3(params, st2, jax.tree.map(jnp.asarray, batch))
    assert np.isfinite(float(l1)) and np.isfinite(float(l2)) \
        and np.isfinite(float(l3))


def test_training_trajectory_invariant_to_microbatch_count():
    """GradAccum with different micro counts yields identical training
    trajectories — the exactness guarantee behind paper §5's comparison."""
    cfg = _dual_cfg()
    world, tok, rng = _world_and_tok(cfg, seed=2)
    key = jax.random.key(2)
    batches = []
    for _ in range(3):
        b, _ = contrastive_batch(world, tok, 16, rng)
        batches.append(jax.tree.map(jnp.asarray, b))

    def run(micro):
        params = de.init_params(cfg, key)
        opt = AdaFactorW(store_m_bf16=False)
        st = opt.init(params)
        enc_i = lambda p, im: de.encode_image(cfg, p, im)   # noqa: E731
        enc_t = lambda p, tx: de.encode_text(cfg, p, tx)    # noqa: E731
        losses = []
        for b in batches:
            loss, _, grads = contrastive_step(enc_i, enc_t, params, b, micro)
            up, st = opt.update(grads, st, params, 1e-3)
            params = apply_updates(params, up)
            losses.append(float(loss))
        return losses

    l1, l4 = run(1), run(4)
    np.testing.assert_allclose(l1, l4, rtol=1e-4)
