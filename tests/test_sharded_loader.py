"""Sharded data subsystem (data/sharded/, DESIGN.md §9): loader layout,
augmentation determinism, resumable state, tokenizer artifact versioning.

The multi-device assertions (shard reassembly on an 8-way mesh, trainer
resume) run in a subprocess via tests/distributed_checks.py sharded_data;
everything here holds on the single tier-1 CPU device.
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data import Tokenizer, make_world
from repro.data.sharded import (ChannelNoise, HorizontalFlip, HostLayout,
                                RandomCrop, ShardedLoader, apply_ops,
                                build_default_tokenizer,
                                default_augmentations, load_tokenizer,
                                save_tokenizer)
from repro.data.sharded.augment import from_names
from repro.data.sharded.loader import LoaderState, aug_rng

_CACHE = {}


def _world_tok():
    if "wt" not in _CACHE:
        _CACHE["wt"] = (make_world(np.random.default_rng(0), n_classes=12),
                        load_tokenizer())
    return _CACHE["wt"]


# ---------------------------------------------------------------------------
# loader layout + determinism
# ---------------------------------------------------------------------------


def test_local_shards_concatenate_to_global_batch():
    """Shard-exactness on the host side: per-host blocks of a 4-host layout
    reassemble bit-exactly to the single-process global materialization."""
    world, tok = _world_tok()
    aug = default_augmentations()
    oracle = ShardedLoader(world, tok, 16, layout=HostLayout(4, 0), seed=9,
                           augment=aug)
    for step in (0, 3):
        want = oracle.global_batch_at(step)
        blocks = [ShardedLoader(world, tok, 16, layout=HostLayout(4, h),
                                seed=9, augment=aug).local_batch_at(step)
                  for h in range(4)]
        np.testing.assert_array_equal(
            np.concatenate([b["images"]["image"] for b in blocks]),
            want["images"]["image"])
        np.testing.assert_array_equal(
            np.concatenate([b["texts"]["tokens"] for b in blocks]),
            want["texts"]["tokens"])


def test_loader_rejects_indivisible_batch():
    world, tok = _world_tok()
    with pytest.raises(ValueError, match="divisible"):
        ShardedLoader(world, tok, 10, layout=HostLayout(4, 0))
    with pytest.raises(ValueError, match="host"):
        HostLayout(2, 2)


def test_augmentation_deterministic_and_effective():
    """Same (seed, host, step) -> bit-identical augmented batch; a clean
    loader at the same key yields the same tokens but different pixels."""
    world, tok = _world_tok()
    aug = default_augmentations()
    a = ShardedLoader(world, tok, 8, seed=4, augment=aug).local_batch_at(2)
    b = ShardedLoader(world, tok, 8, seed=4, augment=aug).local_batch_at(2)
    np.testing.assert_array_equal(a["images"]["image"], b["images"]["image"])
    clean = ShardedLoader(world, tok, 8, seed=4).local_batch_at(2)
    np.testing.assert_array_equal(a["texts"]["tokens"],
                                  clean["texts"]["tokens"])
    assert not np.array_equal(a["images"]["image"], clean["images"]["image"])
    assert a["images"]["image"].shape == clean["images"]["image"].shape


def test_augment_ops_semantics():
    rng = np.random.default_rng(0)
    imgs = rng.standard_normal((4, 8, 8, 3)).astype(np.float32)
    # full-prob flip is an exact mirror
    flipped = HorizontalFlip(prob=1.0)(imgs, np.random.default_rng(1))
    np.testing.assert_array_equal(flipped, imgs[:, :, ::-1, :])
    # zero-pad crop is the identity
    np.testing.assert_array_equal(RandomCrop(pad=0)(imgs,
                                                    np.random.default_rng(1)),
                                  imgs)
    jittered = RandomCrop(pad=2)(imgs, np.random.default_rng(1))
    assert jittered.shape == imgs.shape
    noised = ChannelNoise(scale=0.1)(imgs, np.random.default_rng(1))
    assert noised.shape == imgs.shape and not np.array_equal(noised, imgs)
    # composition is deterministic under a fixed stream
    ops = default_augmentations()
    np.testing.assert_array_equal(apply_ops(ops, imgs, aug_rng(0, 1, 2)),
                                  apply_ops(ops, imgs, aug_rng(0, 1, 2)))
    assert from_names([op.name for op in ops]) == ops
    with pytest.raises(KeyError):
        from_names(["nope"])


# ---------------------------------------------------------------------------
# resumable state
# ---------------------------------------------------------------------------


def test_state_restore_replays_sequence():
    world, tok = _world_tok()
    aug = default_augmentations()
    it = ShardedLoader(world, tok, 8, layout=HostLayout(2, 1), seed=7,
                       augment=aug)
    next(it), next(it)
    st = it.state()
    tail = [next(it) for _ in range(2)]

    fresh = ShardedLoader(world, tok, 8, layout=HostLayout(2, 1), seed=7,
                          augment=aug)
    fresh.restore(LoaderState.from_json(st.to_json()))   # through JSON
    for want in tail:
        np.testing.assert_array_equal(next(fresh)["images"]["image"],
                                      want["images"]["image"])


def test_restore_rejects_mismatched_configuration():
    """Every non-cursor field gates restore — including batch geometry
    and augmentation op PARAMETERS (reprs, not just names), so a resume
    that would replay a different batch sequence cannot pass validation."""
    world, tok = _world_tok()
    it = ShardedLoader(world, tok, 8, seed=7,
                       augment=default_augmentations())
    st = it.state()
    for field, val in [("seed", 8), ("tokenizer_sha", "deadbeef"),
                       ("augment", ("HorizontalFlip(prob=0.5)",)),
                       ("n_hosts", 2), ("global_batch", 16),
                       ("text_len", 32), ("classes_sha", "beef")]:
        with pytest.raises(ValueError, match=field):
            it.restore(dataclasses.replace(st, **{field: val}))
    # same op names, different parameters: still rejected
    other = ShardedLoader(world, tok, 8, seed=7,
                          augment=(RandomCrop(pad=4), HorizontalFlip(),
                                   ChannelNoise()))
    with pytest.raises(ValueError, match="augment"):
        other.restore(st)


def test_stream_advances_cursor_for_mid_stream_checkpoints():
    """A state() snapshot taken after consuming n batches from stream()
    must point at step cursor+n — a mid-stream checkpoint neither replays
    nor skips batches."""
    world, tok = _world_tok()
    it = ShardedLoader(world, tok, 8, seed=3,
                       augment=default_augmentations())
    pf = it.stream(depth=2)
    try:
        for _ in range(3):
            next(pf)
        st = it.state()
        assert st.step == 3
        want = next(pf)
    finally:
        pf.close()
    fresh = ShardedLoader(world, tok, 8, seed=3,
                          augment=default_augmentations())
    fresh.restore(st)
    np.testing.assert_array_equal(next(fresh)["images"]["image"],
                                  want["images"]["image"])


def test_loader_state_persists_through_checkpoint_meta(tmp_path):
    """LoaderState rides checkpoint step dirs as user-meta: save/restore
    through repro.checkpoint round-trips it (and old checkpoints without
    meta read back as None)."""
    from repro import checkpoint as ckpt
    world, tok = _world_tok()
    it = ShardedLoader(world, tok, 8, seed=1,
                       augment=default_augmentations())
    next(it)
    ckpt.save(str(tmp_path), 1, {"w": np.zeros((2,))},
              meta={"loader": it.state().to_json()})
    meta = ckpt.load_meta(str(tmp_path), 1)
    restored = LoaderState.from_json(meta["loader"])
    assert restored == it.state()
    ckpt.save(str(tmp_path), 2, {"w": np.zeros((2,))})
    assert ckpt.load_meta(str(tmp_path), 2) is None


def test_prefetching_stream_matches_direct_iteration():
    world, tok = _world_tok()
    it = ShardedLoader(world, tok, 8, seed=2)
    direct = [it.local_batch_at(s) for s in range(3)]
    pf = ShardedLoader(world, tok, 8, seed=2).stream(depth=2)
    try:
        for want in direct:
            np.testing.assert_array_equal(next(pf)["texts"]["tokens"],
                                          want["texts"]["tokens"])
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# tokenizer artifact
# ---------------------------------------------------------------------------


def test_committed_artifact_loads_and_matches_rebuild():
    """artifacts/tokenizer_v1.json is self-consistent (hash verifies) and
    byte-reproducible from the grammar (the scripts/build_tokenizer.py
    --check invariant)."""
    tok = load_tokenizer("v1")
    assert tok.version == "v1" and tok.vocab_size == 512
    rebuilt = build_default_tokenizer()
    assert rebuilt.content_hash() == tok.content_hash()
    assert rebuilt.pieces == tok.pieces


def test_artifact_rejects_tampering(tmp_path):
    tok = Tokenizer(["aa", "bb"], version="vX")
    path = save_tokenizer(tok, str(tmp_path / "tokenizer_vX.json"))
    loaded = load_tokenizer(path=path)
    assert loaded.pieces == tok.pieces and loaded.version == "vX"

    with open(path) as f:
        payload = json.load(f)
    payload["pieces"].append("zz")
    with open(path, "w") as f:
        json.dump(payload, f)
    with pytest.raises(ValueError, match="hash mismatch"):
        load_tokenizer(path=path)
    with pytest.raises(FileNotFoundError, match="build_tokenizer"):
        load_tokenizer("v999", directory=str(tmp_path))


def test_content_hash_tracks_pieces():
    a, b = Tokenizer(["aa", "bb"]), Tokenizer(["aa", "bb"])
    assert a.content_hash() == b.content_hash()
    assert Tokenizer(["aa", "cc"]).content_hash() != a.content_hash()


def test_registry_fingerprint_includes_tokenizer_hash():
    """ISSUE-5 acceptance: the tokenizer artifact hash appears in the
    class-embedding registry fingerprint, so a retrained vocab invalidates
    cached class matrices by construction."""
    from repro.serving.embed.registry import (checkpoint_fingerprint,
                                              params_fingerprint)
    params = {"w": np.arange(4, dtype=np.float32)}
    tok = load_tokenizer("v1")
    tag = checkpoint_fingerprint(params, tok)
    assert tok.content_hash() in tag
    assert tag.startswith(params_fingerprint(params))
    other = Tokenizer(["aa"])
    assert checkpoint_fingerprint(params, other) != tag
    # no tokenizer -> plain params fingerprint (legacy callers)
    assert checkpoint_fingerprint(params) == params_fingerprint(params)


# ---------------------------------------------------------------------------
# multi-device acceptance (subprocess: 8 simulated host devices)
# ---------------------------------------------------------------------------


def test_two_host_reassembly_and_trainer_resume():
    """Spawns tests/distributed_checks.py sharded_data: two-host bit-exact
    reassembly, block->shard device placement on an 8-way mesh, and the
    checkpoint-resumed contrastive trainer replaying the exact batch
    sequence."""
    checks = os.path.join(os.path.dirname(__file__), "distributed_checks.py")
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    proc = subprocess.run([sys.executable, checks, "sharded_data"],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, (
        f"sharded_data failed\n--- stdout ---\n{proc.stdout}\n"
        f"--- stderr ---\n{proc.stderr[-4000:]}")
    assert "PASS sharded_data" in proc.stdout
