"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.contrastive_loss import ops as cl_ops
from repro.kernels.contrastive_loss import ref as cl_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref


def _unit(key, b, d, dtype):
    z = jax.random.normal(key, (b, d), jnp.float32)
    z = z / jnp.linalg.norm(z, axis=1, keepdims=True)
    return z.astype(dtype)


# ---------------------------------------------------------------------------
# contrastive loss kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,d", [(16, 8), (32, 64), (64, 48), (128, 32),
                                 (24, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_contrastive_kernel_loss_sweep(b, d, dtype):
    k1, k2 = jax.random.split(jax.random.key(b * d))
    x, y = _unit(k1, b, d, dtype), _unit(k2, b, d, dtype)
    lt = jnp.asarray(-1.0)
    ref = cl_ref.loss_ref(x, y, lt)
    got = cl_ops.fused_contrastive_loss(x, y, lt, True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(float(got), float(ref), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,d", [(32, 16), (64, 32)])
def test_contrastive_kernel_grads_sweep(b, d):
    k1, k2 = jax.random.split(jax.random.key(7 * b + d))
    x, y = _unit(k1, b, d, jnp.float32), _unit(k2, b, d, jnp.float32)
    lt = jnp.asarray(-0.5)
    gx_r, gy_r, gt_r = cl_ref.contrastive_grads_ref(x, y, lt)
    gx, gy, gt = jax.grad(
        lambda x, y, t: cl_ops.fused_contrastive_loss(x, y, t, True),
        argnums=(0, 1, 2))(x, y, lt)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(gy_r), atol=1e-6)
    np.testing.assert_allclose(float(gt), float(gt_r), rtol=1e-4, atol=1e-6)


def test_contrastive_kernel_grad_matches_autodiff_of_ref():
    """Cross-check: kernel VJP == jax.grad of the materializing oracle."""
    k1, k2 = jax.random.split(jax.random.key(0))
    x, y = _unit(k1, 48, 24, jnp.float32), _unit(k2, 48, 24, jnp.float32)
    lt = jnp.asarray(-1.2)
    g_ref = jax.grad(cl_ref.loss_ref, argnums=(0, 1, 2))(x, y, lt)
    g_k = jax.grad(
        lambda x, y, t: cl_ops.fused_contrastive_loss(x, y, t, True),
        argnums=(0, 1, 2))(x, y, lt)
    for a, b_ in zip(g_ref, g_k):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=5e-6)


def test_contrastive_kernel_extreme_temperature_stable():
    """Low tau -> large logits; the online LSE must stay finite."""
    k1, k2 = jax.random.split(jax.random.key(1))
    x, y = _unit(k1, 32, 16, jnp.float32), _unit(k2, 32, 16, jnp.float32)
    lt = jnp.asarray(-4.6)  # tau ~ 0.01 -> logits ~ 100
    loss = cl_ops.fused_contrastive_loss(x, y, lt, True)
    assert np.isfinite(float(loss))
    np.testing.assert_allclose(float(loss),
                               float(cl_ref.loss_ref(x, y, lt)), rtol=1e-5)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,kv,s,d,causal,window", [
    (2, 4, 2, 128, 64, True, None),
    (1, 4, 4, 256, 32, True, 64),
    (2, 2, 2, 128, 64, False, None),
    (1, 8, 2, 64, 128, True, None),
    (1, 2, 1, 192, 32, True, 100),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, kv, s, d, causal, window, dtype):
    ks = jax.random.split(jax.random.key(b + h + s), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), jnp.float32).astype(dtype)
    ref = fa_ref.attention_ref(q, k, v, causal=causal, window=window)
    got = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_matches_model_sdpa():
    """Kernel agrees with the model's naive attention path end-to-end."""
    from repro.models.attention import _sdpa
    ks = jax.random.split(jax.random.key(9), 3)
    b, h, kvh, s, d = 2, 4, 2, 64, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kvh, d))
    v = jax.random.normal(ks[2], (b, s, kvh, d))
    mask = jnp.where(jnp.tril(jnp.ones((s, s), bool)), 0.0, -1e30)
    ref = _sdpa(q, k, v, mask)
    got = fa_ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, block_q=32, block_k=32,
        interpret=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# SSD scan kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,l,h,p,n,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 64, 2, 64, 32, 64),
    (1, 256, 8, 16, 8, 128),
    (2, 96, 3, 32, 16, 32),
])
def test_ssd_kernel_vs_sequential_ref(b, l, h, p, n, chunk):
    ks = jax.random.split(jax.random.key(l + h), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, l, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, l, n)) * 0.5
    D = jnp.ones((h,))
    y_ref, _ = ssd_ref.ssd_ref(x, dt, A, Bm, Cm, D)
    y_k = ssd_ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    scale = float(jnp.max(jnp.abs(y_ref))) + 1e-6
    np.testing.assert_allclose(np.asarray(y_k) / scale,
                               np.asarray(y_ref) / scale, atol=2e-5)


def test_ssd_kernel_matches_model_chunked():
    """Kernel output == models.ssm.ssd_chunked (the jnp path the model uses)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.key(11), 5)
    b, l, h, p, n = 1, 128, 4, 32, 16
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, l, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, l, n)) * 0.5
    y_m, _ = ssd_chunked(x, dt, A, Bm, Cm, 32)
    y_k = ssd_ops.ssd_scan(x, dt, A, Bm, Cm, None, chunk=32, interpret=True)
    scale = float(jnp.max(jnp.abs(y_m))) + 1e-6
    np.testing.assert_allclose(np.asarray(y_k) / scale,
                               np.asarray(y_m) / scale, atol=2e-5)


def test_ssd_kernel_decay_extremes():
    """Very fast decay (large dt*|A|) must not overflow the chunk exps."""
    ks = jax.random.split(jax.random.key(12), 5)
    b, l, h, p, n = 1, 64, 2, 16, 8
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jnp.full((b, l, h), 3.0)
    A = jnp.asarray([-5.0, -0.001])
    Bm = jax.random.normal(ks[3], (b, l, n)) * 0.5
    Cm = jax.random.normal(ks[4], (b, l, n)) * 0.5
    y_ref, _ = ssd_ref.ssd_ref(x, dt, A, Bm, Cm)
    y_k = ssd_ops.ssd_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    assert np.all(np.isfinite(np.asarray(y_k)))
    scale = float(jnp.max(jnp.abs(y_ref))) + 1e-6
    np.testing.assert_allclose(np.asarray(y_k) / scale,
                               np.asarray(y_ref) / scale, atol=5e-5)
