"""Sliding-window ring cache: prefill-built ring == step-by-step decode,
including prompts LONGER than the window (the long_500k mechanism)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.models import transformer as tf


def _cfg(window):
    base = smoke_variant(get_arch("llama3.2-1b"))
    return dataclasses.replace(base, sliding_window=window, n_layers=2)


def test_prefill_ring_matches_decode_built_ring():
    """Build the ring two ways: (a) prefill over the full prompt, (b) decode
    token-by-token from an empty ring. The next-token logits must agree."""
    window = 8
    cfg = _cfg(window)
    params = tf.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    plen = 20  # > window: ring has wrapped
    toks = rng.integers(4, cfg.vocab, (2, plen + 1)).astype(np.int32)

    # (a) prefill path: ring cache of size `window`
    _, caches_a = tf.prefill(cfg, params, {"tokens": jnp.asarray(toks[:, :plen])},
                             dtype=jnp.float32, collect_cache_len=window)
    la, _ = tf.decode_step(cfg, params, jnp.asarray(toks[:, plen:plen + 1]),
                           jnp.int32(plen), caches_a, dtype=jnp.float32)

    # (b) decode path from scratch
    caches_b = tf.init_caches(cfg, 2, window, dtype=jnp.float32)
    lb = None
    for t in range(plen + 1):
        lb, caches_b = tf.decode_step(cfg, params,
                                      jnp.asarray(toks[:, t:t + 1]),
                                      jnp.int32(t), caches_b,
                                      dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=1e-4, atol=1e-5)


def test_ring_decode_only_attends_within_receptive_field():
    """After the ring wraps, logits must be independent of tokens beyond the
    L-layer receptive field (L x window tokens back)."""
    window = 8
    cfg = _cfg(window)  # 2 layers -> receptive field 16
    params = tf.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(1)
    plen = 26
    rf = cfg.n_layers * window
    n_changed = plen + 1 - rf - 2  # strictly outside the receptive field
    toks = rng.integers(4, cfg.vocab, (1, plen + 1)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, :n_changed] = rng.integers(4, cfg.vocab, n_changed)

    def final_logits(t):
        _, caches = tf.prefill(cfg, params, {"tokens": jnp.asarray(t[:, :plen])},
                               dtype=jnp.float32, collect_cache_len=window)
        l, _ = tf.decode_step(cfg, params, jnp.asarray(t[:, plen:plen + 1]),
                              jnp.int32(plen), caches, dtype=jnp.float32)
        return np.asarray(l)

    np.testing.assert_allclose(final_logits(toks), final_logits(toks2),
                               rtol=1e-4, atol=1e-5)


def test_long_context_engine_with_window():
    """Generation far past the window keeps working (ring keeps wrapping)."""
    from repro.serving import Engine
    cfg = _cfg(8)
    params = tf.init_params(cfg, jax.random.key(2))
    eng = Engine(cfg, params, cache_len=64)
    rng = np.random.default_rng(2)
    prompts = rng.integers(4, cfg.vocab, (1, 6)).astype(np.int32)
    out = eng.generate(prompts, 30, temperature=0.0)
    assert out.shape == (1, 30)
    assert np.all(out < cfg.vocab)
