"""Planet-scale retrieval (DESIGN.md §13): the top-k-of-top-k combine, the
two-stage coarse→fine path, the registry's centroid-index cache, and the
service-level retrieval modes.

The multi-device sharded assertions (ties/duplicates straddling shard
boundaries vs the stable-argsort oracle, pod×data meshes, service-level
sharded parity) live in tests/distributed_checks.py ``retrieval`` and run
in a subprocess with 8 simulated devices (jax pins the device count at
first init; this process must keep seeing the single real CPU device,
tests/conftest.py). Here we spawn them and cover everything that doesn't
need a multi-device mesh in-process.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.similarity_topk import ops as topk_ops
from repro.kernels.similarity_topk import ref as topk_ref
from repro.kernels.similarity_topk.kernel import IDX_PAD, NEG
from repro.serving import retrieval as rtv

_CHECKS = os.path.join(os.path.dirname(__file__), "distributed_checks.py")


def _unit(key, shape):
    z = jax.random.normal(key, shape, jnp.float32)
    return z / jnp.linalg.norm(z, axis=-1, keepdims=True)


def test_sharded_retrieval_multi_device():
    """The full §13.1 acceptance suite on 4-, 8- and 2x4-device meshes
    (subprocess: simulated host devices)."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    proc = subprocess.run([sys.executable, _CHECKS, "retrieval"],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, (
        f"distributed_checks.py retrieval failed\n--- stdout ---\n"
        f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    assert "PASS retrieval" in proc.stdout


# ---------------------------------------------------------------------------
# merge_topk: the combine the sharded path rests on
# ---------------------------------------------------------------------------


def test_merge_topk_matches_stable_argsort():
    """Random pools: merge_topk == descending stable sort (ties to the
    lower id) of the same candidates."""
    rng = np.random.default_rng(0)
    v = rng.integers(0, 9, (16, 40)).astype(np.float32)  # many exact ties
    i = np.argsort(rng.random((16, 40)), axis=1).astype(np.int32)  # unique
    got_v, got_i = topk_ops.merge_topk(jnp.asarray(v), jnp.asarray(i), 6)
    # oracle: sort by (-value, id)
    order = np.lexsort((i, -v), axis=1)[:, :6]
    np.testing.assert_array_equal(np.asarray(got_v),
                                  np.take_along_axis(v, order, axis=1))
    np.testing.assert_array_equal(np.asarray(got_i),
                                  np.take_along_axis(i, order, axis=1))


def test_merge_topk_order_independent():
    """Column permutation of the candidate pool cannot change the result —
    the property that makes merging per-shard top-ks exact."""
    rng = np.random.default_rng(1)
    v = rng.integers(0, 5, (8, 24)).astype(np.float32)
    i = np.argsort(rng.random((8, 24)), axis=1).astype(np.int32)
    base_v, base_i = topk_ops.merge_topk(jnp.asarray(v), jnp.asarray(i), 5)
    perm = rng.permutation(24)
    got_v, got_i = topk_ops.merge_topk(jnp.asarray(v[:, perm]),
                                       jnp.asarray(i[:, perm]), 5)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(base_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(base_i))


def test_merge_topk_ignores_pad_slots():
    """NEG/IDX_PAD slots (dead shard tails) never displace real
    candidates."""
    v = np.asarray([[3.0, NEG, 1.0, NEG]], np.float32)
    i = np.asarray([[7, IDX_PAD, 2, IDX_PAD]], np.int32)
    got_v, got_i = topk_ops.merge_topk(jnp.asarray(v), jnp.asarray(i), 2)
    np.testing.assert_array_equal(np.asarray(got_i), [[7, 2]])
    np.testing.assert_array_equal(np.asarray(got_v), [[3.0, 1.0]])


def test_merge_topk_rejects_narrow_pool():
    with pytest.raises(ValueError, match="narrower"):
        topk_ops.merge_topk(jnp.zeros((2, 3)), jnp.zeros((2, 3), jnp.int32),
                            4)


# ---------------------------------------------------------------------------
# n_valid masking (the traced shard-tail mask)
# ---------------------------------------------------------------------------


def test_similarity_topk_n_valid_masks_tail():
    """A traced n_valid < n must reproduce the kernel's answer on the
    truncated matrix — including when the tail rows would otherwise win."""
    x = _unit(jax.random.key(0), (5, 16))
    c = np.array(_unit(jax.random.key(1), (96, 16)))
    c[80:] = np.asarray(x[0])       # poison: the masked tail aligns with x0
    c = jnp.asarray(c)
    want_v, want_i = topk_ops.similarity_topk(x, c[:80], 4, interpret=True)
    got_v, got_i = topk_ops.similarity_topk(
        x, c, 4, n_valid=jnp.asarray(80, jnp.int32), interpret=True)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


def test_similarity_topk_n_valid_zero_emits_sentinels():
    """n_valid=0 (a fully dead shard) yields NEG values — the combine
    retires them by value, so they can never alias real rows."""
    x = _unit(jax.random.key(0), (3, 8))
    c = _unit(jax.random.key(1), (32, 8))
    v, i = topk_ops.similarity_topk(x, c, 2,
                                    n_valid=jnp.asarray(0, jnp.int32),
                                    interpret=True)
    assert np.all(np.asarray(v) <= NEG / 2)


# ---------------------------------------------------------------------------
# sharded entry points on the single-device tier-1 host
# ---------------------------------------------------------------------------


def test_sharded_single_device_falls_back_to_fused():
    """A 1-extent data mesh degenerates to the single-device kernel —
    bit-identical, no shard_map in the way."""
    x = _unit(jax.random.key(0), (6, 32))
    c = _unit(jax.random.key(1), (300, 32))
    want_v, want_i = topk_ops.similarity_topk(x, c, 5, interpret=True)
    sm = rtv.shard_matrix(c, rtv.default_data_mesh(1))
    got_v, got_i = rtv.sharded_similarity_topk(x, sm, 5, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    assert sm.n_shards == 1 and sm.n == 300


def test_shard_matrix_pads_to_topk_floor():
    """n_local never drops below MAX_K, so any legal k fits one shard."""
    sm = rtv.shard_matrix(_unit(jax.random.key(0), (10, 8)),
                          rtv.default_data_mesh(1))
    assert sm.n_local >= topk_ops.MAX_K
    assert sm.array.shape[0] == sm.n_shards * sm.n_local


def test_shard_winner_shares_sums_to_one():
    sm = rtv.shard_matrix(_unit(jax.random.key(0), (128, 8)),
                          rtv.default_data_mesh(1))
    shares = rtv.shard_winner_shares(np.asarray([[0, 1], [2, 3]]), sm)
    assert shares.shape == (1,)
    np.testing.assert_allclose(shares.sum(), 1.0)


# ---------------------------------------------------------------------------
# two-stage coarse→fine
# ---------------------------------------------------------------------------


def _clustered(n, d, p, seed, sigma=0.2):
    rng = np.random.default_rng(seed)
    cent = rng.standard_normal((p, d)).astype(np.float32)
    cent /= np.linalg.norm(cent, axis=1, keepdims=True)
    rows = cent[rng.integers(0, p, n)] + sigma * rng.standard_normal(
        (n, d)).astype(np.float32)
    return rows / np.linalg.norm(rows, axis=1, keepdims=True)


def test_twostage_nprobe_all_is_exact():
    """The exactness escape hatch: nprobe='all' (and >= n_blocks, and the
    default None) reproduce the stage-A fused answer bit-for-bit."""
    q = np.asarray(_unit(jax.random.key(0), (9, 24)))
    m = _clustered(800, 24, 12, seed=3)
    index = rtv.build_centroid_index(m, n_blocks=12)
    want_v, want_i = topk_ops.similarity_topk(
        jnp.asarray(q), jnp.asarray(m), 6, interpret=True)
    for nprobe in ("all", None, 12, 99):
        got_v, got_i, info = rtv.two_stage_topk(q, m, index, 6,
                                                nprobe=nprobe,
                                                interpret=True)
        np.testing.assert_array_equal(got_i, np.asarray(want_i))
        np.testing.assert_array_equal(got_v, np.asarray(want_v))
        assert info["prune_ratio"] == 1.0


def test_twostage_recall_monotone_in_nprobe():
    """More probes → (weakly) better recall, less pruning; clustered data
    reaches recall 1.0 well before nprobe=all."""
    q = _clustered(8, 16, 10, seed=7, sigma=0.1)
    m = _clustered(2000, 16, 10, seed=7, sigma=0.1)
    index = rtv.build_centroid_index(m, n_blocks=10)
    _, want_i = topk_ops.similarity_topk(
        jnp.asarray(q), jnp.asarray(m), 5, interpret=True)
    want_sets = [set(r) for r in np.asarray(want_i)]
    prev_recall, prev_prune = -1.0, -1.0
    for nprobe in (1, 3, 10):
        _, got_i, info = rtv.two_stage_topk(q, m, index, 5, nprobe=nprobe,
                                            interpret=True)
        recall = np.mean([len(set(g) & w) / 5
                          for g, w in zip(got_i, want_sets)])
        assert recall >= prev_recall
        assert info["prune_ratio"] >= prev_prune
        prev_recall, prev_prune = recall, info["prune_ratio"]
    assert prev_recall == 1.0       # nprobe=n_blocks is exact


def test_twostage_expands_blocks_when_starved():
    """nprobe so small the probed blocks hold < k rows: the survivor set
    grows (best coarse score first) until >= k candidates exist."""
    rng = np.random.default_rng(0)
    m = np.asarray(_unit(jax.random.key(0), (60, 8)))
    # highly skewed index: force tiny blocks by building many of them
    index = rtv.build_centroid_index(m, n_blocks=30)
    q = np.asarray(_unit(jax.random.key(1), (2, 8)))
    k = 20                          # >> any single block
    vals, gidx, info = rtv.two_stage_topk(q, m, index, k, nprobe=1,
                                          interpret=True)
    assert info["n_candidates"] >= k
    assert gidx.shape == (2, k)
    assert len({int(i) for i in gidx[0]}) == k      # no duplicate winners


def test_twostage_gather_callback_matches_matrix():
    """A gather callback (streamed-gallery storage model) must agree with
    the materialized-matrix path."""
    q = np.asarray(_unit(jax.random.key(0), (4, 16)))
    m = _clustered(500, 16, 8, seed=11)
    index = rtv.build_centroid_index(m, n_blocks=8)
    v1, i1, _ = rtv.two_stage_topk(q, m, index, 5, nprobe=3,
                                   interpret=True)
    v2, i2, _ = rtv.two_stage_topk(q, lambda ids: m[ids], index, 5,
                                   nprobe=3, interpret=True)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(v1, v2)


def test_twostage_validates_k_and_nprobe():
    m = _clustered(100, 8, 4, seed=0)
    index = rtv.build_centroid_index(m, n_blocks=4)
    q = np.asarray(_unit(jax.random.key(0), (2, 8)))
    with pytest.raises(ValueError, match="k="):
        rtv.two_stage_topk(q, m, index, 0, interpret=True)
    with pytest.raises(ValueError, match="nprobe"):
        rtv.two_stage_topk(q, m, index, 3, nprobe=0, interpret=True)


def test_centroid_index_build_is_deterministic_and_partitions():
    m = _clustered(300, 16, 6, seed=5)
    a = rtv.build_centroid_index(m, n_blocks=6)
    b = rtv.build_centroid_index(m, n_blocks=6)
    np.testing.assert_array_equal(a.centroids, b.centroids)
    np.testing.assert_array_equal(a.members, b.members)
    # members form a partition of [0, n)
    all_ids = np.sort(np.concatenate(
        [a.block_members(p) for p in range(a.n_blocks)]))
    np.testing.assert_array_equal(all_ids, np.arange(300))
    # per-block member lists ascend (the global-id tie-break invariant)
    for p in range(a.n_blocks):
        mem = a.block_members(p)
        assert np.all(np.diff(mem) > 0) or len(mem) <= 1


def test_centroid_index_save_load_roundtrip(tmp_path):
    m = _clustered(200, 8, 5, seed=9)
    idx = rtv.build_centroid_index(m, n_blocks=5)
    path = str(tmp_path / "index.npz")
    idx.save(path)
    loaded = rtv.CentroidIndex.load(path)
    np.testing.assert_array_equal(loaded.centroids, idx.centroids)
    np.testing.assert_array_equal(loaded.members, idx.members)
    np.testing.assert_array_equal(loaded.counts, idx.counts)
    assert loaded.n == idx.n


# ---------------------------------------------------------------------------
# registry: centroid-index caching + invalidation by construction
# ---------------------------------------------------------------------------


def _fake_registry(tmp_path, calls):
    from repro.serving.embed.registry import ClassEmbeddingRegistry

    def compute(names, templates):
        calls.append(names)
        rng = np.random.default_rng(len(names))
        m = rng.standard_normal((len(names), 16)).astype(np.float32)
        return m / np.linalg.norm(m, axis=1, keepdims=True)

    return ClassEmbeddingRegistry(compute, cache_dir=str(tmp_path))


def test_registry_centroid_index_cached_per_version(tmp_path):
    calls = []
    reg = _fake_registry(tmp_path, calls)
    names = tuple(f"c{i}" for i in range(50))
    cm = reg.get(names, ("t {} {}",), "ckpt-a", embed_dim=16)
    i1 = reg.get_centroid_index(cm, n_blocks=5)
    i2 = reg.get_centroid_index(cm, n_blocks=5)
    assert i1 is i2                               # memoized
    assert reg.stats["index_builds"] == 1
    assert reg.stats["index_hits"] == 1
    # a second registry over the same cache dir loads from disk, not build
    reg2 = _fake_registry(tmp_path, [])
    cm2 = reg2.get(names, ("t {} {}",), "ckpt-a", embed_dim=16)
    i3 = reg2.get_centroid_index(cm2, n_blocks=5)
    assert reg2.stats["index_builds"] == 0
    np.testing.assert_array_equal(i3.members, i1.members)


def test_registry_centroid_index_invalidated_by_refresh(tmp_path):
    """refresh() bumps the matrix version → the old index is never served
    for the new artifact (invalidation by construction)."""
    calls = []
    reg = _fake_registry(tmp_path, calls)
    names = tuple(f"c{i}" for i in range(40))
    cm1 = reg.get(names, ("t {} {}",), "ckpt-a", embed_dim=16)
    reg.get_centroid_index(cm1, n_blocks=4)
    cm2 = reg.refresh(names, ("t {} {}",), "ckpt-a")
    assert cm2.version == cm1.version + 1
    reg.get_centroid_index(cm2, n_blocks=4)
    assert reg.stats["index_builds"] == 2         # no stale reuse
    # different checkpoint tag → different key → separate index
    cm3 = reg.get(names, ("t {} {}",), "ckpt-b", embed_dim=16)
    reg.get_centroid_index(cm3, n_blocks=4)
    assert reg.stats["index_builds"] == 3


# ---------------------------------------------------------------------------
# service-level: modes, gallery handle, k validation (single device)
# ---------------------------------------------------------------------------

_CACHE = {}


def _service_world():
    if "w" not in _CACHE:
        from repro.configs import get_arch, smoke_variant
        from repro.data import Tokenizer, caption_corpus, world_for_tower
        from repro.models import dual_encoder as de

        cfg = get_arch("basic-s")
        cfg = dataclasses.replace(
            cfg, image_tower=smoke_variant(cfg.image_tower),
            text_tower=smoke_variant(cfg.text_tower), embed_dim=32)
        rng = np.random.default_rng(0)
        world = world_for_tower(rng, cfg.image_tower, n_classes=10,
                                noise=0.2)
        tok = Tokenizer.train(caption_corpus(world, rng, 300),
                              vocab_size=400)
        params = de.init_params(cfg, jax.random.key(0))
        _CACHE["w"] = (cfg, world, tok, params)
    return _CACHE["w"]


def test_service_twostage_exact_matches_fused(tmp_path):
    """retrieval='twostage' with the default nprobe (None ≡ all) classifies
    identically to 'fused', and the index is built exactly once."""
    from repro.data.synthetic import render_images
    from repro.serving import ZeroShotService

    cfg, world, tok, params = _service_world()
    rng = np.random.default_rng(2)
    imgs = render_images(world, rng.integers(0, 10, 6), rng)
    with ZeroShotService(cfg, params, tok, max_delay_ms=1.0,
                         registry_dir=str(tmp_path)) as svc:
        want = svc.classify(imgs, world.class_names, k=5)
    with ZeroShotService(cfg, params, tok, max_delay_ms=1.0,
                         registry_dir=str(tmp_path),
                         retrieval="twostage", index_blocks=4) as svc:
        got = svc.classify(imgs, world.class_names, k=5)
        got2 = svc.classify(imgs, world.class_names, k=5)
        stats = svc.stats()
    np.testing.assert_array_equal(got.indices, want.indices)
    np.testing.assert_array_equal(got.values, want.values)
    np.testing.assert_array_equal(got2.indices, want.indices)
    assert stats["registry"]["index_builds"] == 1
    assert stats["registry"]["index_hits"] == 1
    hists = stats["metrics"]["histograms"]
    assert any(k.startswith("serve/retrieval_prune_ratio") for k in hists)
    assert any(k.startswith("serve/retrieval_latency_s") for k in hists)


def test_service_gallery_handle_uploads_once(tmp_path):
    from repro.data.synthetic import render_images
    from repro.serving import ZeroShotService

    cfg, world, tok, params = _service_world()
    rng = np.random.default_rng(3)
    imgs = render_images(world, rng.integers(0, 10, 5), rng)
    with ZeroShotService(cfg, params, tok, max_delay_ms=1.0) as svc:
        gal = svc.embed_images(imgs)
        handle = svc.prepare_gallery(gal)
        v1, i1 = svc.retrieve(["a photo of a cat"], handle, k=3)
        v2, i2 = svc.retrieve(["a photo of a cat"], handle, k=3)
        # raw-array path: same array object → memoized, still one upload
        v3, _ = svc.retrieve(["a photo of a cat"], gal, k=3)
        v4, _ = svc.retrieve(["a photo of a cat"], gal, k=3)
        snap = svc.metrics.snapshot()
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_allclose(v1, v3, atol=1e-6)
    assert snap["counters"]["serve/gallery_uploads"] == 2
    assert snap["counters"]["serve/gallery_memo_hits"] == 1


def test_service_k_validation_and_clamp(tmp_path):
    from repro.data.synthetic import render_images
    from repro.serving import ZeroShotService

    cfg, world, tok, params = _service_world()
    rng = np.random.default_rng(4)
    imgs = render_images(world, rng.integers(0, 10, 4), rng)
    with ZeroShotService(cfg, params, tok, max_delay_ms=1.0) as svc:
        gal = svc.embed_images(imgs)
        with pytest.raises(ValueError, match="k=0"):
            svc.classify(imgs, world.class_names, k=0)
        with pytest.raises(ValueError, match="k=-2"):
            svc.retrieve(["a photo"], gal, k=-2)
        # k > n clamps (old silent-accept of k<=0 is gone; clamping stays)
        res = svc.classify(imgs, world.class_names, k=999)
        assert res.indices.shape == (4, 10)
        vals, idx = svc.retrieve(["a photo"], gal, k=999)
        assert idx.shape == (1, 4)


def test_service_rejects_unknown_mode():
    from repro.serving import ZeroShotService

    cfg, world, tok, params = _service_world()
    with pytest.raises(ValueError, match="retrieval="):
        ZeroShotService(cfg, params, tok, retrieval="ivf")


def test_service_rejects_mode_mismatched_handle(tmp_path):
    from repro.data.synthetic import render_images
    from repro.serving import ZeroShotService

    cfg, world, tok, params = _service_world()
    rng = np.random.default_rng(5)
    imgs = render_images(world, rng.integers(0, 10, 4), rng)
    with ZeroShotService(cfg, params, tok, max_delay_ms=1.0) as svc:
        gal = svc.embed_images(imgs)
        fused_handle = svc.prepare_gallery(gal)
    with ZeroShotService(cfg, params, tok, max_delay_ms=1.0,
                         retrieval="twostage", index_blocks=2) as svc:
        with pytest.raises(ValueError, match="prepared for mode"):
            svc.retrieve(["a photo"], fused_handle, k=2)
