"""Distributed trainer: loss decreases; checkpoint resume continues exactly;
every step streams a schema-valid runlog record with the full time
breakdown, and the trace export is Perfetto-shaped (DESIGN.md §11).
With --health armed, an injected NaN batch is skipped in-jit, flight-
recorded, and served live over /metrics and /healthz (§14)."""
import json
import os
import sys
import types
import urllib.request

import jax.numpy as jnp
import numpy as np

from repro.launch.train_distributed import train
from repro.obs import health as obs_health
from repro.obs import runlog as rl
from repro.obs import trace as obs_trace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "scripts"))

import check_runlog  # noqa: E402


def _args(**kw):
    base = dict(arch="llama3.2-1b", smoke=True, steps=12, batch=4, seq=32,
                lr=3e-3, seed=0, sharding="basic_ws", remat="basic",
                model_parallel=1, log_every=100, ckpt_dir=None, ckpt_every=0,
                stop_after=None)
    base.update(kw)
    return types.SimpleNamespace(**base)


def test_trainer_reduces_loss():
    # uniform-random tokens have an entropy floor of ln(vocab) ~ 6.24; from
    # a ~6.6 init the trainer must close most of the gap to the floor. The
    # AdaFactorW+warmup-cosine run transits a loss BUMP (up to ~7.0 around
    # steps 10-30, second-moment estimates settling) before descending, so
    # the horizon must extend past it: at 40 steps last-5 mean still sits
    # above first-5, at 80 the descent is unambiguous (~6.58 -> ~6.40).
    losses = train(_args(steps=80, lr=5e-3))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, \
        (np.mean(losses[:5]), np.mean(losses[-5:]))
    assert all(np.isfinite(losses))


def test_checkpoint_resume_is_exact(tmp_path):
    """train 12 straight == train 6, checkpoint, resume 6 more (bitwise-close
    — the data stream is keyed by absolute step, so resume sees the same
    batches)."""
    full = train(_args(steps=12))
    d = str(tmp_path / "ck")
    # stop_after keeps the LR-schedule horizon (steps=12) identical
    train(_args(steps=12, stop_after=6, ckpt_dir=d))
    resumed = train(_args(steps=12, ckpt_dir=d))
    np.testing.assert_allclose(resumed, full[6:], rtol=1e-4)


def test_smoke_run_streams_runlog_and_trace(tmp_path, capsys):
    """A --run-dir smoke run emits one schema-valid step record per step
    (full data-wait/device-step/ckpt-stall breakdown), checkpoint events,
    and a Chrome-trace JSON whose spans carry the required keys."""
    rd = str(tmp_path / "run")
    train(_args(steps=6, ckpt_dir=str(tmp_path / "ck"), ckpt_every=3,
                run_dir=rd, quiet=True, log_every=2))
    # quiet mode: telemetry streams, stdout stays silent
    assert "step " not in capsys.readouterr().out

    path = os.path.join(rd, "runlog.jsonl")
    assert check_runlog.check_file(path) == []       # the schema gate
    records = rl.read_runlog(path)
    steps = [r for r in records if r["kind"] == "step"]
    assert [r["step"] for r in steps] == list(range(6))
    for r in steps:
        for key in rl.STEP_BREAKDOWN_KEYS + ("step_s", "loss",
                                             "examples_per_sec",
                                             "grad_norm"):
            assert isinstance(r[key], (int, float)), (key, r)
        assert r["step_s"] >= r["data_wait_s"] + r["device_step_s"]
    saves = [r for r in records if r["kind"] == "checkpoint"]
    assert {r["event"] for r in saves} >= {"save", "final_save"}
    # the final registry snapshot rode along
    final = [r for r in records if r["kind"] == "metrics"]
    assert final and final[-1]["counters"]["ckpt/saves"] >= 2

    doc = json.load(open(os.path.join(rd, "trace.json")))
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {"data_wait", "device_step", "ckpt_stall"} <= \
        {e["name"] for e in spans}
    for ev in doc["traceEvents"]:
        for key in obs_trace.REQUIRED_EVENT_KEYS:
            assert key in ev, (key, ev)


def test_resume_appends_to_runlog_with_marker(tmp_path):
    """A --resume relaunch APPENDS to the same runlog — one run_start,
    one resume marker, monotone step records across the boundary."""
    d = str(tmp_path / "ck")
    train(_args(steps=12, stop_after=6, ckpt_dir=d, quiet=True))
    train(_args(steps=12, ckpt_dir=d, quiet=True))   # run_dir defaults here
    path = os.path.join(d, "runlog.jsonl")
    assert check_runlog.check_file(path) == []
    records = rl.read_runlog(path)
    kinds = [r["kind"] for r in records]
    assert kinds.count("run_start") == 1 and kinds.count("resume") == 1
    assert next(r for r in records
                if r["kind"] == "resume")["resumed_from"] == 6
    assert [r["step"] for r in records
            if r["kind"] == "step"] == list(range(12))


def test_health_run_survives_injected_nan(tmp_path):
    """The §14 acceptance path end to end: a --health --metrics-port run
    with a NaN batch injected at step 2 must (a) skip the poisoned update
    in-jit so every later loss is finite, (b) write a schema-valid
    ``anomaly`` runlog record and mark the step ``skipped``, (c) dump the
    flight recorder, and (d) serve live /metrics and /healthz mid-run —
    staying healthy, because one contained incident is not an outage."""
    rd = str(tmp_path / "run")
    args = types.SimpleNamespace(
        arch="basic-s", objective="auto", smoke=True, steps=8, batch=8,
        seq=16, lr=3e-4, seed=0, sharding="basic_ws", remat="basic",
        model_parallel=1, log_every=100, ckpt_dir=None, ckpt_every=0,
        stop_after=None, num_micro=2, loss="local", quiet=True,
        run_dir=rd, health=True, metrics_port=0)
    probes = {}

    def hook(step, batch):
        if step == 2:                 # poison the whole image batch
            imgs = dict(batch["images"])
            imgs["image"] = batch["images"]["image"] * jnp.nan
            batch = dict(batch, images=imgs)
        if step == 4:                 # scrape the live endpoint mid-run
            port = int(open(os.path.join(rd, "metrics_port")).read())
            for ep in ("metrics", "healthz"):
                with urllib.request.urlopen(
                        f"http://127.0.0.1:{port}/{ep}", timeout=5) as r:
                    probes[ep] = (r.status, r.read().decode())
        return batch

    obs_health.set_step_fault_hook(hook)
    try:
        losses = train(args)
    finally:
        obs_health.set_step_fault_hook(None)

    # (a) the poisoned step reports NaN but never lands: params stay
    # finite, so every subsequent loss is too
    assert not np.isfinite(losses[2])
    assert all(np.isfinite(v) for i, v in enumerate(losses) if i != 2)

    # (b) schema-valid runlog with the anomaly + skipped step record
    path = os.path.join(rd, "runlog.jsonl")
    assert check_runlog.check_file(path) == []
    records = rl.read_runlog(path)
    anoms = [r for r in records if r["kind"] == "anomaly"]
    assert anoms and all(r["detector"] == "nonfinite" and r["step"] == 2
                         and r["severity"] == "critical" for r in anoms)
    steps = {r["step"]: r for r in records if r["kind"] == "step"}
    assert steps[2].get("skipped") == 1
    assert all("skipped" not in steps[i] for i in steps if i != 2)
    event = next(r for r in records if r["kind"] == "event"
                 and r["event"] == "trace_export")
    assert isinstance(event["dropped"], int)
    final = [r for r in records if r["kind"] == "metrics"][-1]
    assert final["counters"]["health/steps_skipped"] == 1

    # (c) the flight recorder dumped the incident
    dumps = os.listdir(os.path.join(rd, "flight"))
    assert dumps == ["step000002_nonfinite"]
    anomaly = json.load(open(os.path.join(
        rd, "flight", dumps[0], "anomaly.json")))
    assert anomaly["detector"] == "nonfinite" and anomaly["step"] == 2

    # (d) the mid-run scrape saw Prometheus text + a healthy /healthz
    code, body = probes["metrics"]
    assert code == 200 and "# TYPE health_checks counter" in body
    assert 'health_anomalies{detector="nonfinite",severity="critical"} 2' \
        in body
    code, body = probes["healthz"]
    assert code == 200 and json.loads(body)["healthy"] is True
