"""Distributed trainer: loss decreases; checkpoint resume continues exactly."""
import types

import numpy as np

from repro.launch.train_distributed import train


def _args(**kw):
    base = dict(arch="llama3.2-1b", smoke=True, steps=12, batch=4, seq=32,
                lr=3e-3, seed=0, sharding="basic_ws", remat="basic",
                model_parallel=1, log_every=100, ckpt_dir=None, ckpt_every=0,
                stop_after=None)
    base.update(kw)
    return types.SimpleNamespace(**base)


def test_trainer_reduces_loss():
    # uniform-random tokens have an entropy floor of ln(vocab) ~ 6.24; from
    # a ~6.6 init the trainer must close most of the gap to the floor.
    losses = train(_args(steps=40, lr=5e-3))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, \
        (np.mean(losses[:5]), np.mean(losses[-5:]))
    assert all(np.isfinite(losses))


def test_checkpoint_resume_is_exact(tmp_path):
    """train 12 straight == train 6, checkpoint, resume 6 more (bitwise-close
    — the data stream is keyed by absolute step, so resume sees the same
    batches)."""
    full = train(_args(steps=12))
    d = str(tmp_path / "ck")
    # stop_after keeps the LR-schedule horizon (steps=12) identical
    train(_args(steps=12, stop_after=6, ckpt_dir=d))
    resumed = train(_args(steps=12, ckpt_dir=d))
    np.testing.assert_allclose(resumed, full[6:], rtol=1e-4)
