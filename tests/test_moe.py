"""MoE dispatch correctness: capacity (GShard) vs dense (exact) parity,
load-balance loss behaviour, capacity-drop semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_variant
from repro.models import moe as moe_lib


def _cfg(E=4, k=2, dense_residual=False):
    base = smoke_variant(get_arch("mixtral-8x22b"))
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, num_experts=E, top_k=k,
                                      dense_residual=dense_residual))


def test_capacity_equals_dense_when_no_drops():
    """With capacity_factor = E/top_k the buckets can hold every token, so
    GShard dispatch must reproduce the exact dense-dispatch output."""
    cfg = _cfg()
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    params = moe_lib.init_moe_params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    out_d, aux_d = moe_lib.moe_ffn(params, cfg, x, dispatch="dense")
    out_c, aux_c = moe_lib.moe_ffn(params, cfg, x, dispatch="capacity",
                                   group=32, capacity_factor=E / k)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_d),
                               rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_c), float(aux_d), rtol=1e-6)


def test_capacity_drops_reduce_output_norm():
    """Tiny capacity drops tokens -> output is a strict 'subset' (smaller
    norm) of the no-drop output, never garbage."""
    cfg = _cfg()
    params = moe_lib.init_moe_params(jax.random.key(2), cfg)
    x = jax.random.normal(jax.random.key(3), (2, 16, cfg.d_model))
    full, _ = moe_lib.moe_ffn(params, cfg, x, dispatch="capacity",
                              group=32, capacity_factor=2.0)
    tight, _ = moe_lib.moe_ffn(params, cfg, x, dispatch="capacity",
                               group=32, capacity_factor=0.25)
    assert float(jnp.linalg.norm(tight)) < float(jnp.linalg.norm(full))
    assert np.all(np.isfinite(np.asarray(tight)))


def test_load_balance_loss_minimal_for_uniform_router():
    """A router that is exactly uniform achieves the theoretical minimum of
    the aux loss (= load_balance_coef)."""
    cfg = _cfg()
    params = moe_lib.init_moe_params(jax.random.key(4), cfg)
    params["router"] = jnp.zeros_like(params["router"])  # uniform probs
    x = jax.random.normal(jax.random.key(5), (2, 32, cfg.d_model))
    _, aux = moe_lib.moe_ffn(params, cfg, x, dispatch="dense")
    np.testing.assert_allclose(float(aux), cfg.moe.load_balance_coef,
                               rtol=1e-5)


def test_arctic_dense_residual_adds_signal():
    cfg_res = _cfg(dense_residual=True)
    params = moe_lib.init_moe_params(jax.random.key(6), cfg_res)
    x = jax.random.normal(jax.random.key(7), (1, 8, cfg_res.d_model))
    with_res, _ = moe_lib.moe_ffn(params, cfg_res, x, dispatch="dense")
    cfg_no = _cfg(dense_residual=False)
    no_res, _ = moe_lib.moe_ffn(
        {k: v for k, v in params.items() if not k.startswith("dense_")},
        cfg_no, x, dispatch="dense")
    assert float(jnp.max(jnp.abs(with_res - no_res))) > 1e-4


@pytest.mark.parametrize("group", [8, 16, 32])
def test_capacity_invariant_to_group_when_no_drops(group):
    """Group size only affects bucketing, not the (no-drop) result."""
    cfg = _cfg()
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    params = moe_lib.init_moe_params(jax.random.key(8), cfg)
    x = jax.random.normal(jax.random.key(9), (1, 32, cfg.d_model))
    ref, _ = moe_lib.moe_ffn(params, cfg, x, dispatch="dense")
    out, _ = moe_lib.moe_ffn(params, cfg, x, dispatch="capacity",
                             group=group, capacity_factor=E / k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=1e-5)
