import os
import sys

# tests must see the single real CPU device (the 512-device override is
# dryrun.py-local, never global).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
