"""Health & SLO monitoring tier tests (DESIGN.md §14).

Pins, layer by layer:

  * obs/windows.py — windowed percentiles/mean/MAD against a numpy
    oracle ACROSS RING WRAP-AROUND (the ring's oldest-first reassembly
    is the part a naive implementation gets wrong), MAD z-score
    semantics incl. the degenerate-window fallbacks, WindowedRate under
    a fake clock.
  * obs/health.py — each detector on a synthetic trajectory built to
    trip exactly it (NaN, spike, plateau, stall, straggler skew) and on
    a healthy one (no fire); HealthMonitor end-to-end: anomaly runlog
    records, health/* counters, flight-recorder dump contents, the
    consecutive-critical healthy/unhealthy transition, dump rate limit.
  * SLOTracker — readiness flips when the windowed error budget burns
    out and RECOVERS as the window slides (no restart needed).
"""
from __future__ import annotations

import json
import math
import os

import numpy as np
import pytest

from repro.obs import health as oh
from repro.obs import metrics as om
from repro.obs import runlog as orl
from repro.obs import trace as ot
from repro.obs import windows as ow


# ---------------------------------------------------------------------------
# windows: numpy-oracle pinning
# ---------------------------------------------------------------------------


class TestSlidingWindow:
    def test_percentiles_match_numpy_across_wraparound(self):
        rng = np.random.default_rng(0)
        w = ow.SlidingWindow(64)
        stream = rng.standard_normal(1000)
        for j, v in enumerate(stream):
            w.push(v)
            if j in (0, 5, 63, 64, 100, 500, 999):   # pre-fill AND wrapped
                tail = stream[max(0, j - 63):j + 1]
                for q in (0, 10, 25, 50, 90, 99, 100):
                    assert w.percentile(q) == pytest.approx(
                        np.percentile(tail, q), abs=1e-12), (j, q)
                assert w.mean() == pytest.approx(tail.mean())
                assert w.min() == tail.min() and w.max() == tail.max()

    def test_values_oldest_first_after_wrap(self):
        w = ow.SlidingWindow(3)
        for v in (1, 2, 3, 4, 5):
            w.push(v)
        assert w.values() == [3.0, 4.0, 5.0]
        assert w.count == 3 and w.total == 5 and w.full

    def test_mad_matches_numpy_oracle(self):
        rng = np.random.default_rng(1)
        w = ow.SlidingWindow(32)
        xs = rng.standard_normal(80)
        for v in xs:
            w.push(v)
        tail = xs[-32:]
        med = np.percentile(tail, 50)
        assert w.mad() == pytest.approx(
            np.percentile(np.abs(tail - med), 50), abs=1e-12)

    def test_empty_window_is_nan_not_raise(self):
        w = ow.SlidingWindow(4)
        for fn in (w.mean, w.min, w.max, w.median, w.mad):
            assert math.isnan(fn())
        assert math.isnan(w.percentile(99))
        assert math.isnan(w.zscore(1.0))

    def test_bad_args_raise(self):
        with pytest.raises(ValueError):
            ow.SlidingWindow(0)
        with pytest.raises(ValueError):
            ow.percentile([1.0], 101)

    def test_zscore_reads_in_sigma_units(self):
        # symmetric window: median 0, MAD 1 -> z(v) = v * MAD_TO_SIGMA^-1
        # ... scaled so a normal sample's z ~ its sigma distance
        w = ow.SlidingWindow(5)
        for v in (-2, -1, 0, 1, 2):
            w.push(v)
        assert w.zscore(0.0) == 0.0
        z = w.zscore(10.0)
        assert z == pytest.approx((10.0 - 0.0) / (1.0 / ow.MAD_TO_SIGMA))

    def test_zscore_degenerate_fallbacks(self):
        # >half identical: MAD=0, falls back to mean-abs-dev scale
        w = ow.SlidingWindow(5)
        for v in (1, 1, 1, 1, 9):
            w.push(v)
        assert math.isfinite(w.zscore(100.0)) and w.zscore(100.0) > 0
        # ALL identical: any deviation is infinitely surprising
        w2 = ow.SlidingWindow(4)
        for _ in range(4):
            w2.push(3.0)
        assert w2.zscore(3.0) == 0.0
        assert w2.zscore(4.0) == math.inf
        assert w2.zscore(2.0) == -math.inf


class TestWindowedRate:
    def test_rate_counts_trailing_window_only(self):
        t = [0.0]
        r = ow.WindowedRate(window_s=10.0, capacity=100, clock=lambda: t[0])
        for _ in range(5):
            r.mark()
        assert r.rate() == pytest.approx(0.5)      # 5 events / 10s
        t[0] = 20.0                                 # all events aged out
        assert r.rate() == 0.0
        assert r.total == 5

    def test_rate_saturates_at_capacity(self):
        t = [0.0]
        r = ow.WindowedRate(window_s=1.0, capacity=8, clock=lambda: t[0])
        r.mark(100)                                 # only 8 timestamps kept
        assert r.rate() == pytest.approx(8.0)
        assert r.total == 100


# ---------------------------------------------------------------------------
# detectors on synthetic trajectories
# ---------------------------------------------------------------------------


def _sample(step, loss=2.0, gnorm=1.0, wait=1e-4, **kw):
    return oh.StepSample(step=step, loss=loss, grad_norm=gnorm,
                         data_wait_s=wait, device_step_s=0.01,
                         step_s=0.011, **kw)


class TestDetectors:
    def test_nonfinite_fires_critical_on_nan_and_inf(self):
        d = oh.NonFiniteDetector()
        assert d.observe(_sample(0)) == []
        out = d.observe(_sample(1, loss=math.nan))
        assert [a.severity for a in out] == ["critical"]
        assert out[0].detector == "nonfinite" and out[0].step == 1
        out = d.observe(_sample(2, gnorm=math.inf))
        assert len(out) == 1 and "grad_norm" in out[0].message
        # no cooldown: a NaN storm is one incident per step
        assert d.observe(_sample(3, loss=math.nan))

    def test_spike_fires_on_blowup_not_noise(self):
        rng = np.random.default_rng(2)
        d = oh.SpikeDetector("grad_norm", threshold=8.0, window=64,
                             min_count=16)
        for i in range(100):                       # noisy-but-sane gradient
            assert d.observe(_sample(i, gnorm=1.0 + 0.05 * rng.standard_normal())) == []
        out = d.observe(_sample(100, gnorm=50.0))  # the blow-up
        assert len(out) == 1 and out[0].severity == "warn"
        assert out[0].detector == "grad_norm_spike"
        # the spike was NOT absorbed into the window: normal values after
        # it don't fire, and a second spike still does
        assert d.observe(_sample(101, gnorm=1.0)) == []
        assert d.observe(_sample(102, gnorm=50.0))

    def test_spike_ignores_nonfinite(self):
        d = oh.SpikeDetector("grad_norm", window=16, min_count=4)
        for i in range(8):
            d.observe(_sample(i))
        assert d.observe(_sample(8, gnorm=math.nan)) == []

    def test_plateau_fires_once_with_cooldown(self):
        d = oh.PlateauDetector(window=32, rel_improvement=1e-3)
        fired = []
        for i in range(64):                        # learning: no fire
            fired += d.observe(_sample(i, loss=3.0 - 0.01 * i))
        assert fired == []
        for i in range(64, 160):                   # flat: plateau
            fired += d.observe(_sample(i, loss=1.0))
        assert 1 <= len(fired) <= 3                # cooldown, not per-step
        assert fired[0].detector == "loss_plateau"
        assert fired[0].severity == "warn"

    def test_stall_warn_vs_median_and_critical_hard_limit(self):
        d = oh.StallDetector(factor=10.0, min_stall_s=0.5, hard_limit_s=60.0,
                             min_count=8)
        for i in range(20):
            assert d.observe(_sample(i, wait=0.01)) == []
        out = d.observe(_sample(20, wait=2.0))     # 200x median, > floor
        assert len(out) == 1 and out[0].severity == "warn"
        out = d.observe(_sample(21, wait=120.0))   # wedged host
        assert len(out) == 1 and out[0].severity == "critical"

    def test_stall_floor_shields_fast_pipelines(self):
        d = oh.StallDetector(min_stall_s=1.0, min_count=4)
        for i in range(10):                        # µs jitter, all << floor
            assert d.observe(_sample(i, wait=1e-5 * (1 + i % 3))) == []

    def test_straggler_from_registry_series(self):
        reg = om.Registry()
        for i in range(16):
            reg.histogram("data/gen_seconds", host=0).observe(0.01)
            reg.histogram("data/gen_seconds", host=1).observe(0.01)
            reg.histogram("data/gen_seconds", host=2).observe(0.08)
        d = oh.StragglerDetector(reg, ratio=3.0, min_count=8, every=4)
        assert d.observe(_sample(3)) == []          # off-cadence step
        out = d.observe(_sample(4))
        assert len(out) == 1 and out[0].detector == "host_straggler"
        assert "host 2" in out[0].message
        assert out[0].value == pytest.approx(8.0)

    def test_straggler_needs_two_hosts(self):
        reg = om.Registry()
        for _ in range(16):
            reg.histogram("data/gen_seconds", host=0).observe(0.5)
        d = oh.StragglerDetector(reg, every=1)
        assert d.observe(_sample(1)) == []


# ---------------------------------------------------------------------------
# HealthMonitor end-to-end + flight recorder
# ---------------------------------------------------------------------------


class TestHealthMonitor:
    def test_anomaly_response_runlog_counters_flight(self, tmp_path):
        run_dir = str(tmp_path)
        reg = om.Registry()
        tracer = ot.Tracer()
        runlog = orl.RunLogger(os.path.join(run_dir, "runlog.jsonl"))
        mon = oh.HealthMonitor(registry=reg, tracer=tracer, runlog=runlog,
                               run_dir=run_dir, keep_steps=8)
        for i in range(5):
            rec = {"kind": "step", "step": i, "loss": 2.0}
            assert mon.observe_step(_sample(i), record=rec) == []
        found = mon.observe_step(_sample(5, loss=math.nan),
                                 record={"kind": "step", "step": 5})
        runlog.close()
        assert [a.detector for a in found] == ["nonfinite"]

        # runlog got a schema-valid anomaly record
        recs = orl.read_runlog(os.path.join(run_dir, "runlog.jsonl"))
        anoms = [r for r in recs if r["kind"] == "anomaly"]
        assert len(anoms) == 1 and anoms[0]["step"] == 5
        assert anoms[0]["severity"] == "critical"

        # counters
        snap = reg.snapshot()
        key = "health/anomalies{detector=nonfinite,severity=critical}"
        assert snap["counters"][key] == 1
        assert snap["counters"]["health/checks"] == 6
        assert snap["gauges"]["health/last_anomaly_step"] == 5

        # flight dump: self-contained directory with all four artifacts
        dumps = os.listdir(os.path.join(run_dir, "flight"))
        assert dumps == ["step000005_nonfinite"]
        d = os.path.join(run_dir, "flight", dumps[0])
        a = json.load(open(os.path.join(d, "anomaly.json")))
        assert a["detector"] == "nonfinite" and a["step"] == 5
        trace = json.load(open(os.path.join(d, "trace.json")))
        assert any(e["name"] == "anomaly/nonfinite"
                   for e in trace["traceEvents"])
        metrics = json.load(open(os.path.join(d, "metrics.json")))
        assert "health/checks" in metrics["counters"]
        steps = [json.loads(l) for l in
                 open(os.path.join(d, "steps.jsonl"))]
        assert [s["step"] for s in steps] == [0, 1, 2, 3, 4, 5]

    def test_healthy_flips_on_consecutive_criticals_and_recovers(self):
        mon = oh.HealthMonitor(registry=om.Registry(), unhealthy_after=3)
        mon.observe_step(_sample(0, loss=math.nan))
        assert mon.healthy                          # one incident: contained
        mon.observe_step(_sample(1, loss=math.nan))
        assert mon.healthy
        mon.observe_step(_sample(2, loss=math.nan))
        assert not mon.healthy                      # sustained episode
        assert mon.status()["healthy"] is False
        mon.observe_step(_sample(3))                # storm over
        assert mon.healthy
        assert mon.status()["consecutive_critical"] == 0

    def test_flight_dump_rate_limit(self, tmp_path):
        mon = oh.HealthMonitor(registry=om.Registry(),
                               run_dir=str(tmp_path), max_dumps=2)
        for i in range(5):
            mon.observe_step(_sample(i, loss=math.nan))
        assert len(os.listdir(tmp_path / "flight")) == 2
        snap = mon.registry.snapshot()
        assert snap["counters"]["health/flight_dumps"] == 2
        assert snap["counters"]["health/flight_dumps_suppressed"] == 3

    def test_skipped_steps_counted(self):
        mon = oh.HealthMonitor(registry=om.Registry())
        mon.observe_step(_sample(0, loss=math.nan, skipped=True))
        assert mon.status()["steps_skipped"] == 1


# ---------------------------------------------------------------------------
# SLO tracker
# ---------------------------------------------------------------------------


class TestSLOTracker:
    def test_ready_until_budget_burns_then_recovers(self):
        reg = om.Registry()
        slo = oh.SLOTracker(target_s=0.1, objective=0.9, window=20,
                            registry=reg, name="serve")
        for _ in range(20):
            slo.observe(0.05)
        assert slo.ready and slo.status()["error_budget_burn"] == 0.0
        # budget: 10% of the window may violate; 3/20 = 15% -> burn 1.5
        for _ in range(3):
            slo.observe(1.0)
        st = slo.status()
        assert st["error_budget_burn"] == pytest.approx(1.5)
        assert not slo.ready and st["healthy"] is False
        assert reg.snapshot()["gauges"]["serve/slo_ready"] == 0
        # window slides: 20 fast requests age the violations out
        for _ in range(20):
            slo.observe(0.05)
        assert slo.ready
        assert reg.snapshot()["gauges"]["serve/slo_ready"] == 1

    def test_gauges_and_counters_land_on_registry(self):
        reg = om.Registry()
        slo = oh.SLOTracker(target_s=0.1, registry=reg, name="decode")
        slo.observe(0.2)
        snap = reg.snapshot()
        assert snap["counters"]["decode/slo_requests"] == 1
        assert snap["counters"]["decode/slo_violations"] == 1
        assert snap["gauges"]["decode/slo_p99_s"] == pytest.approx(0.2)

    def test_bad_args_raise(self):
        with pytest.raises(ValueError):
            oh.SLOTracker(target_s=0.1, objective=1.5)
        with pytest.raises(ValueError):
            oh.SLOTracker(target_s=0.0)

    def test_p99_tracks_window(self):
        slo = oh.SLOTracker(target_s=1.0, window=100)
        for v in np.linspace(0.01, 0.99, 100):
            slo.observe(v)
        assert slo.status()["p99_s"] == pytest.approx(
            np.percentile(np.linspace(0.01, 0.99, 100), 99), abs=1e-9)


# ---------------------------------------------------------------------------
# fault-hook seam
# ---------------------------------------------------------------------------


class TestFaultHook:
    def test_hook_applies_and_clears(self):
        calls = []
        oh.set_step_fault_hook(lambda step, batch: calls.append(step) or
                               {"poisoned": True})
        try:
            out = oh.apply_step_fault_hook(7, {"x": 1})
            assert out == {"poisoned": True} and calls == [7]
        finally:
            oh.set_step_fault_hook(None)
        assert oh.apply_step_fault_hook(8, {"x": 1}) == {"x": 1}

    def test_monitor_wall_time_feeds_slo(self):
        slo = oh.SLOTracker(target_s=10.0, window=8)
        wrapped = oh.monitor_wall_time(lambda a: a * 2, slo)
        assert wrapped(21) == 42
        assert slo.status()["requests"] == 1
