"""The fused Pallas contrastive loss composes with Algorithm-1 GradAccum:
same loss and same weight gradients as the materializing reference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.contrastive import contrastive_loss, fused_kernel_loss
from repro.core.gradaccum import contrastive_step


def test_gradaccum_with_fused_kernel_loss():
    key = jax.random.key(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    B, Din, D = 32, 12, 16
    params = {"wi": 0.3 * jax.random.normal(k1, (Din, D)),
              "wt": 0.3 * jax.random.normal(k2, (Din, D)),
              "log_tau": jnp.asarray(-1.0)}
    batch = {"images": jax.random.normal(k3, (B, Din)),
             "texts": jax.random.normal(k4, (B, Din))}

    def norm(z):
        return z / jnp.linalg.norm(z, axis=-1, keepdims=True)

    enc_i = lambda p, x: norm(jnp.tanh(x @ p["wi"]))   # noqa: E731
    enc_t = lambda p, y: norm(jnp.tanh(y @ p["wt"]))   # noqa: E731

    l_ref, _, g_ref = contrastive_step(enc_i, enc_t, params, batch, 4,
                                       loss_fn=contrastive_loss)
    l_k, _, g_k = contrastive_step(enc_i, enc_t, params, batch, 4,
                                   loss_fn=fused_kernel_loss)
    np.testing.assert_allclose(float(l_ref), float(l_k), rtol=1e-5)
    for k in g_ref:
        np.testing.assert_allclose(np.asarray(g_ref[k]), np.asarray(g_k[k]),
                                   rtol=1e-4, atol=1e-6, err_msg=k)
