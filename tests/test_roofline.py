"""Roofline extraction: HLO collective parsing + term math."""
import numpy as np

from repro.configs import INPUT_SHAPES, get_arch
from repro.launch import roofline as rf

HLO = """
ENTRY %main {
  %ag = bf16[16,1024,512]{2,1,0} all-gather(bf16[1,1024,512] %p0), dim=0
  %ar = f32[256,4096]{1,0} all-reduce(f32[256,4096] %x), to_apply=%add
  %rs = f32[16,256]{1,0} reduce-scatter(f32[256,256] %y), dimensions={0}
  %a2a = (bf16[8,128]{1,0}, bf16[8,128]{1,0}) all-to-all(%a, %b)
  %cp = bf16[64,64]{1,0} collective-permute(bf16[64,64] %z)
  %ags = bf16[2,8]{1,0} all-gather-start(bf16[1,8] %q), dim=0
  %dot = f32[128,128]{1,0} dot(%l, %r)
}
"""


def test_collective_bytes_parses_all_kinds():
    c = rf.collective_bytes(HLO)
    assert c["all-gather"] == 16 * 1024 * 512 * 2 + 2 * 8 * 2
    assert c["all-reduce"] == 256 * 4096 * 4
    assert c["reduce-scatter"] == 16 * 256 * 4
    assert c["all-to-all"] == 2 * (8 * 128 * 2)
    assert c["collective-permute"] == 64 * 64 * 2
    assert c["count"] == 6
    assert c["total"] == sum(c[k] for k in
                             ("all-gather", "all-reduce", "reduce-scatter",
                              "all-to-all", "collective-permute"))


def test_dot_not_counted():
    c = rf.collective_bytes("%d = f32[4096,4096] dot(%a, %b)")
    assert c["total"] == 0


def test_roofline_terms_and_bottleneck():
    t = rf.roofline_terms({"flops": 197e12, "bytes accessed": 819e9 * 2},
                          {"total": 50e9 * 0.5})
    np.testing.assert_allclose(t["compute_s"], 1.0)
    np.testing.assert_allclose(t["memory_s"], 2.0)
    np.testing.assert_allclose(t["collective_s"], 0.5)
    assert t["bottleneck"] == "memory"


def test_model_flops_train_vs_decode():
    cfg = get_arch("llama3.2-1b")
    n = cfg.param_counts()["active"]
    tr = rf.model_flops(cfg, INPUT_SHAPES["train_4k"], n)
    de = rf.model_flops(cfg, INPUT_SHAPES["decode_32k"], n)
    assert tr == 6 * n * 256 * 4096
    assert de == 2 * n * 128
