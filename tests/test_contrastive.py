"""Unit tests for the contrastive loss (paper §3).

Hypothesis-based property tests live in test_contrastive_properties.py so
that this module collects cleanly on environments without ``hypothesis``.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.contrastive import (contrastive_loss, normalized_train_loss,
                                    similarity)


def _unit(rng, b, d):
    z = rng.standard_normal((b, d)).astype(np.float32)
    return jnp.asarray(z / np.linalg.norm(z, axis=1, keepdims=True))


def test_loss_values_match_manual():
    rng = np.random.default_rng(0)
    x, y = _unit(rng, 8, 16), _unit(rng, 8, 16)
    tau = 0.1
    loss, m = contrastive_loss(x, y, tau)
    a = np.asarray(similarity(x, y, tau))
    row = np.mean([-np.log(np.exp(a[i, i]) / np.exp(a[i]).sum())
                   for i in range(8)])
    col = np.mean([-np.log(np.exp(a[j, j]) / np.exp(a[:, j]).sum())
                   for j in range(8)])
    np.testing.assert_allclose(float(loss), 0.5 * (row + col), rtol=1e-5)


def test_perfect_alignment_minimizes():
    """Identical, well-separated embeddings -> near-minimal loss."""
    rng = np.random.default_rng(1)
    x = _unit(rng, 16, 64)
    loss_aligned, m = contrastive_loss(x, x, 0.01)
    loss_random, _ = contrastive_loss(x, _unit(rng, 16, 64), 0.01)
    assert float(loss_aligned) < 0.05
    assert float(loss_aligned) < float(loss_random)
    assert float(m["i2t_top1"]) == 1.0


def test_gradient_row_stochasticity():
    """Closed-form dA rows/cols sum to 0 for off-batch consistency:
    sum_ij dA_ij = 0 (softmax mass conservation)."""
    rng = np.random.default_rng(3)
    x, y = _unit(rng, 10, 8), _unit(rng, 10, 8)

    def loss_of_a(a):
        row = jnp.mean(jax.nn.logsumexp(a, 1) - jnp.diagonal(a))
        col = jnp.mean(jax.nn.logsumexp(a, 0) - jnp.diagonal(a))
        return 0.5 * (row + col)

    a = similarity(x, y, 0.2)
    da = jax.grad(loss_of_a)(a)
    np.testing.assert_allclose(float(jnp.sum(da)), 0.0, atol=1e-6)


def test_normalized_loss_matches_paper_def():
    rng = np.random.default_rng(4)
    x, y = _unit(rng, 6, 8), _unit(rng, 6, 8)
    ell = normalized_train_loss(x, y)
    s = np.asarray(x) @ np.asarray(y).T
    for i in range(6):
        expect = -np.exp(s[i, i]) / np.mean(np.exp(s[i]))
        np.testing.assert_allclose(float(ell[i]), expect, rtol=1e-5)


def test_larger_batch_tightens_normalized_estimate():
    """The 1/B sum in ell_B estimates E_y[exp(.)]; larger B -> lower variance
    (the mechanism behind Theorem 1)."""
    rng = np.random.default_rng(5)
    x = _unit(rng, 1, 16)
    pool = _unit(rng, 4096, 16)
    target = float(jnp.mean(jnp.exp(x @ pool.T)))
    errs = []
    for b in (8, 64, 512):
        ests = []
        for trial in range(30):
            idx = rng.integers(0, 4096, b)
            ests.append(float(jnp.mean(jnp.exp(x @ pool[idx].T))))
        errs.append(np.std(ests))
    assert errs[0] > errs[1] > errs[2]
    assert abs(np.mean(ests) - target) < 0.05
