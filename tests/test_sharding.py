"""Sharding rules: every produced PartitionSpec must divide its dim, for every
assigned architecture, in both modes, on the production mesh shape."""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_arch, list_archs
from repro.core import sharding as shd
from repro.launch import steps as st

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH_MP = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
ASSIGNED = [a for a in list_archs() if not a.startswith("basic-")]


def _axis_size(mesh, name):
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name]


def _check_divisible(tree_specs, tree_vals, mesh, tag):
    specs = jax.tree_util.tree_leaves_with_path(
        tree_specs, is_leaf=lambda s: isinstance(s, P))
    vals = dict(jax.tree_util.tree_leaves_with_path(tree_vals))
    for path, spec in specs:
        shape = np.shape(vals[path])
        for dim, names in enumerate(spec):
            if names is None:
                continue
            size = _axis_size(mesh, names)
            assert shape[dim] % size == 0, (tag, path, shape, dim, spec)


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mode", ["basic_ws", "tp"])
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["pod", "multipod"])
def test_param_specs_divide(arch, mode, mesh):
    cfg = get_arch(arch)
    params_abs = st.abstract_params(cfg)
    specs = shd.params_specs(params_abs, mesh, mode)
    _check_divisible(specs, params_abs, mesh, f"{arch}/{mode}")


@pytest.mark.parametrize("arch", ["qwen3-32b", "mixtral-8x22b",
                                  "jamba-1.5-large-398b", "arctic-480b"])
def test_basic_ws_shards_every_big_matrix(arch):
    """Paper §5.1: weights (>=2D) must actually be split, not replicated —
    else the memory saving evaporates."""
    cfg = get_arch(arch)
    params_abs = st.abstract_params(cfg)
    specs = shd.params_specs(params_abs, MESH, "basic_ws")
    leaves = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda s: isinstance(s, P))
    vals = dict(jax.tree_util.tree_leaves_with_path(params_abs))
    unsharded_big = [
        (p, np.shape(vals[p])) for p, s in leaves
        if s == P() and np.prod(np.shape(vals[p])) > 1e6]
    assert not unsharded_big, unsharded_big


def test_tp_moe_expert_axis():
    """128-expert Arctic shards the expert axis; 8-expert Mixtral falls back
    to intra-expert TP on the ff dim."""
    for arch, expect_axis in (("arctic-480b", 1), ("mixtral-8x22b", None)):
        cfg = get_arch(arch)
        params_abs = st.abstract_params(cfg)
        specs = shd.params_specs(params_abs, MESH, "tp")
        moe_wi = None
        for path, s in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda s: isinstance(s, P)):
            sp = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                          for k in path)
            if sp.endswith("moe/wi"):
                moe_wi = s
                break
        assert moe_wi is not None
        if expect_axis == 1:
            assert moe_wi[1] == "model", moe_wi      # expert parallel
        else:
            assert moe_wi[1] is None and "model" in tuple(moe_wi), moe_wi


def test_batch_specs_shard_over_data_axes():
    cfg = get_arch("llama3.2-1b")
    ins = st.input_specs(cfg, INPUT_SHAPES["train_4k"])
    specs = shd.batch_specs(ins, MESH_MP)
    assert specs["tokens"][0] == ("pod", "data")


def test_cache_specs_context_parallel_for_batch_1():
    """long_500k (batch=1): the cache sequence axis gets sharded instead."""
    cfg = get_arch("llama3.2-1b")  # SWA ring cache of 8192
    ins = st.input_specs(cfg, INPUT_SHAPES["long_500k"])
    specs = shd.cache_specs(ins["caches"], MESH)
    flat = jax.tree_util.tree_leaves(specs,
                                     is_leaf=lambda s: isinstance(s, P))
    assert any(any(ax is not None for ax in s[2:]) for s in flat
               if len(s) > 2), flat


def test_replicated_mode_is_all_empty_specs():
    cfg = get_arch("mamba2-130m")
    params_abs = st.abstract_params(cfg)
    specs = shd.params_specs(params_abs, MESH, "replicated")
    for s in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda s: isinstance(s, P)):
        assert s == P()


def test_params_specs_largest_axis_not_divisible_falls_back():
    """basic_ws must shard the largest DIVISIBLE dim: when the largest axis
    of a leaf doesn't divide the model-axis size, the next-largest
    divisible one is used, and a leaf with no divisible dim >= the axis
    size stays replicated (never a crash, never an invalid spec)."""
    SDS = jax.ShapeDtypeStruct
    f32 = np.float32
    tree = {
        # largest dim 100 not divisible by 16; dim 64 is -> shard axis 1
        "w_fallback": SDS((100, 64), f32),
        # no dim divisible by 16 -> replicated
        "w_odd": SDS((100, 30), f32),
        # dim 16 == axis size exactly -> shardable
        "w_exact": SDS((16, 10), f32),
        # divisible but smaller than axis size never selected (48 % 16 == 0
        # and 48 >= 16 -> sharded on axis 0, the largest divisible)
        "w_mixed": SDS((48, 100), f32),
    }
    specs = shd.params_specs(tree, MESH, "basic_ws")
    assert specs["w_fallback"] == P(None, "model")
    assert specs["w_odd"] == P()
    assert specs["w_exact"] == P("model", None)
    assert specs["w_mixed"] == P("model", None)
    _check_divisible(specs, tree, MESH, "fallback")


def test_params_specs_stacked_blocks_never_shard_scan_axis():
    """A 'blocks' leaf whose LARGEST axis is the leading scan axis must not
    shard it, even when divisible — the scan axis is iteration order, not
    a weight dim."""
    SDS = jax.ShapeDtypeStruct
    tree = {"blocks": {"w": SDS((32, 16, 10), np.float32)}}
    specs = shd.params_specs(tree, MESH, "basic_ws")
    # axis 0 (32, divisible) is skipped; axis 1 (16) is the fallback
    assert specs["blocks"]["w"] == P(None, "model", None)


def test_batch_specs_explicit_batch_axes_override():
    """batch_axes overrides the default ('pod','data') distribution — the
    paper's §5.1 'batch over ALL cores' layout adds the model axis."""
    SDS = jax.ShapeDtypeStruct
    batch = {"tokens": SDS((512, 128), np.int32),
             "scalar": SDS((), np.float32)}
    specs = shd.batch_specs(batch, MESH_MP,
                            batch_axes=("pod", "data", "model"))
    assert specs["tokens"] == P(("pod", "data", "model"), None)
    assert specs["scalar"] == P()
    # axes that don't divide are dropped left-to-right: batch 24 fits pod=2
    # and nothing more on the 2x16x16 mesh
    small = shd.batch_specs({"t": SDS((24, 4), np.int32)}, MESH_MP,
                            batch_axes=("pod", "data", "model"))
    assert small["t"] == P(("pod",), None)
