"""Sharding rules: every produced PartitionSpec must divide its dim, for every
assigned architecture, in both modes, on the production mesh shape."""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_arch, list_archs
from repro.core import sharding as shd
from repro.launch import steps as st

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH_MP = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
ASSIGNED = [a for a in list_archs() if not a.startswith("basic-")]


def _axis_size(mesh, name):
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name]


def _check_divisible(tree_specs, tree_vals, mesh, tag):
    specs = jax.tree_util.tree_leaves_with_path(
        tree_specs, is_leaf=lambda s: isinstance(s, P))
    vals = dict(jax.tree_util.tree_leaves_with_path(tree_vals))
    for path, spec in specs:
        shape = np.shape(vals[path])
        for dim, names in enumerate(spec):
            if names is None:
                continue
            size = _axis_size(mesh, names)
            assert shape[dim] % size == 0, (tag, path, shape, dim, spec)


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mode", ["basic_ws", "tp"])
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["pod", "multipod"])
def test_param_specs_divide(arch, mode, mesh):
    cfg = get_arch(arch)
    params_abs = st.abstract_params(cfg)
    specs = shd.params_specs(params_abs, mesh, mode)
    _check_divisible(specs, params_abs, mesh, f"{arch}/{mode}")


@pytest.mark.parametrize("arch", ["qwen3-32b", "mixtral-8x22b",
                                  "jamba-1.5-large-398b", "arctic-480b"])
def test_basic_ws_shards_every_big_matrix(arch):
    """Paper §5.1: weights (>=2D) must actually be split, not replicated —
    else the memory saving evaporates."""
    cfg = get_arch(arch)
    params_abs = st.abstract_params(cfg)
    specs = shd.params_specs(params_abs, MESH, "basic_ws")
    leaves = jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda s: isinstance(s, P))
    vals = dict(jax.tree_util.tree_leaves_with_path(params_abs))
    unsharded_big = [
        (p, np.shape(vals[p])) for p, s in leaves
        if s == P() and np.prod(np.shape(vals[p])) > 1e6]
    assert not unsharded_big, unsharded_big


def test_tp_moe_expert_axis():
    """128-expert Arctic shards the expert axis; 8-expert Mixtral falls back
    to intra-expert TP on the ff dim."""
    for arch, expect_axis in (("arctic-480b", 1), ("mixtral-8x22b", None)):
        cfg = get_arch(arch)
        params_abs = st.abstract_params(cfg)
        specs = shd.params_specs(params_abs, MESH, "tp")
        moe_wi = None
        for path, s in jax.tree_util.tree_leaves_with_path(
                specs, is_leaf=lambda s: isinstance(s, P)):
            sp = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                          for k in path)
            if sp.endswith("moe/wi"):
                moe_wi = s
                break
        assert moe_wi is not None
        if expect_axis == 1:
            assert moe_wi[1] == "model", moe_wi      # expert parallel
        else:
            assert moe_wi[1] is None and "model" in tuple(moe_wi), moe_wi


def test_batch_specs_shard_over_data_axes():
    cfg = get_arch("llama3.2-1b")
    ins = st.input_specs(cfg, INPUT_SHAPES["train_4k"])
    specs = shd.batch_specs(ins, MESH_MP)
    assert specs["tokens"][0] == ("pod", "data")


def test_cache_specs_context_parallel_for_batch_1():
    """long_500k (batch=1): the cache sequence axis gets sharded instead."""
    cfg = get_arch("llama3.2-1b")  # SWA ring cache of 8192
    ins = st.input_specs(cfg, INPUT_SHAPES["long_500k"])
    specs = shd.cache_specs(ins["caches"], MESH)
    flat = jax.tree_util.tree_leaves(specs,
                                     is_leaf=lambda s: isinstance(s, P))
    assert any(any(ax is not None for ax in s[2:]) for s in flat
               if len(s) > 2), flat


def test_replicated_mode_is_all_empty_specs():
    cfg = get_arch("mamba2-130m")
    params_abs = st.abstract_params(cfg)
    specs = shd.params_specs(params_abs, MESH, "replicated")
    for s in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda s: isinstance(s, P)):
        assert s == P()
