"""Fused similarity→top-k kernel vs the materializing oracle (interpret
mode): exact ordering incl. ties at block boundaries, ragged class counts,
bf16 inputs with fp32 accumulation, and the padding/validation edges."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.similarity_topk import ops, ref


def _pair(seed, b, n, d, dtype=jnp.float32):
    k1, k2 = jax.random.split(jax.random.key(seed))
    x = jax.random.normal(k1, (b, d), jnp.float32)
    c = jax.random.normal(k2, (n, d), jnp.float32)
    x = x / jnp.linalg.norm(x, axis=1, keepdims=True)
    c = c / jnp.linalg.norm(c, axis=1, keepdims=True)
    return x.astype(dtype), c.astype(dtype)


@pytest.mark.parametrize("k", [1, 5])
@pytest.mark.parametrize("b,n,d", [
    (8, 64, 16),      # single class block
    (5, 37, 16),      # row padding + ragged class block
    (32, 1000, 64),   # n_classes not divisible by the block
    (7, 130, 32),     # ragged both ways
    (1, 5, 8),        # k == n edge (k=5 case)
])
def test_matches_ref_ordering_exactly(b, n, d, k):
    x, c = _pair(b * n + d, b, n, d)
    vr, ir = ref.similarity_topk_ref(x, c, k, 2.0)
    vk, ik = ops.similarity_topk(x, c, k, inv_tau=2.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr),
                               rtol=1e-6, atol=1e-6)


def test_ties_at_block_boundaries_break_to_lower_index():
    """Duplicated class rows straddling a class-block boundary produce
    bitwise-equal logits; both ref and kernel must pick the LOWER id."""
    x, c = _pair(0, 4, 300, 16)
    x, c = np.array(x), np.array(c)
    c[255] = c[2]     # ties across blocks 0/1 at bc=256
    c[256] = c[2]
    c[257] = c[99]
    c[10] = c[9]      # tie inside a block
    x[0] = c[2]       # row 0's best match is the triplicated class
    x, c = jnp.asarray(x), jnp.asarray(c)
    vr, ir = ref.similarity_topk_ref(x, c, 5)
    vk, ik = ops.similarity_topk(x, c, 5, bc=256, interpret=True)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr),
                               rtol=1e-6, atol=1e-6)
    # the triplicated winner surfaces in ascending-id order: 2, 255, 256
    np.testing.assert_array_equal(np.asarray(ik)[0, :3], [2, 255, 256])


def test_all_classes_identical_returns_first_k_indices():
    x, _ = _pair(3, 6, 1, 16)
    c = jnp.tile(_pair(4, 1, 1, 16)[1], (40, 1))
    _, ik = ops.similarity_topk(x, c, 5, bc=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(ik),
                                  np.tile(np.arange(5), (6, 1)))


@pytest.mark.parametrize("k", [1, 5])
def test_bf16_inputs_fp32_accumulation(k):
    """bf16 embeddings go straight to the tile dot; values must match the
    fp32-accumulated oracle on the SAME bf16 inputs, and ordering must be
    identical (both paths see identical rounded logits)."""
    x, c = _pair(11, 16, 520, 64, dtype=jnp.bfloat16)
    vr, ir = ref.similarity_topk_ref(x, c, k)
    vk, ik = ops.similarity_topk(x, c, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr),
                               rtol=1e-6, atol=1e-6)
    # sanity: bf16 ordering agrees with fp32 ordering on well-separated rows
    assert np.asarray(vk).dtype == np.float32


def test_block_sweep_invariance():
    """The result must not depend on the block decomposition."""
    x, c = _pair(7, 12, 700, 32)
    base = ops.similarity_topk(x, c, 5, bc=128, interpret=True)
    for bm, bc in [(8, 256), (16, 512), (8, 1024)]:
        got = ops.similarity_topk(x, c, 5, bm=bm, bc=bc, interpret=True)
        np.testing.assert_array_equal(np.asarray(got[1]),
                                      np.asarray(base[1]))
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(base[0]),
                                   rtol=1e-6, atol=1e-6)


def test_validation_errors():
    x, c = _pair(1, 8, 32, 16)
    with pytest.raises(ValueError, match="k=0"):
        ops.similarity_topk(x, c, 0, interpret=True)
    with pytest.raises(ValueError, match="k=33"):
        ops.similarity_topk(x, c, 33, interpret=True)
    with pytest.raises(ValueError, match="embed dims differ"):
        ops.similarity_topk(x, c[:, :8], 1, interpret=True)
    with pytest.raises(ValueError, match="class block"):
        ops.similarity_topk(x, c, 16, bc=8, interpret=True)


def test_classify_convenience():
    x, c = _pair(2, 9, 33, 16)
    got = ops.classify(x, c, interpret=True)
    want = ref.classify_ref(x, c)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_never_materializes_logits_memory_model():
    """The kernel's live buffers are inputs + O(b·k + b·bc): assert the
    pallas path works at a (b, n) size whose logit matrix would dominate
    memory, and that outputs stay (b, k)."""
    x, c = _pair(5, 8, 20_000, 32)
    vals, idx = ops.similarity_topk(x, c, 5, bc=2048, interpret=True)
    assert vals.shape == (8, 5) and idx.shape == (8, 5)
    assert np.all(np.asarray(idx) < 20_000)


def test_shard_combine_matches_global_sweep_with_boundary_ties():
    """Simulate the sharded serving combine on one process: split the
    class matrix into shards, run the kernel per shard with global index
    offsets, then merge_topk the pooled per-shard top-ks. Duplicate rows
    are planted so exact ties straddle every shard boundary; the merge
    must still be bit-identical to one global kernel sweep (ties to the
    LOWER global id)."""
    b, d, k, shards = 6, 16, 7, 4
    n = 4 * 37  # ragged per-shard blocks
    x, c = _pair(11, b, n, d)
    c = np.array(c)
    per = n // shards
    for s in range(1, shards):
        c[s * per] = c[s * per - 1]      # tie across each boundary
        c[s * per + 1] = c[0]            # duplicate of a far shard's row
    c = jnp.asarray(c)

    want_v, want_i = ops.similarity_topk(x, c, k, interpret=True)

    pool_v, pool_i = [], []
    for s in range(shards):
        lo = s * per
        v, i = ops.similarity_topk(x, c[lo:lo + per], k, interpret=True)
        pool_v.append(v)
        pool_i.append(i + lo)
    got_v, got_i = ops.merge_topk(jnp.concatenate(pool_v, axis=1),
                                  jnp.concatenate(pool_i, axis=1), k)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


def test_shard_combine_invariant_to_shard_order():
    """merge_topk's select-max-retire rule is order-independent: feeding
    the per-shard pools in any order yields identical output."""
    b, d, k = 4, 16, 5
    x, c = _pair(13, b, 96, d)
    pools = []
    for s in range(3):
        lo = s * 32
        v, i = ops.similarity_topk(x, c[lo:lo + 32], k, interpret=True)
        pools.append((v, i + lo))
    fwd = ops.merge_topk(jnp.concatenate([p[0] for p in pools], axis=1),
                         jnp.concatenate([p[1] for p in pools], axis=1), k)
    rev = ops.merge_topk(
        jnp.concatenate([p[0] for p in reversed(pools)], axis=1),
        jnp.concatenate([p[1] for p in reversed(pools)], axis=1), k)
    np.testing.assert_array_equal(np.asarray(fwd[0]), np.asarray(rev[0]))
    np.testing.assert_array_equal(np.asarray(fwd[1]), np.asarray(rev[1]))
