"""Hypothesis property tests for the continuous-batching engine.

Kept separate from test_continuous_engine.py and guarded with
``importorskip`` so the suite collects cleanly on bare environments
without ``hypothesis``; the deterministic parity suite next door pins the
same contract against the legacy engine either way.

Properties over RANDOM request streams (lengths, budgets, arrival
schedule, slot count all drawn):
  - per-request output invariance: the same request produces identical
    tokens no matter what else is in flight, what order things arrived
    in, or how many slots the engine runs,
  - capacity is never exceeded: active slots <= num_slots at every tick,
    and the admission queue fully drains,
  - pad tokens never reach results: outputs are <= budget, non-empty, and
    EOS (when hit) is always the final token — no pad/zero tail.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as hst  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_arch, smoke_variant  # noqa: E402
from repro.serving import ContinuousEngine  # noqa: E402

MOE = {"dispatch": "dense"}
CACHE_LEN = 64
# prompt lengths drawn from a small set so prefill compiles O(3) shapes,
# not O(examples)
PLENS = [3, 5, 8]

_CACHE = {}


def _model():
    if "m" not in _CACHE:
        from repro.models import transformer as tf
        cfg = smoke_variant(get_arch("llama3.2-1b"))
        _CACHE["m"] = (cfg, tf.init_params(cfg, jax.random.key(0)))
    return _CACHE["m"]


def _materialize(stream):
    """(plen_idx, max_new, content_seed) draws -> concrete requests."""
    out = []
    for i, (pi, max_new, seed) in enumerate(stream):
        rng = np.random.default_rng(seed)
        cfg, _ = _model()
        prompt = rng.integers(4, cfg.vocab, (PLENS[pi],)).astype(np.int32)
        out.append((prompt, max_new, i))
    return out


def _run_instrumented(reqs, num_slots, late_after=None):
    """Run a stream, asserting the capacity invariant at every tick.
    ``late_after``: submit only the first k up front, the rest after two
    ticks (exercises arrival staggering)."""
    cfg, params = _model()
    ce = ContinuousEngine(cfg, params, cache_len=CACHE_LEN,
                          num_slots=num_slots, moe_args=MOE)
    k = len(reqs) if late_after is None else late_after
    for r in reqs[:k]:
        ce.submit(*r)
    got, ticks = {}, 0
    late = list(reqs[k:])
    while ce.pending or late:
        for fin in ce.step():
            got[fin.request_id] = fin.tokens
        occupied = sum(s.active for s in ce._slots)
        assert occupied <= num_slots
        ticks += 1
        if ticks == 2 and late:
            for r in late:
                ce.submit(*r)
            late = []
        assert ticks < 10_000
    assert len(ce._queue) == 0                      # queue fully drained
    return ce, got


STREAM = hst.lists(
    hst.tuples(hst.integers(0, len(PLENS) - 1),     # prompt length bucket
               hst.integers(1, 6),                  # token budget
               hst.integers(0, 2**31 - 1)),         # prompt content seed
    min_size=1, max_size=6)


@settings(max_examples=5, deadline=None)
@given(stream=STREAM, slots_a=hst.integers(1, 3), slots_b=hst.integers(1, 3),
       late=hst.booleans())
def test_output_invariance_across_schedules(stream, slots_a, slots_b, late):
    """The same requests through two different engines — different slot
    counts, reversed submission order, optionally staggered arrival —
    yield bit-identical per-request tokens."""
    reqs = _materialize(stream)
    _, got_a = _run_instrumented(reqs, slots_a)
    _, got_b = _run_instrumented(
        reqs[::-1], slots_b,
        late_after=len(reqs) // 2 if late and len(reqs) > 1 else None)
    assert set(got_a) == set(got_b) == {r[2] for r in reqs}
    for rid in got_a:
        np.testing.assert_array_equal(got_a[rid], got_b[rid])


@settings(max_examples=5, deadline=None)
@given(stream=STREAM, slots=hst.integers(1, 3))
def test_results_respect_budget_and_eos(stream, slots):
    """Every result is non-empty, within its budget, and never continues
    past EOS — the fixed-shape step's pad lanes are invisible to callers."""
    reqs = _materialize(stream)
    ce, got = _run_instrumented(reqs, slots)
    eos = ce.eos_id
    for prompt, max_new, rid in reqs:
        toks = got[rid]
        assert 1 <= toks.size <= max_new
        hits = np.flatnonzero(toks == eos)
        if hits.size:                          # EOS is terminal when present
            assert hits[0] == toks.size - 1
        else:                                  # no EOS -> budget fully used
            assert toks.size == max_new
    # conservation: every admitted request retired exactly once
    assert ce.registry.counter("decode/requests").value == len(reqs)
    assert all(not s.active for s in ce._slots)
