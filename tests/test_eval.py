"""Evaluation suite: prompt ensembling, metrics, retrieval."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.data import Tokenizer, caption_corpus, world_for_tower
from repro.eval import (evaluate_benchmark, mean_per_class_recall,
                        retrieval_recall_at_k, topk_accuracy)


def test_topk_and_recall_metrics():
    logits = jnp.asarray([[2.0, 1.0, 0.0],
                          [0.0, 2.0, 1.0],
                          [2.0, 0.0, 1.0],   # wrong (label 2 ranked 2nd)
                          [0.0, 1.0, 2.0]])
    labels = np.array([0, 1, 2, 2])
    assert topk_accuracy(logits, labels, 1) == 0.75
    assert topk_accuracy(logits, labels, 2) == 1.0
    # classes 0,1 perfect; class 2 has recall 0.5
    np.testing.assert_allclose(mean_per_class_recall(logits, labels),
                               (1 + 1 + 0.5) / 3)


def test_class_embeddings_batched_matches_per_class_loop():
    """The single-pass tokenize-all + chunked-encode path must reproduce the
    original one-encode-per-class loop bit-for-bit in shape and closely in
    value (same math, different batch grouping)."""
    from repro.configs import get_arch, smoke_variant
    from repro.eval.zero_shot import DEFAULT_TEMPLATES, class_embeddings
    from repro.models import dual_encoder as de

    cfg = get_arch("basic-s")
    cfg = dataclasses.replace(
        cfg, image_tower=smoke_variant(cfg.image_tower),
        text_tower=smoke_variant(cfg.text_tower), embed_dim=16)
    rng = np.random.default_rng(0)
    world = world_for_tower(rng, cfg.image_tower, n_classes=7)
    from repro.data import Tokenizer, caption_corpus
    tok = Tokenizer.train(caption_corpus(world, rng, 200), vocab_size=300)
    params = de.init_params(cfg, jax.random.key(0))
    enc = lambda tx: de.encode_text(cfg, params, tx)        # noqa: E731

    got = class_embeddings(enc, tok, world.class_names)
    # the pre-batching reference implementation, verbatim
    per_class = []
    for name in world.class_names:
        parts = name.split(" ", 1)
        ids = [tok.encode(t.format(*parts), max_len=16)
               for t in DEFAULT_TEMPLATES]
        tokens, mask = tok.pad_batch(ids, max_len=16)
        emb = enc({"tokens": jnp.asarray(tokens),
                   "attn_mask": jnp.asarray(mask)})
        mean = jnp.mean(emb, axis=0)
        per_class.append(mean / jnp.linalg.norm(mean).clip(1e-6))
    want = jnp.stack(per_class)
    assert got.shape == want.shape == (7, 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # chunking must not change the result either
    got_chunked = class_embeddings(enc, tok, world.class_names, chunk_size=8)
    np.testing.assert_allclose(np.asarray(got_chunked), np.asarray(got),
                               atol=1e-5)


def test_retrieval_recall_identity():
    rng = np.random.default_rng(0)
    z = rng.standard_normal((16, 8)).astype(np.float32)
    z /= np.linalg.norm(z, axis=1, keepdims=True)
    r = retrieval_recall_at_k(jnp.asarray(z), jnp.asarray(z), ks=(1,))
    assert r["i2t@1"] == 1.0 and r["t2i@1"] == 1.0


def test_prompt_ensembling_end_to_end():
    """evaluate_benchmark on a trained-for-a-moment dual encoder: the
    ensembled prompts must classify clearly above chance, and the metric
    plumbing must be self-consistent."""
    from repro.core.gradaccum import contrastive_step
    from repro.data import contrastive_batch
    from repro.models import dual_encoder as de
    from repro.optim import AdaFactorW, apply_updates

    cfg = get_arch("basic-s")
    cfg = dataclasses.replace(
        cfg, image_tower=smoke_variant(cfg.image_tower),
        text_tower=smoke_variant(cfg.text_tower), embed_dim=32)
    rng = np.random.default_rng(0)
    world = world_for_tower(rng, cfg.image_tower, n_classes=12,
                            noise=0.2)
    tok = Tokenizer.train(caption_corpus(world, rng, 300), vocab_size=400)
    params = de.init_params(cfg, jax.random.key(0))
    opt = AdaFactorW()
    st = opt.init(params)
    enc_i = lambda p, im: de.encode_image(cfg, p, im)   # noqa: E731
    enc_t = lambda p, tx: de.encode_text(cfg, p, tx)    # noqa: E731

    @jax.jit
    def step(params, st, batch):
        _, _, g = contrastive_step(enc_i, enc_t, params, batch, 2)
        up, st = opt.update(g, st, params, 2e-3)
        return apply_updates(params, up), st

    for _ in range(40):
        batch, _ = contrastive_batch(world, tok, 24, rng)
        params, st = step(params, st, jax.tree.map(jnp.asarray, batch))

    test, cls = contrastive_batch(world, tok, 60, rng)
    out = evaluate_benchmark(
        encode_image=lambda im: enc_i(params, jax.tree.map(jnp.asarray, im)),
        encode_text=lambda tx: enc_t(params, tx),
        tok=tok, class_names=world.class_names,
        images=test["images"], labels=cls)
    assert out["top1"] > 2.0 / 12
    assert out["top5"] >= out["top1"]
    assert 0.0 <= out["mean_per_class_recall"] <= 1.0
    assert out["headline"] == out["top1"]
