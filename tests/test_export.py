"""Prometheus exposition + live endpoint tests (DESIGN.md §14.3).

The renderer is pinned by a GOLDEN FILE: ``_build_registry()`` below
deterministically populates a registry exercising every rendering rule
(name sanitization, label escaping, multi-series ``# TYPE`` grouping,
the histogram ``_bucket`` ladder, NaN/Inf/int formatting), and the
rendered text must match ``artifacts/metrics_sample.prom`` byte for
byte. Regenerate after an INTENTIONAL format change with:

  PYTHONPATH=src:tests python -c \
      "import test_export; test_export.regen_golden()"

The endpoint tests stand a real ``MetricsServer`` up on an ephemeral
loopback port and scrape it with urllib: /metrics content type and body,
/healthz 200-vs-503 driven by a live health source, /snapshot.json
round-trip, 404 for anything else, port file discovery.
"""
from __future__ import annotations

import json
import math
import os
import urllib.error
import urllib.request

import pytest

from repro.obs import export as oe
from repro.obs import metrics as om

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(ROOT, "artifacts", "metrics_sample.prom")


def _build_registry() -> om.Registry:
    """Deterministic registry covering every exposition rule — shared by
    the golden test and ``regen_golden()`` so the two can never drift."""
    reg = om.Registry()
    # sanitization: '/' and '-' both map to '_'; multi-series grouping
    reg.counter("train/steps").inc(42)
    reg.counter("data/bytes-read", host=0).inc(1024)
    reg.counter("data/bytes-read", host=1).inc(2048)
    # label escaping: quotes and backslashes must survive a scrape
    reg.counter("serve/requests", route='cls "a\\b"').inc(7)
    # value formatting: int-valued, float, NaN, +Inf
    reg.gauge("health/healthy").set(1)
    reg.gauge("train/loss").set(2.718281828459045)
    reg.gauge("health/last_p99_s").set(math.nan)
    reg.gauge("serve/burn").set(math.inf)
    # histogram: explicit buckets -> cumulative ladder + +Inf/_sum/_count
    h = reg.histogram("serve/latency_s", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.05, 0.5, 5.0):
        h.observe(v)
    return reg


def regen_golden() -> None:
    """Rewrite the committed golden from ``_build_registry()``."""
    with open(GOLDEN, "w") as f:
        f.write(oe.render_prometheus(_build_registry().snapshot()))
    print(f"wrote {GOLDEN}")


class TestRenderPrometheus:
    def test_matches_committed_golden(self):
        got = oe.render_prometheus(_build_registry().snapshot())
        with open(GOLDEN) as f:
            want = f.read()
        assert got == want, (
            "render_prometheus drifted from artifacts/metrics_sample.prom "
            "— if the format change is intentional, regenerate via "
            "test_export.regen_golden()")

    def test_histogram_ladder_semantics(self):
        reg = om.Registry()
        h = reg.histogram("lat", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 0.5, 5.0):
            h.observe(v)
        text = oe.render_prometheus(reg.snapshot())
        assert '# TYPE lat histogram' in text
        assert 'lat_bucket{le="0.1"} 1' in text      # cumulative, not per-bin
        assert 'lat_bucket{le="1"} 3' in text
        assert 'lat_bucket{le="+Inf"} 4' in text     # +Inf == _count always
        assert 'lat_count 4' in text
        assert 'lat_sum 6.05' in text

    def test_name_sanitization_and_grouping(self):
        reg = om.Registry()
        reg.counter("a/b-c.d").inc()
        reg.counter("9lives").inc()
        text = oe.render_prometheus(reg.snapshot())
        assert "a_b_c_d 1" in text
        assert "_9lives 1" in text                   # leading digit guarded
        # one TYPE header per base name even with many label series
        reg2 = om.Registry()
        reg2.counter("x", k=1).inc()
        reg2.counter("x", k=2).inc()
        t2 = oe.render_prometheus(reg2.snapshot())
        assert t2.count("# TYPE x counter") == 1
        assert 'x{k="1"} 1' in t2 and 'x{k="2"} 1' in t2

    def test_value_formats(self):
        reg = om.Registry()
        reg.gauge("g_nan").set(math.nan)
        reg.gauge("g_inf").set(math.inf)
        reg.gauge("g_int").set(3.0)
        text = oe.render_prometheus(reg.snapshot())
        assert "g_nan NaN" in text
        assert "g_inf +Inf" in text
        assert "g_int 3\n" in text                   # no trailing .0

    def test_empty_snapshot_is_just_newline_terminated(self):
        text = oe.render_prometheus(om.Registry().snapshot())
        assert text == "\n"

    def test_scrape_parses_line_shape(self):
        # every non-comment line must be "<name>[{labels}] <value>"
        text = oe.render_prometheus(_build_registry().snapshot())
        assert text.endswith("\n")
        for line in text.strip().split("\n"):
            if line.startswith("# TYPE "):
                assert len(line.split(" ")) == 4
                continue
            body, _, value = line.rpartition(" ")
            assert body and value
            float(value.replace("+Inf", "inf").replace("NaN", "nan"))


def _get(url: str):
    try:
        with urllib.request.urlopen(url, timeout=5) as r:
            return r.status, r.headers.get("Content-Type"), \
                r.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.headers.get("Content-Type"), e.read().decode()


class TestMetricsServer:
    def test_endpoints_live(self, tmp_path):
        reg = _build_registry()
        health = {"healthy": True, "checks": 5}
        with oe.MetricsServer(reg, health=lambda: dict(health),
                              run_dir=str(tmp_path)) as srv:
            assert srv.host == "127.0.0.1"           # localhost-only default
            # ephemeral port discovered via the run-dir port file
            port = int((tmp_path / "metrics_port").read_text())
            assert port == srv.port and port > 0

            code, ctype, body = _get(f"{srv.url}/metrics")
            assert code == 200 and ctype == oe.CONTENT_TYPE
            assert body == oe.render_prometheus(reg.snapshot())

            code, ctype, body = _get(f"{srv.url}/healthz")
            assert code == 200 and ctype == "application/json"
            assert json.loads(body) == {"healthy": True, "checks": 5}

            health["healthy"] = False                # live flip -> 503
            code, _, body = _get(f"{srv.url}/healthz")
            assert code == 503 and json.loads(body)["healthy"] is False

            code, _, body = _get(f"{srv.url}/snapshot.json")
            assert code == 200
            snap = json.loads(body)
            assert snap["counters"]["train/steps"] == 42

            code, _, _ = _get(f"{srv.url}/nope")
            assert code == 404
        # context exit stopped the server: the port must be dead
        with pytest.raises(urllib.error.URLError):
            urllib.request.urlopen(f"http://127.0.0.1:{port}/metrics",
                                   timeout=1)

    def test_no_health_source_always_ready(self):
        with oe.MetricsServer(om.Registry()) as srv:
            code, _, body = _get(f"{srv.url}/healthz")
            assert code == 200 and json.loads(body) == {"healthy": True}

    def test_start_stop_idempotent(self):
        srv = oe.MetricsServer(om.Registry())
        srv.start()
        srv.start()
        srv.stop()
        srv.stop()
