"""obs/ telemetry subsystem: histogram math vs numpy, thread safety,
trace-event schema, runlog round-trip + schema gating, stats back-compat,
and the committed runlog sample artifact."""
import json
import os
import sys
import threading
import time

import numpy as np
import pytest

from repro.obs import metrics, report, runlog, trace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "scripts"))

import check_runlog  # noqa: E402


# -- metrics ---------------------------------------------------------------
def test_histogram_percentiles_vs_numpy_oracle():
    """Interpolated percentile error is bounded by one bucket width."""
    buckets = metrics.exponential_buckets(1e-3, 2.0, 16)
    h = metrics.Histogram("lat", buckets=buckets)
    rng = np.random.default_rng(0)
    vals = rng.uniform(1e-3, 1.0, 4000)
    for v in vals:
        h.observe(v)
    bounds = (0.0,) + buckets + (float("inf"),)
    for q in (1, 25, 50, 75, 90, 99):
        oracle = float(np.percentile(vals, q))
        est = h.percentile(q)
        # the bucket containing the oracle bounds the allowed error
        i = np.searchsorted(buckets, oracle)
        width = bounds[i + 1] - bounds[i]
        assert abs(est - oracle) <= width, (q, est, oracle, width)
    assert h.count == len(vals)
    np.testing.assert_allclose(h.sum, vals.sum(), rtol=1e-9)


def test_histogram_summary_and_edges():
    h = metrics.Histogram("h", buckets=(1.0, 2.0, 4.0))
    assert np.isnan(h.percentile(50))
    assert h.summary()["count"] == 0 and h.summary()["p50"] is None
    for v in (0.5, 1.5, 3.0, 100.0):   # incl. overflow bucket
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and s["min"] == 0.5 and s["max"] == 100.0
    assert s["p50"] <= s["p90"] <= s["p99"] <= 100.0
    with pytest.raises(ValueError):
        h.percentile(101)
    with pytest.raises(ValueError):
        metrics.Histogram("bad", buckets=(2.0, 1.0))


def test_concurrent_counter_increments():
    """8 threads x 5000 incs race one counter; nothing is lost."""
    reg = metrics.Registry()
    c = reg.counter("hits")
    h = reg.histogram("obs", buckets=(0.5, 1.0))

    def work():
        for _ in range(5000):
            c.inc()
            h.observe(0.25)
    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8 * 5000
    assert h.count == 8 * 5000


def test_registry_labeled_children_and_snapshot():
    reg = metrics.Registry()
    a = reg.counter("req", tower="image")
    b = reg.counter("req", tower="text")
    assert a is not b
    assert reg.counter("req", tower="image") is a   # same child back
    a.inc(3)
    b.inc()
    reg.gauge("depth").set(7)
    reg.histogram("lat").observe(0.01)
    snap = reg.snapshot()
    assert snap["counters"]["req{tower=image}"] == 3
    assert snap["counters"]["req{tower=text}"] == 1
    assert snap["gauges"]["depth"] == 7.0
    assert snap["histograms"]["lat"]["count"] == 1
    json.loads(reg.to_json())                        # serializable
    with pytest.raises(TypeError):
        reg.gauge("req", tower="image")              # kind mismatch
    with pytest.raises(ValueError):
        a.inc(-1)                                    # counters only go up


# -- trace -----------------------------------------------------------------
def test_trace_event_schema_and_ring_buffer():
    tr = trace.Tracer(capacity=3)
    for i in range(5):
        with tr.span("work", pid=i % 2, arg=i):
            time.sleep(0.001)
    tr.instant("marker", pid=0)
    events = tr.events()
    assert len(events) == 3 and tr.dropped == 3      # ring: newest 3 win
    doc = tr.to_chrome_trace()
    assert isinstance(doc["traceEvents"], list)
    for ev in doc["traceEvents"]:
        for key in trace.REQUIRED_EVENT_KEYS:
            assert key in ev, (key, ev)
    # span durations are real wall time
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert spans and all(e["dur"] >= 900 for e in spans)   # ≥0.9ms in µs
    # process_name metadata labels the pid lanes
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert any(e["args"]["name"] == "trainer" for e in metas)


def test_trace_export_and_none_tracer(tmp_path):
    tr = trace.Tracer()
    tr.set_process_name(1, "host 0")
    with tr.span("s"):
        pass
    path = tr.export(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    assert {e["args"]["name"] for e in doc["traceEvents"]
            if e["ph"] == "M"} >= {"trainer", "host 0"}
    with trace.span(None, "noop") as got:            # disabled path
        assert got is None


def test_trace_thread_lanes():
    tr = trace.Tracer()
    def work():
        with tr.span("bg"):
            pass
    t = threading.Thread(target=work)
    t.start()
    t.join()
    with tr.span("fg"):
        pass
    tids = {e["name"]: e["tid"] for e in tr.events()}
    assert tids["bg"] != tids["fg"]


# -- runlog ----------------------------------------------------------------
def _write_steps(path, n, **meta):
    with runlog.RunLogger(str(path), meta=meta) as log:
        for i in range(n):
            log.log_step(i, loss=5.0 - i * 0.1, data_wait_s=0.001,
                         device_step_s=0.01, ckpt_stall_s=0.0,
                         step_s=0.011, examples_per_sec=700.0,
                         grad_norm=2.0)


def test_runlog_roundtrip_and_resume_marker(tmp_path):
    p = tmp_path / "runlog.jsonl"
    _write_steps(p, 3, arch="basic-s")
    # resumed segment appends to the SAME file: marker, no second header
    with runlog.RunLogger(str(p), resumed_from=3) as log:
        log.log_step(3, loss=4.6, data_wait_s=0.001, device_step_s=0.01,
                     ckpt_stall_s=0.002, step_s=0.013,
                     examples_per_sec=600.0)
        log.log("checkpoint", step=4, event="final_save")
    recs = runlog.read_runlog(str(p))
    kinds = [r["kind"] for r in recs]
    assert kinds.count("run_start") == 1 and kinds[0] == "run_start"
    assert kinds.count("resume") == 1
    resume = next(r for r in recs if r["kind"] == "resume")
    assert resume["resumed_from"] == 3
    steps = [r for r in recs if r["kind"] == "step"]
    assert [r["step"] for r in steps] == [0, 1, 2, 3]
    for r in steps:
        for key in runlog.STEP_BREAKDOWN_KEYS:
            assert isinstance(r[key], float)


def test_runlog_schema_version_rejection(tmp_path):
    p = tmp_path / "runlog.jsonl"
    _write_steps(p, 2)
    with open(p, "a") as f:
        f.write(json.dumps({"schema": 99, "kind": "step", "t": 0.0}) + "\n")
        f.write("")
    with pytest.raises(runlog.RunlogError, match="schema"):
        runlog.read_runlog(str(p))
    assert len(runlog.read_runlog(str(p), strict=False)) == 3  # skipped


def test_runlog_torn_final_line_tolerated(tmp_path):
    p = tmp_path / "runlog.jsonl"
    _write_steps(p, 2)
    with open(p, "a") as f:
        f.write('{"schema": 1, "kind": "st')      # crash mid-write
    recs = runlog.read_runlog(str(p))             # strict, still fine
    assert sum(r["kind"] == "step" for r in recs) == 2


def test_runlog_refuses_invalid_writes(tmp_path):
    with runlog.RunLogger(str(tmp_path / "r.jsonl")) as log:
        with pytest.raises(runlog.RunlogError):
            log.log("no_such_kind")
        with pytest.raises(runlog.RunlogError):
            log.log("resume")                     # missing resumed_from


def test_report_cli_and_summary(tmp_path, capsys):
    p = tmp_path / "runlog.jsonl"
    _write_steps(p, 10, arch="basic-s")
    assert report.main([str(p)]) == 0
    out = capsys.readouterr().out
    assert "10 step records" in out and "p99" in out
    summary = report.summarize(runlog.read_runlog(str(p)))
    assert summary["loss"]["first"] == pytest.approx(5.0)
    assert summary["phases"]["device_step_s"]["p50"] == pytest.approx(0.01)
    # exact percentile helper matches numpy's linear convention
    vals = [1.0, 2.0, 10.0, 11.0]
    assert report._percentile(vals, 50) == pytest.approx(
        float(np.percentile(vals, 50)))
    # bad file -> non-zero
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"schema": 9, "kind": "step", "t": 0}\n' * 2)
    assert report.main([str(bad)]) == 1


def test_committed_runlog_sample_validates():
    """The committed artifacts/runlog_sample.jsonl (a real smoke-run
    output) stays valid under the schema gate — drift in the runlog
    format shows up here, not in a consumer's dashboard."""
    sample = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                          "runlog_sample.jsonl")
    assert check_runlog.check_file(sample) == []
    recs = runlog.read_runlog(sample)
    steps = [r for r in recs if r["kind"] == "step"]
    assert steps, "sample must contain step records"
    for r in steps:
        for key in runlog.STEP_BREAKDOWN_KEYS:
            assert key in r
    assert check_runlog.main([sample]) == 0


# -- back-compat: one stats mechanism repo-wide ----------------------------
def test_batcher_stats_backcompat_registry_backed():
    from repro.serving.embed.batcher import MicroBatcher
    mb = MicroBatcher({"t": lambda b: np.asarray(b["x"], np.float32)},
                      buckets=(2, 4), autostart=False)
    mb.submit_many("t", {"x": np.ones((3, 2), np.float32)})
    mb.flush_now()
    # legacy dict shape intact...
    assert mb.stats["requests"] == 3
    assert mb.stats["manual_flushes"] == 1
    assert mb.stats["encoded_examples"] == 3
    assert mb.stats["padded_examples"] == 1        # 3 -> bucket 4
    # ...and the SAME numbers come from the registry
    snap = mb.metrics.snapshot()
    assert snap["counters"]["serve/requests"] == 3
    assert snap["histograms"]["serve/batch_occupancy"]["count"] == 1
    assert snap["histograms"]["serve/request_latency_s"]["count"] == 1
    assert snap["gauges"]["serve/queue_depth"] == 0.0
    mb.stop()


def test_manager_stats_backcompat_registry_backed(tmp_path):
    from repro.checkpoint.manager import AsyncCheckpointManager
    with AsyncCheckpointManager(str(tmp_path), sync=True) as m:
        m.save(1, {"w": np.ones(4, np.float32)})
        assert m.stats["saves"] == 1 and m.stats["sync_saves"] == 1
        snap = m.metrics.snapshot()
        assert snap["counters"]["ckpt/saves"] == 1
        assert snap["histograms"]["ckpt/write_latency_s"]["count"] == 1
        assert snap["gauges"]["ckpt/last_stall_s"] > 0
        m.degrade_to_sync()                        # already sync: no-op
        assert m.stats["degraded"] == 0
        m.sync = False
        m.degrade_to_sync()
        assert m.sync and m.stats["degraded"] == 1


def test_shared_registry_across_subsystems(tmp_path):
    """One run registry can host batcher + manager series side by side."""
    from repro.checkpoint.manager import AsyncCheckpointManager
    from repro.serving.embed.batcher import MicroBatcher
    reg = metrics.Registry()
    mb = MicroBatcher({"t": lambda b: np.asarray(b["x"], np.float32)},
                      buckets=(2,), autostart=False, registry=reg)
    mb.submit_many("t", {"x": np.ones((2, 2), np.float32)})
    mb.flush_now()
    with AsyncCheckpointManager(str(tmp_path), sync=True,
                                registry=reg) as m:
        m.save(1, {"w": np.ones(2, np.float32)})
    counters = reg.snapshot()["counters"]
    assert counters["serve/requests"] == 2 and counters["ckpt/saves"] == 1
    mb.stop()


def test_report_serving_snapshot_rendering(tmp_path, capsys):
    """--serving renders serve/retrieval_* series: per-stage latency,
    prune ratio, shard skew, and serve/ counters; unwraps a full
    ZeroShotService.stats() dict via its "metrics" key."""
    reg = metrics.Registry()
    for stage, v in (("coarse", 0.002), ("rerank", 0.05), ("total", 0.06)):
        reg.histogram("serve/retrieval_latency_s", stage=stage).observe(v)
    pr = reg.histogram("serve/retrieval_prune_ratio",
                       buckets=metrics.RATIO_BUCKETS)
    pr.observe(0.06)
    pr.observe(0.10)
    reg.histogram("serve/retrieval_shard_share",
                  buckets=metrics.RATIO_BUCKETS).observe(0.25)
    reg.counter("serve/gallery_uploads").inc()

    stats = {"retrieval_mode": "twostage", "metrics": reg.snapshot()}
    text = report.format_serving(stats)
    assert "stage=rerank" in text
    assert "prune ratio" in text and "mean 0.080" in text
    assert "shard skew" in text and "0.250" in text
    assert "serve/gallery_uploads=1" in text

    p = tmp_path / "stats.json"
    p.write_text(json.dumps(stats))
    assert report.main(["--serving", str(p)]) == 0
    assert "prune ratio" in capsys.readouterr().out

    assert report.format_serving({"histograms": {}, "counters": {}}) == (
        "no serve/retrieval_* series in snapshot")
