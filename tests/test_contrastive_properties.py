"""Hypothesis property tests for the contrastive loss (paper §3).

Kept separate from test_contrastive.py and guarded with ``importorskip`` so
the suite collects cleanly on bare environments without ``hypothesis``; the
property tests still run wherever it is installed.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as hst  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core.contrastive import contrastive_loss  # noqa: E402


def _unit(rng, b, d):
    z = rng.standard_normal((b, d)).astype(np.float32)
    return jnp.asarray(z / np.linalg.norm(z, axis=1, keepdims=True))


@settings(max_examples=25, deadline=None)
@given(b=hst.integers(2, 24), d=hst.integers(2, 32),
       seed=hst.integers(0, 2**30), log_tau=hst.floats(-3.0, 1.0))
def test_loss_nonnegative_and_symmetric(b, d, seed, log_tau):
    """Properties: loss >= 0 (diag is one of the LSE terms); swapping the
    modalities leaves the loss invariant (row<->col exchange)."""
    rng = np.random.default_rng(seed)
    x, y = _unit(rng, b, d), _unit(rng, b, d)
    tau = float(np.exp(log_tau))
    l1, _ = contrastive_loss(x, y, tau)
    l2, _ = contrastive_loss(y, x, tau)
    assert float(l1) >= -1e-5
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=hst.integers(0, 2**30))
def test_permutation_invariance(seed):
    """Permuting the pair order must not change the loss."""
    rng = np.random.default_rng(seed)
    x, y = _unit(rng, 12, 8), _unit(rng, 12, 8)
    perm = rng.permutation(12)
    l1, _ = contrastive_loss(x, y, 0.3)
    l2, _ = contrastive_loss(x[perm], y[perm], 0.3)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5, atol=1e-6)
