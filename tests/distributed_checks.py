"""Standalone multi-device checks for core/distributed_loss.py, the
sharded data subsystem (data/sharded/, DESIGN.md §9), and the checkpoint
fault-tolerance harness (checkpoint/, DESIGN.md §10).

Run by tests/test_distributed_loss.py / tests/test_sharded_loader.py /
tests/test_fault_tolerance.py in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the tier-1 pytest
process pins the single real CPU device — see tests/conftest.py — and jax
locks the device count at first init, so multi-shard meshes need their own
process). ``loss``/``gradaccum`` assert the cross-shard GLOBAL-batch loss
and its dX/dY/dτ gradients are bit-close to the single-device fused loss at
the same global batch; ``sharded_data`` asserts the two-host loader
reassembles bit-exactly, device assembly places the right rows on the right
shards, and a checkpoint-resumed loader replays the identical batch
sequence. ``ckpt_fault`` is the kill-and-recover acceptance check: a
training run hard-killed MID-CHECKPOINT-WRITE (``ckpt_victim`` grandchild
process, ``os._exit`` via the write fault hook — SIGKILL-equivalent), with
its newest surviving checkpoint then bit-rotted, must auto-resume from the
newest VERIFIED step and replay the uninterrupted run's per-step losses
bit-exactly; ditto a SIGTERM-preempted run.

Usage:  python tests/distributed_checks.py
            {loss|gradaccum|sharded_data|ckpt_fault|ckpt_victim CKPT_DIR}
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import sys                                                       # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                       # noqa: E402
import jax.numpy as jnp                                          # noqa: E402
import numpy as np                                               # noqa: E402

from repro.core import distributed_loss as dl                    # noqa: E402
from repro.core.contrastive import fused_kernel_loss             # noqa: E402


def _unit_rows(key, shape):
    z = jax.random.normal(key, shape, jnp.float32)
    return z / jnp.linalg.norm(z, axis=-1, keepdims=True)


def check_loss_equivalence():
    """Acceptance: data-axis size >= 2 mesh, both methods, loss and grads
    match the single-device fused loss at the same global batch (fp32)."""
    b, d = 256, 64
    kx, ky = jax.random.split(jax.random.key(7))
    x, y = _unit_rows(kx, (b, d)), _unit_rows(ky, (b, d))
    tau = jnp.asarray(0.31)

    def ref(x, y, tau):
        return fused_kernel_loss(x, y, tau, interpret=True)[0]

    ref_loss, ref_g = jax.value_and_grad(ref, argnums=(0, 1, 2))(x, y, tau)

    meshes = [
        jax.make_mesh((8,), ("data",)),                  # pure data parallel
        jax.make_mesh((4, 2), ("data", "model")),        # data x tensor
        jax.make_mesh((2, 2, 2), ("pod", "data", "model")),  # multi-pod
    ]
    for mesh in meshes:
        for method in dl.METHODS:
            loss_fn = dl.make_global_loss_fn(mesh, method)

            def f(x, y, tau):
                return loss_fn(x, y, tau)[0]

            with mesh:
                loss, g = jax.jit(jax.value_and_grad(
                    f, argnums=(0, 1, 2)))(x, y, tau)
            tag = f"{dict(mesh.shape)}/{method}"
            np.testing.assert_allclose(loss, ref_loss, rtol=2e-6, atol=2e-6,
                                       err_msg=f"{tag} loss")
            for got, want, name in zip(g, ref_g, ("dX", "dY", "dtau")):
                np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6,
                                           err_msg=f"{tag} {name}")
            print(f"ok {tag}")

    # bf16 embeddings (fp32 accumulation inside the kernels): compare the
    # two distributed methods against the single-device fused loss on the
    # SAME bf16 inputs — rounding of the inputs is shared, paths must agree
    xb, yb = x.astype(jnp.bfloat16), y.astype(jnp.bfloat16)
    ref_loss16, ref_g16 = jax.value_and_grad(ref, argnums=(0, 1, 2))(
        xb, yb, tau)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for method in dl.METHODS:
        loss_fn = dl.make_global_loss_fn(mesh, method)
        with mesh:
            loss, g = jax.jit(jax.value_and_grad(
                lambda x, y, t: loss_fn(x, y, t)[0],
                argnums=(0, 1, 2)))(xb, yb, tau)
        np.testing.assert_allclose(loss, ref_loss16, rtol=1e-3, atol=1e-4,
                                   err_msg=f"bf16 {method} loss")
        np.testing.assert_allclose(
            g[0].astype(jnp.float32), ref_g16[0].astype(jnp.float32),
            rtol=2e-2, atol=1e-4, err_msg=f"bf16 {method} dX")
        print(f"ok bf16 {method}")


def check_gradaccum_composition():
    """The full Algorithm-1 step with the cross-shard loss (GradAccum x
    data-parallel x tensor-parallel under one jit) produces the same
    weight gradients as the single-device step at the same global batch."""
    from repro.configs import get_arch, smoke_dual_variant
    from repro.core.gradaccum import contrastive_step
    from repro.data import Tokenizer, caption_corpus, contrastive_batch, \
        world_for_tower
    from repro.models import dual_encoder as de

    cfg = smoke_dual_variant(get_arch("basic-s"))
    rng = np.random.default_rng(0)
    world = world_for_tower(rng, cfg.image_tower, n_classes=8, noise=0.2)
    tok = Tokenizer.train(caption_corpus(world, rng, 200), vocab_size=300)
    batch, _ = contrastive_batch(world, tok, 32, rng)
    batch = jax.tree.map(jnp.asarray, batch)
    params = de.init_params(cfg, jax.random.key(0))

    def enc_i(p, im):
        return de.encode_image(cfg, p, im)

    def enc_t(p, tx):
        return de.encode_text(cfg, p, tx)

    l_ref, _, g_ref = jax.jit(lambda p, b: contrastive_step(
        enc_i, enc_t, p, b, 2))(params, batch)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for method in dl.METHODS:
        loss_fn = dl.make_global_loss_fn(mesh, method)
        with mesh:
            l_dist, _, g_dist = jax.jit(lambda p, b: contrastive_step(
                enc_i, enc_t, p, b, 2, loss_fn=loss_fn,
                emb_sharding=dl.emb_sharding(mesh)))(params, batch)
        np.testing.assert_allclose(l_dist, l_ref, rtol=2e-5, atol=2e-6,
                                   err_msg=f"{method} loss")
        flat_ref = jax.tree_util.tree_leaves_with_path(g_ref)
        flat_dist = dict(jax.tree_util.tree_leaves_with_path(g_dist))
        for path, want in flat_ref:
            got = flat_dist[path]
            np.testing.assert_allclose(
                got, want, rtol=5e-4, atol=1e-5,
                err_msg=f"{method} grad {jax.tree_util.keystr(path)}")
        print(f"ok gradaccum {method}")


def check_sharded_data():
    """Acceptance (ISSUE-5): (1) the two simulated hosts' local shards
    concatenate BIT-EXACTLY to the single-host global batch, augmentation
    included; (2) ``device_put_global`` lays block h onto data shard h of
    an 8-way mesh with global content equal to the host-side batch; (3) a
    contrastive trainer run that checkpoints, stops, and resumes (loader
    state restored from checkpoint user-meta) reproduces the uninterrupted
    run's per-step losses exactly."""
    import tempfile
    import types

    from repro.data import make_world
    from repro.data.sharded import (HostLayout, ShardedLoader,
                                    default_augmentations, device_put_global,
                                    load_tokenizer)

    world = make_world(np.random.default_rng(3), n_classes=16)
    tok = load_tokenizer()
    aug = default_augmentations()

    # (1) two-host reassembly, clean and augmented
    for augment in ((), aug):
        hosts = [ShardedLoader(world, tok, 32, layout=HostLayout(2, h),
                               seed=11, augment=augment) for h in (0, 1)]
        oracle = ShardedLoader(world, tok, 32, layout=HostLayout(2, 0),
                               seed=11, augment=augment)
        for step in (0, 1, 5):
            want = oracle.global_batch_at(step)
            got = jax.tree.map(
                lambda *xs: np.concatenate(xs, axis=0),
                *[h.local_batch_at(step) for h in hosts])
            for path, a in jax.tree_util.tree_leaves_with_path(want):
                b = dict(jax.tree_util.tree_leaves_with_path(got))[path]
                np.testing.assert_array_equal(a, b)
    print("ok two-host reassembly (clean + augmented)")

    # (2) device assembly on an 8-way data mesh: block h -> shard h
    mesh = jax.make_mesh((8,), ("data",))
    loader = ShardedLoader(world, tok, 32, layout=HostLayout(8, 0),
                           seed=11, augment=aug)
    host_batch = loader.global_batch_at(0)
    arrs = device_put_global(host_batch, mesh)
    img = arrs["images"]["image"]
    assert img.sharding.is_fully_addressable
    np.testing.assert_array_equal(np.asarray(img),
                                  host_batch["images"]["image"])
    shards = sorted(img.addressable_shards, key=lambda s: s.index[0].start)
    assert len(shards) == 8
    for h, s in enumerate(shards):
        block = ShardedLoader(world, tok, 32, layout=HostLayout(8, h),
                              seed=11, augment=aug).local_batch_at(0)
        np.testing.assert_array_equal(np.asarray(s.data),
                                      block["images"]["image"])
    print("ok device assembly block->shard")

    # (3) trainer-level resume: full run == stop@2 + resume, exact losses
    from repro.launch.train_distributed import train
    base = dict(arch="basic-s", smoke=True, objective="contrastive",
                steps=4, batch=64, seq=16, lr=1e-3, seed=0,
                sharding="basic_ws", remat="basic", model_parallel=1,
                num_micro=2, loss="chunked", augment="on", tokenizer="v1",
                log_every=100, ckpt_dir=None, ckpt_every=0, stop_after=None)
    full = train(types.SimpleNamespace(**base))
    with tempfile.TemporaryDirectory() as d:
        ck = dict(base, ckpt_dir=d)
        train(types.SimpleNamespace(**dict(ck, stop_after=2)))
        resumed = train(types.SimpleNamespace(**ck))
    np.testing.assert_allclose(resumed, full[2:], rtol=1e-5)
    print("ok trainer resume replays the batch sequence")


_TRAIN_BASE = dict(arch="basic-s", smoke=True, objective="contrastive",
                   steps=6, batch=64, seq=16, lr=1e-3, seed=0,
                   sharding="basic_ws", remat="basic", model_parallel=1,
                   num_micro=2, loss="chunked", augment="on", tokenizer="v1",
                   log_every=100, ckpt_dir=None, ckpt_every=0,
                   stop_after=None)

_VICTIM_KILL_STEP = 4     # die during the 2nd file-write of this step's save
_VICTIM_EXIT = 17


def run_ckpt_victim(ckpt_dir):
    """Grandchild process of the ckpt_fault check: train with async
    per-step checkpointing, then die by ``os._exit`` (no cleanup — the
    SIGKILL/preemption stand-in) in the middle of writing step
    ``_VICTIM_KILL_STEP``'s checkpoint, leaving a torn ``.tmp_ckpt_*``
    behind. Never returns."""
    import types

    from repro.checkpoint import faults, io
    from repro.launch.train_distributed import train

    orig = io.write_snapshot

    def dying_write(directory, step, arrs, treedef, meta=None):
        if step == _VICTIM_KILL_STEP:
            # allow one leaf file, then os._exit on the next write: the
            # tmp dir is left torn, exactly like a mid-save preemption
            with faults.exit_during_write(after=1, code=_VICTIM_EXIT):
                return orig(directory, step, arrs, treedef, meta=meta)
        return orig(directory, step, arrs, treedef, meta=meta)

    io.write_snapshot = dying_write
    train(types.SimpleNamespace(**dict(_TRAIN_BASE, ckpt_dir=ckpt_dir,
                                       ckpt_every=1)))
    raise SystemExit("victim survived training — kill hook never fired")


def check_ckpt_fault():
    """Acceptance (ISSUE-6): (1) a run hard-killed mid-checkpoint-write
    leaves completed steps plus a torn tmp dir; (2) after the newest
    completed checkpoint is additionally bit-rotted, ``--resume auto``
    lands on the older verified step (GC'ing the torn tmp) and the resumed
    run replays the uninterrupted run's per-step losses BIT-EXACTLY on the
    8-device mesh; (3) a SIGTERM-preempted run writes a final sync
    checkpoint after the in-flight step and resumes bit-exactly too."""
    import glob
    import subprocess
    import tempfile
    import types

    from repro import checkpoint as ckpt
    from repro.checkpoint import faults
    from repro.launch.train_distributed import train

    full = train(types.SimpleNamespace(**_TRAIN_BASE))
    print(f"uninterrupted run: {len(full)} steps")

    with tempfile.TemporaryDirectory() as d:
        # (1) kill a training run in the middle of a checkpoint write
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "ckpt_victim", d],
            capture_output=True, text=True, timeout=900, env=dict(os.environ))
        assert proc.returncode == _VICTIM_EXIT, (
            f"victim exit {proc.returncode}\n--- stdout ---\n{proc.stdout}"
            f"\n--- stderr ---\n{proc.stderr[-3000:]}")
        torn = glob.glob(os.path.join(d, ".tmp_ckpt_*"))
        assert torn, "kill mid-write must leave a torn tmp dir"
        assert ckpt.latest_step(d) == _VICTIM_KILL_STEP - 1
        print(f"ok victim killed mid-write of step {_VICTIM_KILL_STEP} "
              f"(torn tmp: {os.path.basename(torn[0])})")

        # (2) bit-rot the newest completed checkpoint: auto-resume must
        # skip it to the older verified step and GC the torn tmp
        faults.flip_byte(d, _VICTIM_KILL_STEP - 1)
        good = _VICTIM_KILL_STEP - 2
        assert ckpt.latest_verified_step(d, gc=False) == good
        resumed = train(types.SimpleNamespace(**dict(_TRAIN_BASE,
                                                     ckpt_dir=d)))
        assert not glob.glob(os.path.join(d, ".tmp_ckpt_*")), \
            "resume must GC the torn tmp dir"
        np.testing.assert_array_equal(
            np.asarray(resumed, np.float64),
            np.asarray(full[good:], np.float64),
            err_msg="killed+resumed losses must be bit-exact vs "
                    "uninterrupted")
        print(f"ok resume skipped corrupt step {_VICTIM_KILL_STEP - 1} -> "
              f"{good}; {len(resumed)} resumed losses bit-exact")

    # (3) SIGTERM preemption: final sync checkpoint + bit-exact resume
    with tempfile.TemporaryDirectory() as d:
        pre = train(types.SimpleNamespace(**dict(_TRAIN_BASE, ckpt_dir=d,
                                                 preempt_after=2)))
        assert len(pre) == 2 and ckpt.latest_verified_step(d) == 2
        resumed = train(types.SimpleNamespace(**dict(_TRAIN_BASE,
                                                     ckpt_dir=d)))
        np.testing.assert_array_equal(
            np.asarray(pre + resumed, np.float64),
            np.asarray(full, np.float64),
            err_msg="SIGTERM-preempted + resumed losses must be bit-exact")
    print("ok SIGTERM preemption checkpoint + bit-exact resume")


def check_retrieval():
    """Acceptance (ISSUE-9): the mesh-sharded similarity→top-k serving
    path is BIT-IDENTICAL to the stable-argsort oracle (and the
    single-device kernel) on 4-device, 8-device, and 2x4 pod×data meshes —
    including exact ties and duplicate rows straddling shard boundaries,
    ragged N (last shard partially padded), n so small that whole shards
    are dead padding, and bf16 inputs. Then: the ZeroShotService wired to
    retrieval='sharded' classifies identically to the 'fused' service, a
    prepared gallery is uploaded once, and k>n clamps / k<1 raises on the
    sharded path."""
    from repro.kernels.similarity_topk import ops as topk_ops
    from repro.kernels.similarity_topk import ref as topk_ref
    from repro.serving import retrieval as rtv

    b, d, k = 9, 32, 7
    kx = jax.random.key(23)
    x = _unit_rows(kx, (b, d))
    meshes = [
        jax.make_mesh((4,), ("data",)),
        jax.make_mesh((8,), ("data",)),
        jax.make_mesh((2, 4), ("pod", "data")),   # multi-axis linear index
    ]

    def oracle(x, c, kk):
        v, i = topk_ref.similarity_topk_ref(jnp.asarray(x, jnp.float32),
                                            jnp.asarray(c, jnp.float32), kk)
        return np.asarray(v), np.asarray(i)

    rng = np.random.default_rng(5)
    for mesh in meshes:
        s = int(np.prod([mesh.shape[a] for a in mesh.shape]))
        tag = dict(mesh.shape)
        # n sweeps: ragged tails, exact multiples, and n < S*k (k=7, S*64
        # n_local floor -> every shard but the first is 100% padding)
        for n in (40, 257, 64 * s, 64 * s + 1, 1000):
            # duplicates + exact ties EVERYWHERE, including straddling
            # shard boundaries: every row drawn from a 17-row dictionary,
            # so each boundary [n_local*r - 1, n_local*r] pair collides
            # with near-certainty and every top-k is a tie-break decision
            dic = np.asarray(_unit_rows(jax.random.key(n), (17, d)))
            c = dic[rng.integers(0, 17, n)]
            kk = min(k, n)
            want_v, want_i = oracle(x, c, kk)
            sm = rtv.shard_matrix(jnp.asarray(c), mesh)
            got_v, got_i = rtv.sharded_similarity_topk(x, sm, kk,
                                                       interpret=True)
            np.testing.assert_array_equal(
                np.asarray(got_i), want_i,
                err_msg=f"{tag} n={n}: sharded indices != oracle")
            np.testing.assert_array_equal(
                np.asarray(got_v), want_v,
                err_msg=f"{tag} n={n}: sharded values != oracle")
        print(f"ok sharded==oracle {tag} (ties/duplicates/ragged)")

    # bf16 inputs: compare against the single-device kernel on the SAME
    # bf16 arrays (shared input rounding; both paths accumulate fp32)
    mesh = meshes[1]
    n = 700
    c = _unit_rows(jax.random.key(41), (n, d))
    xb, cb = x.astype(jnp.bfloat16), c.astype(jnp.bfloat16)
    want_v, want_i = topk_ops.similarity_topk(xb, cb, k, interpret=True)
    sm = rtv.shard_matrix(cb, mesh)
    got_v, got_i = rtv.sharded_similarity_topk(xb, sm, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    print("ok sharded==fused on bf16 inputs")

    # k validation at the op level
    sm = rtv.shard_matrix(jnp.asarray(_unit_rows(jax.random.key(2),
                                                 (300, d))), mesh)
    for bad_k in (0, -3, 301):
        try:
            rtv.sharded_similarity_topk(x, sm, bad_k, interpret=True)
            raise AssertionError(f"k={bad_k} must raise")
        except ValueError:
            pass
    print("ok op-level k validation")

    # service level: sharded classify == fused classify, upload-once
    # gallery, k clamping, k<1 rejection
    import dataclasses as dc

    from repro.configs import get_arch, smoke_variant
    from repro.data import Tokenizer, caption_corpus, world_for_tower
    from repro.data.synthetic import render_images
    from repro.models import dual_encoder as de
    from repro.serving import ZeroShotService

    cfg = get_arch("basic-s")
    cfg = dc.replace(cfg, image_tower=smoke_variant(cfg.image_tower),
                     text_tower=smoke_variant(cfg.text_tower), embed_dim=32)
    rng = np.random.default_rng(0)
    world = world_for_tower(rng, cfg.image_tower, n_classes=10, noise=0.2)
    tok = Tokenizer.train(caption_corpus(world, rng, 300), vocab_size=400)
    params = de.init_params(cfg, jax.random.key(0))
    imgs = render_images(world, rng.integers(0, 10, 6), rng)

    with ZeroShotService(cfg, params, tok, max_delay_ms=1.0,
                         retrieval="fused") as svc:
        ref_res = svc.classify(imgs, world.class_names, k=5)
        gal = svc.embed_images(imgs)
    with ZeroShotService(cfg, params, tok, max_delay_ms=1.0,
                         retrieval="sharded") as svc:
        res = svc.classify(imgs, world.class_names, k=5)
        np.testing.assert_array_equal(res.indices, ref_res.indices)
        np.testing.assert_array_equal(res.values, ref_res.values)
        # k > n_classes clamps to n (10), never errors on the sharded path
        wide = svc.classify(imgs, world.class_names, k=64)
        assert wide.indices.shape == (6, 10)
        np.testing.assert_array_equal(wide.indices[:, :5], res.indices)
        try:
            svc.classify(imgs, world.class_names, k=0)
            raise AssertionError("k=0 must raise")
        except ValueError:
            pass
        # prepared gallery: one upload, many retrieves, clamped k
        handle = svc.prepare_gallery(gal)
        v1, i1 = svc.retrieve(["a photo"], handle, k=64)
        v2, i2 = svc.retrieve(["a photo"], handle, k=64)
        assert i1.shape == (1, 6)       # clamped to the 6-row gallery
        np.testing.assert_array_equal(i1, i2)
        snap = svc.metrics.snapshot()
        assert snap["counters"]["serve/gallery_uploads"] == 1
        shares = [key for key in snap["histograms"]
                  if key.startswith("serve/retrieval_shard_share")]
        assert shares, snap["histograms"].keys()
    print("ok service-level sharded parity + gallery handle + k clamps")


if __name__ == "__main__":
    mode = sys.argv[1] if len(sys.argv) > 1 else "loss"
    if mode == "ckpt_victim":
        run_ckpt_victim(sys.argv[2])
    assert jax.device_count() >= 8, jax.devices()
    {"loss": check_loss_equivalence,
     "gradaccum": check_gradaccum_composition,
     "sharded_data": check_sharded_data,
     "ckpt_fault": check_ckpt_fault,
     "retrieval": check_retrieval}[mode]()
    print(f"PASS {mode}")
