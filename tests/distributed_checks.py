"""Standalone multi-device checks for core/distributed_loss.py.

Run by tests/test_distributed_loss.py in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the tier-1 pytest
process pins the single real CPU device — see tests/conftest.py — and jax
locks the device count at first init, so multi-shard meshes need their own
process). Each check asserts the cross-shard GLOBAL-batch loss and its
dX/dY/dτ gradients are bit-close to the single-device fused loss at the
same global batch.

Usage:  python tests/distributed_checks.py {loss|gradaccum}
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import sys                                                       # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                       # noqa: E402
import jax.numpy as jnp                                          # noqa: E402
import numpy as np                                               # noqa: E402

from repro.core import distributed_loss as dl                    # noqa: E402
from repro.core.contrastive import fused_kernel_loss             # noqa: E402


def _unit_rows(key, shape):
    z = jax.random.normal(key, shape, jnp.float32)
    return z / jnp.linalg.norm(z, axis=-1, keepdims=True)


def check_loss_equivalence():
    """Acceptance: data-axis size >= 2 mesh, both methods, loss and grads
    match the single-device fused loss at the same global batch (fp32)."""
    b, d = 256, 64
    kx, ky = jax.random.split(jax.random.key(7))
    x, y = _unit_rows(kx, (b, d)), _unit_rows(ky, (b, d))
    tau = jnp.asarray(0.31)

    def ref(x, y, tau):
        return fused_kernel_loss(x, y, tau, interpret=True)[0]

    ref_loss, ref_g = jax.value_and_grad(ref, argnums=(0, 1, 2))(x, y, tau)

    meshes = [
        jax.make_mesh((8,), ("data",)),                  # pure data parallel
        jax.make_mesh((4, 2), ("data", "model")),        # data x tensor
        jax.make_mesh((2, 2, 2), ("pod", "data", "model")),  # multi-pod
    ]
    for mesh in meshes:
        for method in dl.METHODS:
            loss_fn = dl.make_global_loss_fn(mesh, method)

            def f(x, y, tau):
                return loss_fn(x, y, tau)[0]

            with mesh:
                loss, g = jax.jit(jax.value_and_grad(
                    f, argnums=(0, 1, 2)))(x, y, tau)
            tag = f"{dict(mesh.shape)}/{method}"
            np.testing.assert_allclose(loss, ref_loss, rtol=2e-6, atol=2e-6,
                                       err_msg=f"{tag} loss")
            for got, want, name in zip(g, ref_g, ("dX", "dY", "dtau")):
                np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6,
                                           err_msg=f"{tag} {name}")
            print(f"ok {tag}")

    # bf16 embeddings (fp32 accumulation inside the kernels): compare the
    # two distributed methods against the single-device fused loss on the
    # SAME bf16 inputs — rounding of the inputs is shared, paths must agree
    xb, yb = x.astype(jnp.bfloat16), y.astype(jnp.bfloat16)
    ref_loss16, ref_g16 = jax.value_and_grad(ref, argnums=(0, 1, 2))(
        xb, yb, tau)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for method in dl.METHODS:
        loss_fn = dl.make_global_loss_fn(mesh, method)
        with mesh:
            loss, g = jax.jit(jax.value_and_grad(
                lambda x, y, t: loss_fn(x, y, t)[0],
                argnums=(0, 1, 2)))(xb, yb, tau)
        np.testing.assert_allclose(loss, ref_loss16, rtol=1e-3, atol=1e-4,
                                   err_msg=f"bf16 {method} loss")
        np.testing.assert_allclose(
            g[0].astype(jnp.float32), ref_g16[0].astype(jnp.float32),
            rtol=2e-2, atol=1e-4, err_msg=f"bf16 {method} dX")
        print(f"ok bf16 {method}")


def check_gradaccum_composition():
    """The full Algorithm-1 step with the cross-shard loss (GradAccum x
    data-parallel x tensor-parallel under one jit) produces the same
    weight gradients as the single-device step at the same global batch."""
    from repro.configs import get_arch, smoke_dual_variant
    from repro.core.gradaccum import contrastive_step
    from repro.data import Tokenizer, caption_corpus, contrastive_batch, \
        world_for_tower
    from repro.models import dual_encoder as de

    cfg = smoke_dual_variant(get_arch("basic-s"))
    rng = np.random.default_rng(0)
    world = world_for_tower(rng, cfg.image_tower, n_classes=8, noise=0.2)
    tok = Tokenizer.train(caption_corpus(world, rng, 200), vocab_size=300)
    batch, _ = contrastive_batch(world, tok, 32, rng)
    batch = jax.tree.map(jnp.asarray, batch)
    params = de.init_params(cfg, jax.random.key(0))

    def enc_i(p, im):
        return de.encode_image(cfg, p, im)

    def enc_t(p, tx):
        return de.encode_text(cfg, p, tx)

    l_ref, _, g_ref = jax.jit(lambda p, b: contrastive_step(
        enc_i, enc_t, p, b, 2))(params, batch)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for method in dl.METHODS:
        loss_fn = dl.make_global_loss_fn(mesh, method)
        with mesh:
            l_dist, _, g_dist = jax.jit(lambda p, b: contrastive_step(
                enc_i, enc_t, p, b, 2, loss_fn=loss_fn,
                emb_sharding=dl.emb_sharding(mesh)))(params, batch)
        np.testing.assert_allclose(l_dist, l_ref, rtol=2e-5, atol=2e-6,
                                   err_msg=f"{method} loss")
        flat_ref = jax.tree_util.tree_leaves_with_path(g_ref)
        flat_dist = dict(jax.tree_util.tree_leaves_with_path(g_dist))
        for path, want in flat_ref:
            got = flat_dist[path]
            np.testing.assert_allclose(
                got, want, rtol=5e-4, atol=1e-5,
                err_msg=f"{method} grad {jax.tree_util.keystr(path)}")
        print(f"ok gradaccum {method}")


if __name__ == "__main__":
    mode = sys.argv[1] if len(sys.argv) > 1 else "loss"
    assert jax.device_count() >= 8, jax.devices()
    {"loss": check_loss_equivalence,
     "gradaccum": check_gradaccum_composition}[mode]()
    print(f"PASS {mode}")
