"""Cross-shard global-batch contrastive loss bench (DESIGN.md §7.5).

Times one full loss+gradient evaluation at the same GLOBAL batch three
ways — multi-host simulated via a local host-platform device mesh:

  dist_ref/...        single-device fused loss on the full global batch
                      (the oracle the distributed paths must reproduce;
                      also the host-drift ref anchor for check_bench)
  dist_allgather/...  shard_map all-gather variant: every shard computes
                      the full (B, B) problem redundantly
  dist_chunked/...    shard_map chunked-negatives variant: each shard
                      computes only its row block + column partials

The simulated mesh needs its own process (jax locks the device count at
first init), so ``run()`` re-executes this module in a subprocess with
``--xla_force_host_platform_device_count`` and collects the entries via
``--emit``. ``run(json_path=...)`` writes BENCH_distributed.json, the
committed perf trajectory gated by scripts/check_bench.py through
``benchmarks/run.py --json`` exactly like the kernel and serving benches.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

from benchmarks.common import csv_line, write_json  # noqa: F401 (run.py API)

R = 4                       # simulated data-parallel degree
SHAPES = [(2048, 256)]      # (global batch, embed dim)
ITERS = 3


def _bench_entries() -> dict:
    """Subprocess body: requires >= R simulated devices."""
    import jax
    import jax.numpy as jnp

    from repro.core import distributed_loss as dl
    from repro.core.contrastive import fused_kernel_loss

    assert jax.device_count() >= R, jax.devices()
    interpret = jax.default_backend() == "cpu"
    entries = {}
    for b, d in SHAPES:
        kx, ky = jax.random.split(jax.random.key(0))
        x = jax.random.normal(kx, (b, d), jnp.float32)
        x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
        y = jax.random.normal(ky, (b, d), jnp.float32)
        y = y / jnp.linalg.norm(y, axis=-1, keepdims=True)
        tau = jnp.asarray(0.3)

        def ref_loss(x, y, tau):
            return fused_kernel_loss(x, y, tau, interpret=interpret)[0]

        mesh = jax.make_mesh((R,), ("data",))
        fns = {"dist_ref": jax.jit(jax.value_and_grad(
            ref_loss, argnums=(0, 1, 2)))}
        for method in dl.METHODS:
            loss_fn = dl.make_global_loss_fn(mesh, method,
                                             interpret=interpret)
            fns[f"dist_{method}"] = jax.jit(jax.value_and_grad(
                lambda x, y, t, loss_fn=loss_fn: loss_fn(x, y, t)[0],
                argnums=(0, 1, 2)))

        from benchmarks.common import timeit_min
        with mesh:
            for name, fn in fns.items():
                us = timeit_min(fn, x, y, tau, iters=ITERS)
                entry = {
                    "us": round(us, 1),
                    "desc": f"loss+grad global B={b} D={d} "
                            f"({'1 device' if name == 'dist_ref' else f'{R}-shard mesh'})",
                    # absolute timings of R threads time-slicing one host
                    # CPU jitter well past the 1.3x threshold run-to-run;
                    # only the intra-run must_beat below gates (the
                    # kernels bench owns the absolute perf trajectory)
                    "ungated": True,
                }
                if name == "dist_chunked":
                    # the whole point of the scheme: per-shard work drops
                    # R/2x vs computing the full problem on every shard —
                    # an intra-run invariant, immune to host drift
                    entry["must_beat"] = f"dist_allgather/R{R}_B{b}_D{d}"
                entries[f"{name}/R{R}_B{b}_D{d}"] = entry
    return entries


def run(json_path: str | None = None) -> dict:
    """Spawn the simulated-mesh bench subprocess, print CSV lines, return
    (and optionally write) the BENCH_distributed.json payload."""
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        emit = f.name
    try:
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={R}")
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.pathsep.join(
            [root, os.path.join(root, "src"),
             env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.distributed_bench",
             "--emit", emit],
            env=env, cwd=root, capture_output=True, text=True, timeout=1200)
        if proc.returncode != 0:
            raise RuntimeError(
                f"distributed_bench subprocess failed:\n{proc.stderr[-3000:]}")
        with open(emit) as f:
            entries = json.load(f)
    finally:
        os.unlink(emit)

    for name, e in sorted(entries.items()):
        csv_line(name, e["us"], e["desc"])
    payload = {
        "meta": {
            "bench": "distributed_contrastive_loss",
            # the subprocess is pinned to JAX_PLATFORMS=cpu: a simulated
            # mesh always measures host-CPU interpret mode, whatever
            # accelerator the parent process would default to
            "interpret": True,
            "backend": "cpu",
            "simulated_devices": R,
            "iters": ITERS,
        },
        "entries": entries,
    }
    if json_path:
        write_json(json_path, payload)
    return payload


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--emit", default=None,
                    help="(internal) run the bench in THIS process and "
                         "write raw entries to PATH — requires the "
                         "simulated-device XLA flag to be set")
    ap.add_argument("--json", default=None,
                    help="write the full BENCH payload to PATH")
    args = ap.parse_args()
    if args.emit:
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "src"))
        entries = _bench_entries()
        with open(args.emit, "w") as f:
            json.dump(entries, f)
        return
    run(args.json)


if __name__ == "__main__":
    main()
