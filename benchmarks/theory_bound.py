"""Paper §6 (Theorems 1-2): measured generalization gap of the normalized
contrastive loss vs contrastive batch size B — the empirical counterpart of
the O(1/sqrt(B)) bound — plus the bound-term values."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, tiny_dual_cfg, world_and_tok
from repro.core.theory import bound_terms, empirical_gap
from repro.core.gradaccum import contrastive_step
from repro.data import contrastive_batch
from repro.models import dual_encoder as de
from repro.optim import AdaFactorW, apply_updates


def run():
    cfg = tiny_dual_cfg()
    world, tok, _ = world_and_tok(cfg)
    m = 512  # train samples per row

    for B in (8, 32, 128):
        t0 = time.perf_counter()
        params = de.init_params(cfg, jax.random.key(0))
        opt = AdaFactorW()
        st = opt.init(params)
        enc_i = lambda p, im: de.encode_image(cfg, p, im)   # noqa: E731
        enc_t = lambda p, tx: de.encode_text(cfg, p, tx)    # noqa: E731

        @jax.jit
        def step(params, st, batch):
            loss, _, g = contrastive_step(enc_i, enc_t, params, batch, 2)
            up, st = opt.update(g, st, params, 2e-3)
            return apply_updates(params, up), st

        rng = np.random.default_rng(7)
        for _ in range(m // B):
            batch, _ = contrastive_batch(world, tok, B, rng)
            params, st = step(params, st, jax.tree.map(jnp.asarray, batch))

        # gap: normalized losses with a B-sized train batch vs big test pool
        trb, _ = contrastive_batch(world, tok, B, rng)
        teb, _ = contrastive_batch(world, tok, 512, rng)
        xtr = enc_i(params, jax.tree.map(jnp.asarray, trb["images"]))
        ytr = enc_t(params, jax.tree.map(jnp.asarray, trb["texts"]))
        xte = enc_i(params, jax.tree.map(jnp.asarray, teb["images"]))
        yte = enc_t(params, jax.tree.map(jnp.asarray, teb["texts"]))
        gap = empirical_gap(xtr, ytr, xte, yte)
        bt = bound_terms(cfg, params["image"], params["text"], m=m, B=B)
        us = (time.perf_counter() - t0) * 1e6
        csv_line(f"theory/B{B}", us,
                 f"emp_gap={gap:.4f};bound_B_term={bt['term_1_over_sqrt_2B']:.4f};"
                 f"gap_shape={bt['gap_shape']:.5f}")
