"""Continuous-batching decode bench (DESIGN.md §12.5).

The serving claim behind ``serving.continuous``: with >= slots-many
requests in flight, slot-packed decoding serves a request stream faster
than the legacy engine decoding requests ONE AT A TIME — the packed
(num_slots, 1) step streams the model weights once per token tick for
all slots, where the sequential loop streams them once per token per
request. This bench pins that on a fixed stream of 8 requests:

  prefill_ref/b1             one b=1 prompt prefill (the admission-path
                             unit cost) — a ``*_ref`` host-drift anchor
                             (scripts/check_bench.py)
  generate_ref/one_at_a_time legacy ``Engine.generate`` over the 8
                             requests sequentially (b=1 each): the
                             one-at-a-time serving baseline and second
                             ``*_ref`` anchor
  generate/continuous_s4     ``ContinuousEngine`` (num_slots=4) serving
                             the same 8 requests through its admission
                             queue. ``must_beat: generate_ref/
                             one_at_a_time`` — continuous batching must
                             outrun one-at-a-time decode at >=4
                             concurrent requests on every host
  step/packed_s4             one packed 4-slot decode step (per-slot
                             positions). UNGATED: sub-ms and jittery on
                             shared hosts; recorded for the trajectory

Committed as BENCH_decode.json and gated through ``benchmarks/run.py
--json``: absolute timings ride the 1.3x cross-run gate where they clear
the 50ms interpret floor; the must_beat invariant carries the
continuous-vs-sequential claim regardless of host speed.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, write_json
from repro.configs import get_arch, smoke_variant
from repro.models import transformer as tf
from repro.serving import ContinuousEngine, Engine

ARCH = "llama3.2-1b"
CACHE_LEN = 64
PROMPT_LEN = 8                # one length -> one prefill compile
MAX_NEW = 16
N_REQUESTS = 8
NUM_SLOTS = 4
REPEATS = 3                   # min-of-N (scheduler-noise robustness)
MOE = {"dispatch": "dense"}


def _min_of(fn, reps=REPEATS) -> float:
    """Min-of-reps wall time of ``fn()`` in µs."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(json_path: str | None = None):
    """Run the bench; optionally write the BENCH_decode.json payload."""
    cfg = smoke_variant(get_arch(ARCH))
    params = tf.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(4, cfg.vocab, (N_REQUESTS, PROMPT_LEN),
                           dtype=np.int32)
    entries: dict = {}

    # EOS never fires on the random-weight model in practice, but pin the
    # token count anyway so both engines decode exactly the same stream
    eos = -1

    legacy = Engine(cfg, params, cache_len=CACHE_LEN, moe_args=MOE,
                    eos_id=eos)
    legacy.generate(prompts[:1], MAX_NEW)            # warm: compile both

    us_prefill = round(_min_of(lambda: jax.block_until_ready(
        legacy._prefill(params, jnp.asarray(prompts[:1]))[0])), 1)
    entries["prefill_ref/b1"] = {"us": us_prefill}
    csv_line("decode/prefill_ref/b1", us_prefill, f"plen={PROMPT_LEN}")

    def one_at_a_time():
        for p in prompts:
            legacy.generate(p[None, :], MAX_NEW)

    us_seq = round(_min_of(one_at_a_time), 1)
    total_toks = N_REQUESTS * MAX_NEW
    entries["generate_ref/one_at_a_time"] = {
        "us": us_seq, "tok_per_s": round(total_toks / (us_seq / 1e6), 1)}
    csv_line("decode/generate_ref/one_at_a_time", us_seq,
             f"{total_toks / (us_seq / 1e6):.0f}tok/s")

    cont = ContinuousEngine(cfg, params, cache_len=CACHE_LEN,
                            num_slots=NUM_SLOTS, moe_args=MOE, eos_id=eos)
    reqs = [(p, MAX_NEW, i) for i, p in enumerate(prompts)]
    got = cont.run(reqs)                             # warm: compile all three
    assert all(got[i].size == MAX_NEW for i in range(N_REQUESTS)), \
        "bench stream must be EOS-free so both engines decode equal tokens"

    us_cont = round(_min_of(lambda: cont.run(reqs)), 1)
    entries["generate/continuous_s4"] = {
        "us": us_cont, "must_beat": "generate_ref/one_at_a_time",
        "tok_per_s": round(total_toks / (us_cont / 1e6), 1),
        "speedup_vs_one_at_a_time": round(us_seq / us_cont, 2)}
    csv_line("decode/generate/continuous_s4", us_cont,
             f"{us_seq / us_cont:.2f}x_vs_sequential")

    toks = jnp.asarray(prompts[:NUM_SLOTS, :1])
    pos = jnp.asarray(np.arange(NUM_SLOTS) + PROMPT_LEN, jnp.int32)
    caches = cont._caches
    step_fn = jax.jit(cont._step_impl)   # no donation: reusable input cache
    jax.block_until_ready(step_fn(params, caches, toks, pos)[0])   # warm
    us_step = round(_min_of(lambda: jax.block_until_ready(
        step_fn(params, caches, toks, pos)[0])), 1)
    entries["step/packed_s4"] = {"us": us_step, "ungated": True}
    csv_line("decode/step/packed_s4", us_step, f"slots={NUM_SLOTS}")

    result = {
        "meta": {
            "backend": jax.default_backend(),
            "interpret": True,       # CPU XLA decode: keep the 50ms floor
            "shape": {"arch": ARCH, "cache_len": CACHE_LEN,
                      "prompt_len": PROMPT_LEN, "max_new": MAX_NEW,
                      "n_requests": N_REQUESTS, "num_slots": NUM_SLOTS},
        },
        "entries": entries,
    }
    if json_path:
        write_json(json_path, result)
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_decode.json-style output here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(json_path=args.json)


if __name__ == "__main__":
    main()
