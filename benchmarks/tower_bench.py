"""Tower-runtime perf bench: the encode hot path under each attention
backend (DESIGN.md §8).

One bidirectional encoder tower (the BASIC text-tower shape class: 2 scanned
layers, d_model=256, 4 heads × head_dim 64, bf16 precision policy) encodes a
(b=4, s=1024) token batch — long enough that attention dominates — through
``models.attention``'s three backends:

  encode_ref/{fwd,grad}   : impl='naive' — materialized (s, s) scores, the
                            paper-era baseline and the host-drift anchor
                            (scripts/check_bench.py ``*_ref`` convention)
  encode/chunked_{fwd,grad}: flash-style XLA blocks
  encode/pallas_{fwd,grad} : kernels/flash_attention fwd + custom-VJP bwd
                            (interpret mode on CPU hosts)

The committed invariant (BENCH_tower.json, gated via benchmarks/run.py
--json): ``encode/pallas_fwd`` carries ``must_beat: encode_ref/fwd`` — the
kernel-backed encode must stay strictly faster than naive at the bench
shape on every host (measured margin ~1.8x). The chunked and grad entries
ride without a must_beat: their margins over naive (~1.1-1.2x — the
backward is dominated by the towers' FFN/VJP work) sit inside scheduler
jitter and would flap the gate; the trajectory still records them and the
1.3x cross-run gate still applies.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, timeit_min, write_json
from repro.configs.base import ArchConfig
from repro.models import transformer as tf

B, S, D, H = 4, 1024, 256, 4
BLOCK = 512
PRECISION = "bf16"


def bench_cfg(impl: str) -> ArchConfig:
    """The bench tower at attention backend ``impl``."""
    return ArchConfig(
        name=f"tower-bench-{impl}", family="encoder", n_layers=2, d_model=D,
        n_heads=H, n_kv_heads=H, d_ff=2 * D, vocab=512, head_dim=D // H,
        causal=False, attn_impl=impl, attn_block=BLOCK, rope_theta=1e4,
        source="bench")


def _entries(entries: dict):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 512, (B, S)), jnp.int32)}
    ref_fwd = ref_grad = None
    for impl in ("naive", "chunked", "pallas"):
        cfg = bench_cfg(impl)
        params = tf.init_params(cfg, jax.random.key(0))
        enc = jax.jit(lambda p, bt, cfg=cfg: tf.encode(
            cfg, p, bt, precision=PRECISION))

        def loss(p, bt, cfg=cfg):
            return jnp.sum(tf.encode(cfg, p, bt, precision=PRECISION) ** 2)

        grad = jax.jit(jax.grad(loss))
        us_f = round(timeit_min(enc, params, batch, iters=3), 1)
        us_g = round(timeit_min(grad, params, batch, iters=3), 1)
        if impl == "naive":
            ref_fwd, ref_grad = us_f, us_g
            entries["encode_ref/fwd"] = {"us": us_f}
            entries["encode_ref/grad"] = {"us": us_g}
            csv_line("tower/encode_ref/fwd", us_f, "naive baseline")
            csv_line("tower/encode_ref/grad", us_g, "naive baseline")
            continue
        entries[f"encode/{impl}_fwd"] = {
            "us": us_f, "speedup_vs_naive": round(ref_fwd / us_f, 2)}
        if impl == "pallas":
            entries[f"encode/{impl}_fwd"]["must_beat"] = "encode_ref/fwd"
        entries[f"encode/{impl}_grad"] = {
            "us": us_g, "speedup_vs_naive": round(ref_grad / us_g, 2)}
        csv_line(f"tower/encode/{impl}_fwd", us_f,
                 f"{ref_fwd / us_f:.2f}x_vs_naive")
        csv_line(f"tower/encode/{impl}_grad", us_g,
                 f"{ref_grad / us_g:.2f}x_vs_naive")


def run(json_path: str | None = None):
    """Run the bench; optionally write the BENCH_tower.json payload."""
    entries: dict = {}
    _entries(entries)
    result = {
        "meta": {
            "backend": jax.default_backend(),
            "interpret": jax.default_backend() == "cpu",
            "shape": {"b": B, "s": S, "d_model": D, "heads": H,
                      "block": BLOCK, "precision": PRECISION},
        },
        "entries": entries,
    }
    if json_path:
        write_json(json_path, result)
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_tower.json-style output here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(json_path=args.json)


if __name__ == "__main__":
    main()
