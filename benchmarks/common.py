"""Shared benchmark helpers."""
import json
import time

import jax
import numpy as np


def write_json(path: str, payload: dict) -> None:
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")


def timeit(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out  # us


def timeit_min(fn, *args, iters=5):
    """Min-of-N µs/call after one compile+warm call — min is robust to
    scheduler interference, which the 1.3x regression gate
    (scripts/check_bench.py) must not trip on. The single timer every
    gated bench (kernels, serving, distributed) uses."""
    jax.block_until_ready(fn(*args))          # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


def tiny_dual_cfg(embed_dim=32):
    """CPU-sized basic-s dual encoder for benches (the shared
    configs.smoke_dual_variant transform)."""
    from repro.configs import get_arch, smoke_dual_variant
    return smoke_dual_variant(get_arch("basic-s"), embed_dim=embed_dim)


def world_and_tok(cfg, seed=0, n_classes=16, noise=0.25):
    """Bench world for a dual config + the committed v1 tokenizer artifact
    (benches tokenize exactly like train/serve/eval — one vocab)."""
    from repro.data import load_tokenizer, world_for_tower
    rng = np.random.default_rng(seed)
    world = world_for_tower(rng, cfg.image_tower, n_classes=n_classes,
                            noise=noise)
    return world, load_tokenizer(), rng


def csv_line(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
