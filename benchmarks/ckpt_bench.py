"""Checkpoint write/restore bench (DESIGN.md §10.5).

At paper scale (3B params checkpointed every few minutes for a week,
PAPER.md §5) a blocking save stalls the step for the full host-gather +
serialize + hash + rename; the AsyncCheckpointManager keeps only the host
snapshot on the step path. This bench measures, per save of an ~64 MiB
fp32 pytree:

  save_ref/blocking     full synchronous save (snapshot + np.save per leaf
                        + sha256 + atomic rename on the calling thread) —
                        the ``*_ref`` host-drift anchor
                        (scripts/check_bench.py)
  save/async_stall      the time ``save_async`` holds the train loop in
                        steady state (previous write joined first): the
                        snapshot only. ``must_beat: save_ref/blocking`` —
                        the whole point of the async path is that the step
                        stall drops below the blocking save on every host
  restore/latency       integrity-verified ``io.restore`` of the same tree
                        (read + reassemble). UNGATED: restore happens once
                        per (re)launch, not per step — recorded for the
                        trajectory, not raced

Committed as BENCH_ckpt.json and gated through ``benchmarks/run.py
--json``: absolute timings ride the 1.3x cross-run gate where they clear
the 50ms interpret floor; the must_beat invariant carries the async-vs-
blocking claim regardless of host speed.
"""
from __future__ import annotations

import argparse
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line, write_json
from repro import checkpoint as ckpt

N_LEAVES = 16
LEAF_SHAPE = (1024, 1024)     # 16 × 4 MiB fp32 = 64 MiB per checkpoint
REPEATS = 3                   # min-of-N (scheduler-noise robustness)
KEEP_LAST = 2                 # retention bounds bench disk usage


def _tree():
    """The checkpointed state: N_LEAVES device arrays, ~64 MiB total."""
    keys = jax.random.split(jax.random.key(0), N_LEAVES)
    return {f"w{i}": jax.random.normal(k, LEAF_SHAPE, jnp.float32)
            for i, k in enumerate(keys)}


def _min_of(fn, reps=REPEATS) -> float:
    """Min-of-reps wall time of ``fn()`` in µs."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(json_path: str | None = None):
    """Run the bench; optionally write the BENCH_ckpt.json payload."""
    tree = jax.block_until_ready(_tree())
    size_mb = sum(v.size * v.dtype.itemsize
                  for v in tree.values()) / 2 ** 20
    root = tempfile.mkdtemp(prefix="ckpt_bench_")
    entries: dict = {}
    try:
        step = iter(range(1, 10_000)).__next__

        with ckpt.AsyncCheckpointManager(root, sync=True,
                                         keep_last=KEEP_LAST) as m:
            m.save(step(), tree)                      # warm (page cache, jit)
            us_sync = round(_min_of(lambda: m.save(step(), tree)), 1)
        entries["save_ref/blocking"] = {"us": us_sync}
        csv_line("ckpt/save_ref/blocking", us_sync, f"{size_mb:.0f}MB")

        with ckpt.AsyncCheckpointManager(root, keep_last=KEEP_LAST) as m:
            m.save(step(), tree)                      # warm
            stalls = []
            for _ in range(REPEATS):
                m.wait()                              # steady state: no
                t0 = time.perf_counter()              # in-flight write to join
                m.save(step(), tree)
                stalls.append(time.perf_counter() - t0)
            us_async = round(min(stalls) * 1e6, 1)
        entries["save/async_stall"] = {
            "us": us_async, "must_beat": "save_ref/blocking",
            "stall_reduction_vs_blocking": round(us_sync / us_async, 2)}
        csv_line("ckpt/save/async_stall", us_async,
                 f"{us_sync / us_async:.2f}x_less_stall")

        last = ckpt.latest_verified_step(root)
        like = jax.eval_shape(lambda: tree)
        us_restore = round(
            _min_of(lambda: ckpt.restore(root, last, like)), 1)
        entries["restore/latency"] = {"us": us_restore, "ungated": True}
        csv_line("ckpt/restore/latency", us_restore, f"step={last}")
    finally:
        shutil.rmtree(root, ignore_errors=True)

    result = {
        "meta": {
            "backend": "host",        # np.save/sha256 — disk + CPU bound
            "interpret": True,        # keeps the 50ms jitter floor active
            "shape": {"n_leaves": N_LEAVES, "leaf": list(LEAF_SHAPE),
                      "total_mb": round(size_mb, 1),
                      "keep_last": KEEP_LAST},
        },
        "entries": entries,
    }
    if json_path:
        write_json(json_path, result)
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_ckpt.json-style output here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(json_path=args.json)


if __name__ == "__main__":
    main()
