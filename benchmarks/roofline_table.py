"""§Roofline: aggregate the dry-run JSONs into the per-(arch × shape) table.

Reads experiments/baseline/*.json (written by repro.launch.dryrun) and prints
one CSV row per combo: the three roofline terms, the dominant bottleneck, and
MODEL_FLOPS/HLO_FLOPs."""
import glob
import json
import os

from benchmarks.common import csv_line

DIRS = ("experiments/baseline", "experiments/dryrun")


def run():
    files = []
    for d in DIRS:
        files += glob.glob(os.path.join(d, "*.json"))
    if not files:
        csv_line("roofline/none", 0.0, "no dry-run artifacts yet")
        return
    for f in sorted(files):
        r = json.load(open(f))
        if not r.get("ok"):
            csv_line(f"roofline/{r['arch']}_{r['shape']}", 0.0,
                     f"FAILED:{r['error'][:60]}")
            continue
        t = r["roofline"]
        step_us = max(t["compute_s"], t["memory_s"], t["collective_s"]) * 1e6
        csv_line(
            f"roofline/{r['arch']}_{r['shape']}_{r['mesh']}_{r['sharding']}",
            step_us,
            f"compute_ms={t['compute_s']*1e3:.2f};"
            f"memory_ms={t['memory_s']*1e3:.2f};"
            f"collective_ms={t['collective_s']*1e3:.2f};"
            f"bottleneck={t['bottleneck']};"
            f"useful_flops={r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 3)};"
            f"peak_gb={r['memory']['peak_gb_per_device']}")
