"""Zero-shot serving perf bench: fused similarity→top-k vs the materializing
matmul+argsort reference, plus end-to-end classify latency through the
ZeroShotService (DESIGN.md §6.4).

Kernel comparison at n_classes ∈ {1k, 16k, 100k} (b=128, d=256, k=5):

  topk_ref   : jnp matmul -> stable argsort -> slice (materializes (b, n))
  topk_fused : blockwise Pallas kernel, running top-k in VMEM scratch

The 100k fused entry carries ``must_beat: topk_ref`` — scripts/check_bench.py
fails the gate if the kernel ever stops beating the reference at the label
scale the subsystem exists for. End-to-end entries time a warm classify()
(micro-batcher + registry hit + fused kernel) on a smoke dual encoder;
they are recorded for the trajectory but marked ``ungated`` (thread/deadline
jitter would flap the 1.3x gate).

``run(json_path=...)`` emits BENCH_serving.json, the committed perf
trajectory regressed by scripts/check_bench.py via benchmarks/run.py --json.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, write_json
from benchmarks.common import timeit_min as _timeit
from repro.kernels.similarity_topk import ops as topk_ops
from repro.kernels.similarity_topk import ref as topk_ref

N_CLASSES = (1_000, 16_000, 100_000)
B, D, K = 128, 256, 5
E2E_BATCH = 16
MUST_BEAT_N = 100_000


def _unit(key, rows, d):
    z = jax.random.normal(key, (rows, d), jnp.float32)
    return z / jnp.linalg.norm(z, axis=1, keepdims=True)


def _kernel_entries(entries, n_classes, interpret):
    for n in n_classes:
        k1, k2 = jax.random.split(jax.random.key(n))
        x = _unit(k1, B, D)
        c = _unit(k2, n, D)
        iters = 2 if n >= 100_000 else 3
        ref_fn = jax.jit(lambda x, c: topk_ref.similarity_topk_ref(x, c, K))
        fused_fn = jax.jit(lambda x, c: topk_ops.similarity_topk(
            x, c, K, interpret=interpret))
        ref_key, fused_key = f"topk_ref/N{n}", f"topk_fused/N{n}"
        entries[ref_key] = {"us": round(_timeit(ref_fn, x, c, iters=iters), 1)}
        entries[fused_key] = {
            "us": round(_timeit(fused_fn, x, c, iters=iters), 1)}
        entries[fused_key]["speedup_vs_ref"] = round(
            entries[ref_key]["us"] / entries[fused_key]["us"], 2)
        if n == MUST_BEAT_N:
            entries[fused_key]["must_beat"] = ref_key
        for key in (ref_key, fused_key):
            csv_line(f"serving/{key}", entries[key]["us"],
                     f"b={B};d={D};k={K}")


def _e2e_entries(entries, interpret):
    """Warm classify() latency through the full service stack."""
    import tempfile

    from benchmarks.common import tiny_dual_cfg
    from repro.data import load_tokenizer, world_for_tower
    from repro.data.synthetic import render_images
    from repro.models import dual_encoder as de
    from repro.serving import ZeroShotService

    cfg = tiny_dual_cfg()
    rng = np.random.default_rng(0)
    world = world_for_tower(rng, cfg.image_tower, n_classes=32)
    tok = load_tokenizer()
    params = de.init_params(cfg, jax.random.key(0))
    imgs = render_images(world, rng.integers(0, 32, E2E_BATCH), rng)

    with tempfile.TemporaryDirectory() as td, \
            ZeroShotService(cfg, params, tok, registry_dir=td,
                            max_delay_ms=1.0, interpret=interpret) as svc:
        svc.classify(imgs, world.class_names, k=5)   # compile + class matrix
        lat = []
        for _ in range(5):
            t0 = time.perf_counter()
            svc.classify(imgs, world.class_names, k=5)
            lat.append(time.perf_counter() - t0)
        us = min(lat) * 1e6
        # ungated: this times the threaded micro-batcher's deadline waits and
        # scheduler, not a kernel — it jitters 2x run-to-run on shared hosts
        # and would make the 1.3x gate flappy; the topk_* entries carry it.
        entries[f"e2e/classify_b{E2E_BATCH}"] = {
            "us": round(us, 1),
            "img_per_s": round(E2E_BATCH / (us * 1e-6), 1),
            "ungated": True,
        }
        csv_line(f"serving/e2e/classify_b{E2E_BATCH}", us,
                 f"{E2E_BATCH / (us * 1e-6):.1f}img/s")


def run(json_path: str | None = None, n_classes=None, e2e: bool = True):
    interpret = jax.default_backend() == "cpu"
    entries: dict = {}
    _kernel_entries(entries, n_classes or N_CLASSES, interpret)
    if e2e:
        _e2e_entries(entries, interpret)
    result = {
        "meta": {
            "backend": jax.default_backend(),
            "interpret": interpret,
            "kernel_shape": {"b": B, "d": D, "k": K},
            "n_classes": list(n_classes or N_CLASSES),
        },
        "entries": entries,
    }
    if json_path:
        write_json(json_path, result)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_serving.json-style output here")
    ap.add_argument("--smoke", action="store_true",
                    help="small label spaces only (CI sanity, not a baseline)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(json_path=args.json,
        n_classes=[1_000, 4_000] if args.smoke else None,
        e2e=not args.smoke)


if __name__ == "__main__":
    main()
