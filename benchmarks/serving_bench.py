"""Zero-shot serving perf bench: fused similarity→top-k vs the materializing
matmul+argsort reference, the §13 planet-scale retrieval paths, plus
end-to-end classify latency through the ZeroShotService (DESIGN.md §6.4).

Kernel comparison at n_classes ∈ {1k, 16k, 100k} (b=128, d=256, k=5):

  topk_ref   : jnp matmul -> stable argsort -> slice (materializes (b, n))
  topk_fused : blockwise Pallas kernel, running top-k in VMEM scratch

The 100k fused entry carries ``must_beat: topk_ref`` — scripts/check_bench.py
fails the gate if the kernel ever stops beating the reference at the label
scale the subsystem exists for.

Planet-scale entries (DESIGN.md §13.5):

  topk_fused_extrap/N1000000 : EXTRAPOLATED single-device latency at N=1M —
      10x a fresh same-process topk_fused/N100000 sweep (the kernel's cost
      is linear in class blocks, measured super-linear in interpret mode,
      so 10x UNDERSTATES the single-device cost — a conservative target).
  topk_sharded/N1000000      : the real N=1M exact sweep over an 8-way
      simulated data mesh (subprocess, same pattern as distributed_bench);
      carries ``must_beat: topk_fused_extrap/N1000000`` — the headline
      invariant: sharding must beat single-device scaling at 1M rows.
  topk_twostage/N10000000    : coarse→fine at N=10M synthetic clustered
      gallery (block-seeded, streamed through the gather callback — the
      matrix never fully materializes); reports recall@5 vs a streaming
      exact oracle at the pruned setting.
  topk_twostage/N100000_*    : two-stage at the committed 100k scale —
      ``nprobe_all`` asserts bit-identical-to-fused (recall 1.0 by
      construction), ``nprobe8`` measures the pruned latency/recall trade.

End-to-end entries time a warm classify() (micro-batcher + registry hit +
fused kernel) on a smoke dual encoder. e2e, extrap/sharded (subprocess
thread scheduling) and twostage (host-side coarse/gather stages) entries
are ``ungated`` for 1.3x drift — the must_beat invariants still gate.

``run(json_path=...)`` emits BENCH_serving.json, the committed perf
trajectory regressed by scripts/check_bench.py via benchmarks/run.py --json.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, write_json
from benchmarks.common import timeit_min as _timeit
from repro.kernels.similarity_topk import ops as topk_ops
from repro.kernels.similarity_topk import ref as topk_ref

N_CLASSES = (1_000, 16_000, 100_000)
B, D, K = 128, 256, 5
E2E_BATCH = 16
MUST_BEAT_N = 100_000

# -- §13 planet-scale shapes ----------------------------------------------
SHARD_DEVICES = 8           # simulated data-parallel degree (subprocess)
SHARD_N = 1_000_000
SHARD_BC = 131_072          # per-shard class block: ONE interpret grid
                            # step per shard at N=1M/8 (DESIGN.md §13.5)
EXTRAP_FACTOR = SHARD_N // MUST_BEAT_N
TWOSTAGE_N = 10_000_000
TWOSTAGE_BLOCKS = 1_000     # synthetic gallery: 1000 blocks x 10000 rows
TWOSTAGE_D = 64
TWOSTAGE_B = 16
TWOSTAGE_NPROBE = 4
TWOSTAGE_SIGMA = 0.15       # intra-block noise scale around each centroid


def _unit(key, rows, d):
    z = jax.random.normal(key, (rows, d), jnp.float32)
    return z / jnp.linalg.norm(z, axis=1, keepdims=True)


def _kernel_entries(entries, n_classes, interpret):
    for n in n_classes:
        k1, k2 = jax.random.split(jax.random.key(n))
        x = _unit(k1, B, D)
        c = _unit(k2, n, D)
        iters = 2 if n >= 100_000 else 3
        ref_fn = jax.jit(lambda x, c: topk_ref.similarity_topk_ref(x, c, K))
        fused_fn = jax.jit(lambda x, c: topk_ops.similarity_topk(
            x, c, K, interpret=interpret))
        ref_key, fused_key = f"topk_ref/N{n}", f"topk_fused/N{n}"
        entries[ref_key] = {"us": round(_timeit(ref_fn, x, c, iters=iters), 1)}
        entries[fused_key] = {
            "us": round(_timeit(fused_fn, x, c, iters=iters), 1)}
        entries[fused_key]["speedup_vs_ref"] = round(
            entries[ref_key]["us"] / entries[fused_key]["us"], 2)
        if n == MUST_BEAT_N:
            entries[fused_key]["must_beat"] = ref_key
        for key in (ref_key, fused_key):
            csv_line(f"serving/{key}", entries[key]["us"],
                     f"b={B};d={D};k={K}")


def _e2e_entries(entries, interpret):
    """Warm classify() latency through the full service stack."""
    import tempfile

    from benchmarks.common import tiny_dual_cfg
    from repro.data import load_tokenizer, world_for_tower
    from repro.data.synthetic import render_images
    from repro.models import dual_encoder as de
    from repro.serving import ZeroShotService

    cfg = tiny_dual_cfg()
    rng = np.random.default_rng(0)
    world = world_for_tower(rng, cfg.image_tower, n_classes=32)
    tok = load_tokenizer()
    params = de.init_params(cfg, jax.random.key(0))
    imgs = render_images(world, rng.integers(0, 32, E2E_BATCH), rng)

    with tempfile.TemporaryDirectory() as td, \
            ZeroShotService(cfg, params, tok, registry_dir=td,
                            max_delay_ms=1.0, interpret=interpret) as svc:
        svc.classify(imgs, world.class_names, k=5)   # compile + class matrix
        lat = []
        for _ in range(5):
            t0 = time.perf_counter()
            svc.classify(imgs, world.class_names, k=5)
            lat.append(time.perf_counter() - t0)
        us = min(lat) * 1e6
        # ungated: this times the threaded micro-batcher's deadline waits and
        # scheduler, not a kernel — it jitters 2x run-to-run on shared hosts
        # and would make the 1.3x gate flappy; the topk_* entries carry it.
        entries[f"e2e/classify_b{E2E_BATCH}"] = {
            "us": round(us, 1),
            "img_per_s": round(E2E_BATCH / (us * 1e-6), 1),
            "ungated": True,
        }
        csv_line(f"serving/e2e/classify_b{E2E_BATCH}", us,
                 f"{E2E_BATCH / (us * 1e-6):.1f}img/s")


def _sharded_entries_body() -> dict:
    """Subprocess body (needs the simulated-device XLA flag): the N=1M
    exact sharded sweep vs the extrapolated single-device target."""
    from repro.serving import retrieval as rtv

    assert jax.device_count() >= SHARD_DEVICES, jax.devices()
    k1, k2 = jax.random.split(jax.random.key(SHARD_N))
    x = _unit(k1, B, D)
    c = _unit(k2, SHARD_N, D)
    mesh = rtv.default_data_mesh(SHARD_DEVICES)
    sm = rtv.shard_matrix(c, mesh)

    # sanity: the sharded path is bit-identical to the single-device kernel
    # at the committed 100k scale (the full suite lives in the tests)
    c100k = c[:MUST_BEAT_N]
    v_ref, i_ref = jax.block_until_ready(
        topk_ops.similarity_topk(x, c100k, K, interpret=True))
    sm100k = rtv.shard_matrix(c100k, mesh)
    v_sh, i_sh = rtv.sharded_similarity_topk(x, sm100k, K, interpret=True)
    assert jnp.array_equal(v_ref, v_sh) and jnp.array_equal(i_ref, i_sh), \
        "sharded sweep diverged from the single-device kernel at N=100k"

    # the extrapolation anchor: a FRESH default-tuned single-device 100k
    # sweep in this same process, scaled linearly to N=1M
    fused_fn = jax.jit(lambda x, c: topk_ops.similarity_topk(
        x, c, K, interpret=True))
    fused_100k_us = _timeit(fused_fn, x, c100k, iters=3)
    extrap_key = f"topk_fused_extrap/N{SHARD_N}"
    sharded_key = f"topk_sharded/N{SHARD_N}"
    entries = {extrap_key: {
        "us": round(EXTRAP_FACTOR * fused_100k_us, 1),
        "desc": f"{EXTRAP_FACTOR}x fresh topk_fused/N{MUST_BEAT_N} "
                f"(conservative single-device N={SHARD_N} estimate)",
        # derived from a fresh sub-50ms-floor-adjacent sweep each run;
        # the drift gate is owned by topk_fused/N100000
        "ungated": True,
    }}

    def sharded_fn(x):
        return rtv.sharded_similarity_topk(x, sm, K, interpret=True,
                                           bc=SHARD_BC)
    us = _timeit(sharded_fn, x, iters=2)
    entries[sharded_key] = {
        "us": round(us, 1),
        "desc": f"exact N={SHARD_N} sweep, {SHARD_DEVICES}-shard mesh, "
                f"per-shard bc={SHARD_BC}",
        "speedup_vs_extrap": round(entries[extrap_key]["us"] / us, 2),
        # S threads time-slicing one host CPU jitter past the 1.3x gate;
        # the must_beat invariant below is the gate (host-drift immune)
        "ungated": True,
        "must_beat": extrap_key,
    }
    return entries


def _sharded_entries(entries: dict) -> None:
    """Spawn the simulated-mesh subprocess (same pattern as
    benchmarks/distributed_bench.py: jax locks the device count at first
    init, so the parent process cannot host the mesh itself)."""
    with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
        emit = f.name
    try:
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={SHARD_DEVICES}")
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.pathsep.join(
            [root, os.path.join(root, "src"),
             env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.serving_bench",
             "--emit-sharded", emit],
            env=env, cwd=root, capture_output=True, text=True, timeout=1200)
        if proc.returncode != 0:
            raise RuntimeError(
                f"sharded bench subprocess failed:\n{proc.stderr[-3000:]}")
        with open(emit) as f:
            emitted = json.load(f)
    finally:
        os.unlink(emit)
    for name, e in sorted(emitted.items()):
        entries[name] = e
        csv_line(f"serving/{name}", e["us"], e["desc"])


def _twostage_block(block: int, centroids: np.ndarray) -> np.ndarray:
    """Regenerate one synthetic gallery block from its seed: rows clustered
    around the block centroid — the gather-callback storage model (the
    10M-row matrix never materializes)."""
    m = TWOSTAGE_N // TWOSTAGE_BLOCKS
    rng = np.random.default_rng(10_000 + block)
    rows = centroids[block] + TWOSTAGE_SIGMA * rng.standard_normal(
        (m, TWOSTAGE_D)).astype(np.float32)
    return rows / np.linalg.norm(rows, axis=1, keepdims=True)


def _twostage_10m_entries(entries: dict, interpret) -> None:
    """Coarse→fine at N=10M: index known by construction (the generator's
    centroids ARE the block structure), rows streamed per block."""
    from repro.serving import retrieval as rtv

    p, m = TWOSTAGE_BLOCKS, TWOSTAGE_N // TWOSTAGE_BLOCKS
    rng = np.random.default_rng(999)
    cent = rng.standard_normal((p, TWOSTAGE_D)).astype(np.float32)
    cent /= np.linalg.norm(cent, axis=1, keepdims=True)
    index = rtv.CentroidIndex(
        centroids=cent,
        members=np.arange(TWOSTAGE_N, dtype=np.int32).reshape(p, m),
        counts=np.full(p, m, np.int32), n=TWOSTAGE_N)
    # queries near (but not on) random block centroids — the regime the
    # coarse stage exists for
    qi = rng.integers(0, p, TWOSTAGE_B)
    q = cent[qi] + TWOSTAGE_SIGMA * rng.standard_normal(
        (TWOSTAGE_B, TWOSTAGE_D)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)

    def gather(ids):
        blocks = np.unique(ids // m)
        chunks = {b: _twostage_block(b, cent) for b in blocks}
        return np.concatenate(
            [chunks[b][ids[ids // m == b] % m] for b in blocks])

    t0 = time.perf_counter()
    vals, gidx, info = rtv.two_stage_topk(
        q, gather, index, K, nprobe=TWOSTAGE_NPROBE, interpret=interpret,
        bc=SHARD_BC)
    us = (time.perf_counter() - t0) * 1e6

    # streaming exact oracle: per-block top-K merge in numpy
    best_v = np.full((TWOSTAGE_B, K), -np.inf, np.float32)
    best_i = np.full((TWOSTAGE_B, K), -1, np.int64)
    for blk in range(p):
        s = (q @ _twostage_block(blk, cent).T).astype(np.float32)
        top = np.argpartition(-s, K - 1, axis=1)[:, :K]
        cv = np.concatenate([best_v, np.take_along_axis(s, top, axis=1)], 1)
        ci = np.concatenate([best_i, top + blk * m], 1)
        keep = np.argpartition(-cv, K - 1, axis=1)[:, :K]
        best_v = np.take_along_axis(cv, keep, axis=1)
        best_i = np.take_along_axis(ci, keep, axis=1)
    recall = float(np.mean([
        len(set(gidx[r]) & set(best_i[r])) / K for r in range(TWOSTAGE_B)]))
    entries[f"topk_twostage/N{TWOSTAGE_N}"] = {
        "us": round(us, 1),
        "desc": f"coarse→fine, {p} blocks, nprobe={TWOSTAGE_NPROBE}, "
                f"b={TWOSTAGE_B} d={TWOSTAGE_D}, block-streamed gallery",
        "recall_at_k": round(recall, 4),
        "prune_ratio": round(info["prune_ratio"], 4),
        "ungated": True,   # host-side coarse/gather stages drift with load
    }
    csv_line(f"serving/topk_twostage/N{TWOSTAGE_N}", us,
             f"recall@{K}={recall:.3f};prune={info['prune_ratio']:.4f}")


def _twostage_100k_entries(entries: dict, interpret) -> None:
    """Two-stage at the committed 100k scale: nprobe=all must reproduce
    the fused kernel bit-for-bit (the exactness escape hatch), nprobe=8
    records the pruned latency/recall trade."""
    from repro.serving import retrieval as rtv

    n = MUST_BEAT_N
    k1, k2 = jax.random.split(jax.random.key(n))
    # TWOSTAGE_B queries, not B: the probe-union across a batch is what
    # survives pruning, and the coarse stage targets interactive batch
    # sizes (a 128-query union touches ~every block — no prune left)
    x = np.asarray(_unit(k1, TWOSTAGE_B, D))
    c = np.asarray(_unit(k2, n, D))
    index = rtv.build_centroid_index(c, iters=2)
    v_ref, i_ref = topk_ops.similarity_topk(
        jnp.asarray(x), jnp.asarray(c), K, interpret=interpret)
    v_ref, i_ref = np.asarray(v_ref), np.asarray(i_ref)

    for nprobe, tag in (("all", "nprobe_all"), (8, "nprobe8")):
        t0 = time.perf_counter()
        vals, gidx, info = rtv.two_stage_topk(
            x, c, index, K, nprobe=nprobe, interpret=interpret)
        us = (time.perf_counter() - t0) * 1e6
        recall = float(np.mean([
            len(set(gidx[r]) & set(i_ref[r])) / K
            for r in range(TWOSTAGE_B)]))
        if nprobe == "all":
            assert np.array_equal(vals, v_ref) and \
                np.array_equal(gidx, i_ref), \
                "nprobe=all diverged from the fused kernel"
            assert recall == 1.0
        entries[f"topk_twostage/N{n}_{tag}"] = {
            "us": round(us, 1),
            # uniform random gallery = the WORST case for coarse pruning
            # (no cluster structure to exploit); the N=10M entry measures
            # the clustered regime the index is built for
            "desc": f"two-stage N={n} nprobe={nprobe} "
                    f"({index.n_blocks} blocks, uniform gallery)",
            "recall_at_k": round(recall, 4),
            "prune_ratio": round(info["prune_ratio"], 4),
            "ungated": True,
        }
        csv_line(f"serving/topk_twostage/N{n}_{tag}", us,
                 f"recall@{K}={recall:.3f};prune={info['prune_ratio']:.4f}")


def run(json_path: str | None = None, n_classes=None, e2e: bool = True,
        planet_scale: bool = True):
    interpret = jax.default_backend() == "cpu"
    entries: dict = {}
    _kernel_entries(entries, n_classes or N_CLASSES, interpret)
    if planet_scale:
        _sharded_entries(entries)
        _twostage_100k_entries(entries, interpret)
        _twostage_10m_entries(entries, interpret)
    if e2e:
        _e2e_entries(entries, interpret)
    result = {
        "meta": {
            "backend": jax.default_backend(),
            "interpret": interpret,
            "kernel_shape": {"b": B, "d": D, "k": K},
            "n_classes": list(n_classes or N_CLASSES),
            "sharded": {"devices": SHARD_DEVICES, "n": SHARD_N,
                        "bc": SHARD_BC},
            "twostage": {"n": TWOSTAGE_N, "blocks": TWOSTAGE_BLOCKS,
                         "d": TWOSTAGE_D, "nprobe": TWOSTAGE_NPROBE},
        },
        "entries": entries,
    }
    if json_path:
        write_json(json_path, result)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_serving.json-style output here")
    ap.add_argument("--smoke", action="store_true",
                    help="small label spaces only (CI sanity, not a baseline)")
    ap.add_argument("--emit-sharded", default=None, metavar="PATH",
                    help="(internal) run the sharded-mesh bench in THIS "
                         "process and write raw entries to PATH — requires "
                         "the simulated-device XLA flag to be set")
    args = ap.parse_args()
    if args.emit_sharded:
        entries = _sharded_entries_body()
        with open(args.emit_sharded, "w") as f:
            json.dump(entries, f)
        return
    print("name,us_per_call,derived")
    run(json_path=args.json,
        n_classes=[1_000, 4_000] if args.smoke else None,
        e2e=not args.smoke, planet_scale=not args.smoke)


if __name__ == "__main__":
    main()
