"""Telemetry overhead bench (DESIGN.md §11.4).

Observability is only free if it stays off the hot path: the trainer's
per-step instrumentation is two trace spans (data_wait / device_step), a
handful of histogram observes, and one runlog JSONL line. The claim this
bench gates is "instrumented step <= 1.05x bare step". Measuring that as
a ratio of two wall-clock loops flaps on shared hosts — load drift over
seconds swings ANY multi-ms workload (matmul or sleep) by more than the
5% budget itself — so the gated form measures the telemetry cost
DIRECTLY (a tight loop of the per-step instrumentation with no workload:
pure host CPU microseconds, stable under contention) and requires it to
beat a 5%-of-bare-step budget. Same claim, no noisy subtraction.

  bare_ref/step_loop      N_STEPS bare steps of a clock-based simulated
                          device-blocked step (the trainer's steady
                          state) — the ``*_ref`` host-drift anchor
                          (scripts/check_bench.py) and the budget's base
  step/telemetry          N_STEPS iterations of the full per-step
                          telemetry alone: tracer spans, registry
                          histogram observe, RunLogger.log_step to a real
                          file. ``must_beat: step/overhead_budget`` — THE
                          1.05x GATE
  step/overhead_budget    synthetic: 5% of bare_ref/step_loop. UNGATED
                          (derived, not timed) — exists so must_beat's
                          strictly-faster semantics express "telemetry
                          stays within 5% of the step it instruments"
  step/instrumented       the workload loop with telemetry riding along,
                          UNGATED informational (it carries the host
                          noise the direct form avoids)
  window/observe          N_STEPS pushes + the per-step windowed stats
                          the health tier reads (median/zscore over a
                          128-window). ``must_beat: step/overhead_budget``
                          — windowed aggregation stays within the same 5%
  health/check            N_STEPS full ``HealthMonitor.observe_step``
                          calls (default detector suite, healthy
                          trajectory — the every-step steady-state cost).
                          ``must_beat: step/overhead_budget``
  micro/*                 per-op costs (span pair, histogram observe,
                          runlog step record), UNGATED — what the budget
                          is spent on

Committed as BENCH_obs.json and gated through ``benchmarks/run.py
--json``: the must_beat invariant carries the <=1.05x overhead claim on
every host; absolute timings ride the 1.3x cross-run gate where they
clear the 50ms interpret floor.
"""
from __future__ import annotations

import argparse
import os
import statistics
import tempfile
import time

import numpy as np

from benchmarks.common import csv_line, write_json
from repro.obs import health as obs_health
from repro.obs import metrics as obs_metrics
from repro.obs import runlog as obs_runlog
from repro.obs import trace as obs_trace
from repro.obs import windows as obs_windows

N_STEPS = 30                  # steps per timed loop
REPEATS = 7                   # median-of-N (scheduler-noise robustness)
STEP_S = 0.005                # simulated device-blocked step: smoke scale
OVERHEAD_BUDGET = 0.05        # telemetry must cost <5% of the bare step


def _workload():
    """The fixed per-step work: block STEP_S on the 'device' (wall clock —
    the steady-state trainer is device-bound, and sleep overshoot under
    load hits bare and instrumented loops alike), plus a token host-side
    reduction standing in for the loss fetch."""
    rng = np.random.default_rng(0)
    a = rng.standard_normal((64, 64)).astype(np.float32)

    def step():
        time.sleep(STEP_S)
        return float(a.sum())
    return step


def _bare_loop(step_fn) -> float:
    t0 = time.perf_counter()
    for _ in range(N_STEPS):
        step_fn()
    return time.perf_counter() - t0


def _instrumented_loop(step_fn, tracer, runlog, hist) -> float:
    """The trainer's per-step telemetry, verbatim shape (_run_loop):
    data_wait span, device_step span, histogram observe, log_step line.
    ``step_fn=None`` measures the telemetry alone — the gated form."""
    t0 = time.perf_counter()
    for i in range(N_STEPS):
        t_iter = time.perf_counter()
        with obs_trace.span(tracer, "data_wait", step=i):
            pass                                  # batch already prefetched
        t_wait = time.perf_counter() - t_iter
        with obs_trace.span(tracer, "device_step", step=i):
            out = step_fn() if step_fn is not None else 0.0
        step_s = time.perf_counter() - t_iter
        hist.observe(step_s)
        runlog.log_step(i, loss=float(out), data_wait_s=t_wait,
                        device_step_s=step_s - t_wait, ckpt_stall_s=0.0,
                        step_s=step_s, examples_per_sec=N_STEPS / step_s)
    return time.perf_counter() - t0


def run(json_path: str | None = None):
    """Run the bench; optionally write the BENCH_obs.json payload."""
    step_fn = _workload()
    step_fn()                                     # warm (BLAS threads, pages)

    tmp = tempfile.mkdtemp(prefix="obs_bench_")
    registry = obs_metrics.Registry()
    hist = registry.histogram("bench/step_s")
    tracer = obs_trace.Tracer()
    # interleaved median-of-N: host drift hits all three variants equally,
    # and median (not min) keeps one lucky/unlucky trial from skewing the
    # budget base or the informational ratio
    bares, insts, tels = [], [], []
    with obs_runlog.RunLogger(os.path.join(tmp, "runlog.jsonl")) as runlog:
        _instrumented_loop(step_fn, tracer, runlog, hist)   # warm file path
        for _ in range(REPEATS):
            bares.append(_bare_loop(step_fn))
            insts.append(_instrumented_loop(step_fn, tracer, runlog, hist))
            tels.append(_instrumented_loop(None, tracer, runlog, hist))
    us_bare = round(statistics.median(bares) * 1e6, 1)
    us_inst = round(statistics.median(insts) * 1e6, 1)
    us_tel = round(statistics.median(tels) * 1e6, 1)

    entries = {
        "bare_ref/step_loop": {
            "us": us_bare,
            "per_step_us": round(us_bare / N_STEPS, 1)},
        "step/overhead_budget": {
            "us": round(us_bare * OVERHEAD_BUDGET, 1), "ungated": True,
            "budget_frac_of_bare": OVERHEAD_BUDGET},
        "step/telemetry": {
            "us": us_tel, "must_beat": "step/overhead_budget",
            "per_step_us": round(us_tel / N_STEPS, 1),
            "frac_of_bare_step": round(us_tel / us_bare, 4)},
        "step/instrumented": {
            "us": us_inst, "ungated": True,
            "per_step_us": round(us_inst / N_STEPS, 1),
            "overhead_vs_bare": round(us_inst / us_bare, 4)},
    }
    csv_line("obs/bare_ref/step_loop", us_bare, f"{N_STEPS}steps")
    csv_line("obs/step/telemetry", us_tel,
             f"{us_tel / us_bare:.4f}_of_bare")
    csv_line("obs/step/instrumented", us_inst,
             f"{us_inst / us_bare:.3f}x_bare")

    # health-tier per-step costs, gated against the SAME 5% budget: these
    # run every step when --health is on, so they must fit where the
    # passive telemetry fits (DESIGN.md §14.4)
    win = obs_windows.SlidingWindow(128)
    for i in range(128):
        win.push(1.0 + 0.01 * (i % 7))            # pre-wrapped window
    win_times = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        for i in range(N_STEPS):
            win.push(1.0 + 0.01 * (i % 7))
            win.median()
            win.zscore(1.0)
        win_times.append(time.perf_counter() - t0)
    us_win = round(statistics.median(win_times) * 1e6, 1)

    mon = obs_health.HealthMonitor(registry=obs_metrics.Registry())
    for i in range(64):                            # warm the detector windows
        mon.observe_step(obs_health.StepSample(
            step=i, loss=2.0 - 1e-3 * i, grad_norm=1.0 + 0.01 * (i % 5),
            data_wait_s=1e-4, device_step_s=STEP_S, step_s=STEP_S))
    mon_times = []
    for r in range(REPEATS):
        t0 = time.perf_counter()
        for i in range(N_STEPS):
            s = 64 + r * N_STEPS + i
            mon.observe_step(obs_health.StepSample(
                step=s, loss=2.0 - 1e-3 * s, grad_norm=1.0 + 0.01 * (s % 5),
                data_wait_s=1e-4, device_step_s=STEP_S, step_s=STEP_S))
        mon_times.append(time.perf_counter() - t0)
    us_health = round(statistics.median(mon_times) * 1e6, 1)

    entries["window/observe"] = {
        "us": us_win, "must_beat": "step/overhead_budget",
        "per_step_us": round(us_win / N_STEPS, 1)}
    entries["health/check"] = {
        "us": us_health, "must_beat": "step/overhead_budget",
        "per_step_us": round(us_health / N_STEPS, 1)}
    csv_line("obs/window/observe", us_win,
             f"{us_win / us_bare:.4f}_of_bare")
    csv_line("obs/health/check", us_health,
             f"{us_health / us_bare:.4f}_of_bare")

    # per-op micro costs (informational: what the 5% budget is spent on)
    reg2 = obs_metrics.Registry()
    h2 = reg2.histogram("micro/x")
    tr2 = obs_trace.Tracer()
    n = 10_000
    t0 = time.perf_counter()
    for i in range(n):
        with obs_trace.span(tr2, "s", step=i):
            pass
    us_span = round((time.perf_counter() - t0) / n * 1e6, 3)
    t0 = time.perf_counter()
    for _ in range(n):
        h2.observe(0.01)
    us_obs = round((time.perf_counter() - t0) / n * 1e6, 3)
    with obs_runlog.RunLogger(os.path.join(tmp, "micro.jsonl")) as rl2:
        t0 = time.perf_counter()
        for i in range(1000):
            rl2.log_step(i, loss=1.0, data_wait_s=0.0, device_step_s=0.01,
                         ckpt_stall_s=0.0, step_s=0.01,
                         examples_per_sec=100.0)
        us_line = round((time.perf_counter() - t0) / 1000 * 1e6, 3)
    for name, us in (("micro/span_pair", us_span),
                     ("micro/hist_observe", us_obs),
                     ("micro/runlog_step", us_line)):
        entries[name] = {"us": us, "ungated": True}
        csv_line(f"obs/{name}", us, "per_op")

    result = {
        "meta": {
            "backend": "host",     # pure-python telemetry, clock workload
            "interpret": True,     # keeps the 50ms jitter floor active
            "shape": {"n_steps": N_STEPS, "step_s": STEP_S,
                      "budget": OVERHEAD_BUDGET},
        },
        "entries": entries,
    }
    if json_path:
        write_json(json_path, result)
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_obs.json-style output here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(json_path=args.json)


if __name__ == "__main__":
    main()
