"""Paper Table 4 / Figure 5 analog: larger contrastive batch -> better final
zero-shot accuracy at equal examples seen. Toy scale (CPU): B in {8,32,128},
steps scaled so B*steps is constant."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, timeit, tiny_dual_cfg, world_and_tok
from repro.core.gradaccum import contrastive_step
from repro.data import classification_prompts, contrastive_batch
from repro.models import dual_encoder as de
from repro.optim import AdaFactorW, apply_updates


def _train_and_eval(cfg, world, tok, B, steps, seed=0):
    params = de.init_params(cfg, jax.random.key(seed))
    opt = AdaFactorW()
    st = opt.init(params)
    enc_i = lambda p, im: de.encode_image(cfg, p, im)   # noqa: E731
    enc_t = lambda p, tx: de.encode_text(cfg, p, tx)    # noqa: E731

    @jax.jit
    def step(params, st, batch):
        loss, _, grads = contrastive_step(enc_i, enc_t, params, batch,
                                          max(1, B // 16))
        up, st = opt.update(grads, st, params, 2e-3)
        return apply_updates(params, up), st, loss

    rng = np.random.default_rng(seed + 100)
    for _ in range(steps):
        batch, _ = contrastive_batch(world, tok, B, rng)
        params, st, loss = step(params, st, jax.tree.map(jnp.asarray, batch))

    prompts = classification_prompts(world, tok)
    temb = enc_t(params, jax.tree.map(jnp.asarray, prompts))
    tb, cls = contrastive_batch(world, tok, 128, rng)
    iemb = enc_i(params, jax.tree.map(jnp.asarray, tb["images"]))
    pred = np.asarray(jnp.argmax(iemb @ temb.T, 1))
    return float(np.mean(pred == cls)), float(loss)


def run():
    cfg = tiny_dual_cfg()
    world, tok, _ = world_and_tok(cfg)
    total = 2048  # examples seen, constant across rows (paper's protocol)
    for B in (8, 32, 128):
        steps = total // B
        import time
        t0 = time.perf_counter()
        acc, loss = _train_and_eval(cfg, world, tok, B, steps)
        us = (time.perf_counter() - t0) * 1e6 / steps
        csv_line(f"table4/B{B}_steps{steps}", us,
                 f"zeroshot_acc={acc:.3f};final_loss={loss:.3f}")
