"""Contrastive-kernel perf bench: reference vs legacy 4-pass vs fused 2-pass.

Times three implementations of the paper's contrastive loss (DESIGN.md §5) —

  ref    : materializing jnp oracle (``ref.loss_and_grads_ref``)
  old4   : legacy blockwise path, 4 Pallas launches (2 fwd + 2 bwd sweeps)
  fused2 : single-pass blockwise path, 2 Pallas launches (DESIGN.md §2.3)

— for forward and forward+backward over B ∈ {512, 2048, 8192} and
D ∈ {256, 1024}, reporting µs/call and effective GB/s against the ideal
Θ(B·D) traffic model (X/Y reads + gradient writes; the B×B matrix is free
in the blockwise paths). On accelerators the kernels run compiled
(interpret=False); on CPU they run jit-compiled in interpret mode.

``run(json_path=...)`` additionally emits BENCH_kernels.json, the committed
perf trajectory that scripts/check_bench.py regresses against.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line, write_json  # noqa: F401 (run.py API)
from benchmarks.common import timeit_min as _timeit
from repro.kernels.contrastive_loss import ops, ref
from repro.kernels.contrastive_loss.ops import pick_blocks

SHAPES = [(512, 256), (512, 1024), (2048, 256), (2048, 1024),
          (8192, 256), (8192, 1024)]
LOG_TAU = -1.0


def _ideal_bytes(b, d, itemsize, with_grads):
    reads = 2 * b * d * itemsize              # X and Y streamed once
    writes = 2 * b * 4                        # row/col LSE
    if with_grads:
        writes += 2 * b * d * 4               # dX, dY (fp32)
    return reads + writes


def _paths(b, d, interpret):
    """name -> (fwd_fn, fwdbwd_fn), all jitted, taking (x, y, log_tau)."""
    bm, bn = pick_blocks(b, d, 4)
    fused = lambda x, y, t: ops.fused_contrastive_loss(   # noqa: E731
        x, y, t, interpret, bm, bn)
    return {
        "ref": (
            jax.jit(ref.loss_ref),
            jax.jit(ref.loss_and_grads_ref),
        ),
        "old4": (
            jax.jit(lambda x, y, t: ops.fused_loss_and_lse_4pass(
                x, y, t, interpret, bm, bn)[0]),
            jax.jit(lambda x, y, t: ops.fused_contrastive_loss_4pass(
                x, y, t, interpret, bm, bn)),
        ),
        "fused2": (
            jax.jit(fused),
            jax.jit(jax.value_and_grad(fused, argnums=(0, 1, 2))),
        ),
    }


def run(json_path: str | None = None, shapes=None) -> dict:
    interpret = jax.default_backend() == "cpu"
    entries = {}
    for b, d in (shapes or SHAPES):
        k1, k2 = jax.random.split(jax.random.key(b + d))
        x = jax.random.normal(k1, (b, d), jnp.float32)
        y = jax.random.normal(k2, (b, d), jnp.float32)
        x = x / jnp.linalg.norm(x, axis=1, keepdims=True)
        y = y / jnp.linalg.norm(y, axis=1, keepdims=True)
        log_tau = jnp.asarray(LOG_TAU)
        iters = 2 if b >= 8192 else 5
        # on compiled backends ops._bwd falls back to the legacy two-sweep
        # backward when the dY carrier won't fit VMEM (DESIGN.md §2.3);
        # record the launch count so a fused2 entry that actually measured
        # the fallback (3 launches) is visible in the committed trajectory.
        bm, bn = pick_blocks(b, d, 4)
        fused_launches = 2 if (interpret or ops.bwd_fits_fused(
            b, d, bm, bn, 4)) else 3
        for name, (fwd, fwdbwd) in _paths(b, d, interpret).items():
            for tag, fn in (("fwd", fwd), ("fwdbwd", fwdbwd)):
                us = _timeit(fn, x, y, log_tau, iters=iters)
                gbps = _ideal_bytes(b, d, 4, tag == "fwdbwd") / (us * 1e-6) / 1e9
                key = f"{name}/B{b}_D{d}/{tag}"
                entries[key] = {"us": round(us, 1), "gbps": round(gbps, 3)}
                if name == "fused2" and tag == "fwdbwd":
                    entries[key]["launches"] = fused_launches
                csv_line(f"kernels/{key}", us, f"{gbps:.3f}GB/s")

    result = {
        "meta": {
            "backend": jax.default_backend(),
            "interpret": interpret,
            "shapes": [list(s) for s in (shapes or SHAPES)],
            "traffic_model": "ideal 2BD reads + grad writes (DESIGN.md §5)",
        },
        "entries": entries,
    }
    if json_path:
        write_json(json_path, result)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_kernels.json-style output here")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes only (CI sanity, not a baseline)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(json_path=args.json,
        shapes=[(512, 256), (512, 1024)] if args.smoke else None)


if __name__ == "__main__":
    main()
