"""Paper Tables 1/3 analog: zero-shot transfer across 'benchmarks' — held-out
class splits with distinct prompt templates (the synthetic stand-ins for
ImageNet / ImageNet-{A,R,V2,Sketch} / etc.). Trains once, evaluates on:

  seen        — classes used in contrastive training (ImageNet analog)
  unseen      — classes NEVER in training (open-vocabulary transfer)
  shifted     — seen classes rendered at 2x noise (robustness analog)
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, tiny_dual_cfg, world_and_tok
from repro.core.gradaccum import contrastive_step
from repro.data import classification_prompts, contrastive_batch
from repro.data.synthetic import render_images
from repro.models import dual_encoder as de
from repro.optim import AdaFactorW, apply_updates


def run():
    t0 = time.perf_counter()
    cfg = tiny_dual_cfg()
    world, tok, _ = world_and_tok(cfg, n_classes=24)
    seen = np.arange(16)
    unseen = np.arange(16, 24)

    params = de.init_params(cfg, jax.random.key(3))
    opt = AdaFactorW()
    st = opt.init(params)
    enc_i = lambda p, im: de.encode_image(cfg, p, im)   # noqa: E731
    enc_t = lambda p, tx: de.encode_text(cfg, p, tx)    # noqa: E731

    @jax.jit
    def step(params, st, batch):
        loss, _, g = contrastive_step(enc_i, enc_t, params, batch, 2)
        up, st = opt.update(g, st, params, 2e-3)
        return apply_updates(params, up), st

    rng = np.random.default_rng(11)
    for _ in range(80):
        batch, _ = contrastive_batch(world, tok, 32, rng, classes=seen)
        params, st = step(params, st, jax.tree.map(jnp.asarray, batch))

    prompts = classification_prompts(world, tok)
    temb = np.asarray(enc_t(params, jax.tree.map(jnp.asarray, prompts)))

    def acc_on(cls_pool, noise_mult=1.0):
        cls = cls_pool[rng.integers(0, len(cls_pool), 128)]
        old = world.noise
        world.noise = old * noise_mult
        imgs = render_images(world, cls, rng)
        world.noise = old
        iemb = np.asarray(enc_i(params, {"image": jnp.asarray(imgs)}))
        pred = np.argmax(iemb @ temb.T, axis=1)
        return float(np.mean(pred == cls))

    us = (time.perf_counter() - t0) * 1e6
    csv_line("zeroshot/seen", us, f"top1={acc_on(seen):.3f};chance=0.042")
    csv_line("zeroshot/unseen_openvocab", us,
             f"top1={acc_on(unseen):.3f};chance=0.042")
    csv_line("zeroshot/shifted_robustness", us,
             f"top1={acc_on(seen, 2.0):.3f};chance=0.042")
