# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
#
# ``--json`` additionally runs the committed perf benches (contrastive
# kernels + zero-shot serving), rewrites BENCH_kernels.json /
# BENCH_serving.json, and gates the fresh numbers against the previously
# committed content via scripts.check_bench (>1.3x, plus the serving
# bench's intra-run must_beat invariants).
import argparse
import importlib
import json
import os
import sys
import traceback

# suite name -> (module, one-line description shown in --help)
SUITES = {
    "table2": ("benchmarks.table2_memory",
               "step time/memory: DP vs GradAccum (paper Table 2)"),
    "table4": ("benchmarks.table4_batch",
               "batch-size ablation (paper Table 4)"),
    "zeroshot": ("benchmarks.zero_shot",
                 "zero-shot accuracy sweep (paper Tables 1/3 analog)"),
    "theory": ("benchmarks.theory_bound",
               "Theorems 1-2 generalization gap vs B"),
    "roofline": ("benchmarks.roofline_table",
                 "roofline aggregation over dryrun outputs"),
    "kernels": ("benchmarks.kernel_bench",
                "contrastive loss kernels: ref vs 4-pass vs fused "
                "(gated, DESIGN.md §5)"),
    "serving": ("benchmarks.serving_bench",
                "similarity->top-k kernel + e2e classify "
                "(gated, DESIGN.md §6.4)"),
    "distributed": ("benchmarks.distributed_bench",
                    "cross-shard global-batch loss, simulated mesh "
                    "(gated, DESIGN.md §7.5)"),
    "tower": ("benchmarks.tower_bench",
              "encode path per attention backend: naive vs chunked vs "
              "pallas (gated, DESIGN.md §8)"),
    "data": ("benchmarks.data_bench",
             "host-side input pipeline: generation, augmentation "
             "overhead, prefetch depth sweep (gated, DESIGN.md §9.4)"),
    "ckpt": ("benchmarks.ckpt_bench",
             "checkpoint save stall: blocking vs async manager, plus "
             "verified restore (gated, DESIGN.md §10.5)"),
    "obs": ("benchmarks.obs_bench",
            "telemetry overhead: per-step instrumentation vs 5%-of-step "
            "budget (gated, DESIGN.md §11.4)"),
    "decode": ("benchmarks.decode_bench",
               "continuous-batching decode vs one-at-a-time legacy "
               "serving (gated, DESIGN.md §12.5)"),
}
TABLES = {name: mod for name, (mod, _) in SUITES.items()}

# slow full-sweep benches only run when selected explicitly (or via --json)
_OPT_IN = {"kernels", "serving", "distributed", "tower", "data", "ckpt",
           "obs", "decode"}

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# gated perf-trajectory files: bench module -> committed baseline JSON
GATED = {
    "kernels": os.path.join(_ROOT, "BENCH_kernels.json"),
    "serving": os.path.join(_ROOT, "BENCH_serving.json"),
    "distributed": os.path.join(_ROOT, "BENCH_distributed.json"),
    "tower": os.path.join(_ROOT, "BENCH_tower.json"),
    "data": os.path.join(_ROOT, "BENCH_data.json"),
    "ckpt": os.path.join(_ROOT, "BENCH_ckpt.json"),
    "obs": os.path.join(_ROOT, "BENCH_obs.json"),
    "decode": os.path.join(_ROOT, "BENCH_decode.json"),
}


def _run_bench_json(name: str, json_path: str) -> int:
    """Run bench ``name`` and gate it against the checked-out JSON. On pass
    the file is refreshed (committing it is how the perf trajectory ratchets
    forward — review its git diff, since sub-threshold drift accumulates by
    design); on failure the baseline is kept and the fresh numbers go to
    ``<file>.new``, so re-running can't silently accept a regression by
    comparing it against itself. Returns rc."""
    from scripts import check_bench

    mod = importlib.import_module(TABLES[name])
    baseline = None
    if os.path.exists(json_path):
        with open(json_path) as f:
            baseline = json.load(f)
    fresh = mod.run()
    if baseline is None:
        failures = check_bench.must_beat_failures(fresh)
        for line in failures:
            print(f"check_bench[{name}]: REGRESSION {line}", file=sys.stderr)
        if failures:
            mod.write_json(json_path + ".new", fresh)
            return 1
        mod.write_json(json_path, fresh)
        print(f"run.py --json: no prior baseline; wrote initial "
              f"{json_path}", file=sys.stderr)
        return 0
    print(f"check_bench[{name}]: {check_bench.summarize(fresh, baseline)}")
    failures = check_bench.compare(fresh, baseline)
    for line in failures:
        print(f"check_bench[{name}]: REGRESSION {line}", file=sys.stderr)
    if failures:
        mod.write_json(json_path + ".new", fresh)
        print(f"run.py --json: baseline kept; fresh (regressed) numbers in "
              f"{json_path}.new", file=sys.stderr)
        return 1
    mod.write_json(json_path, fresh)
    if os.path.exists(json_path + ".new"):
        os.remove(json_path + ".new")  # stale output of an older failed run
    print(f"check_bench[{name}]: OK")
    return 0


def main() -> None:
    suites = "\n".join(f"  {n:<12} {d}" + ("  [opt-in]" if n in _OPT_IN
                                           else "")
                       for n, (_, d) in sorted(SUITES.items()))
    ap = argparse.ArgumentParser(
        description="run the repo's benchmark suites "
                    "(CSV: name,us_per_call,derived)",
        epilog=f"registered suites:\n{suites}\n\n[opt-in] suites only run "
               "with --only <name> or --json (they are slow full sweeps "
               "and carry the perf-regression gate)",
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--only", choices=sorted(TABLES), default=None,
                    help="run a single suite")
    ap.add_argument("--json", action="store_true",
                    help="run the gated perf benches, rewrite BENCH_*.json, "
                         "and fail on >1.3x regression vs the committed files")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = 0
    for name, mod_name in TABLES.items():
        if args.only and name != args.only:
            continue
        if name in _OPT_IN and (args.json or args.only != name):
            continue  # opt-in only; with --json the gate runs it instead
        try:
            importlib.import_module(mod_name).run()
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        gated = [n for n in GATED if args.only in (None, n)]
        if not gated:
            print(f"run.py: --json ignored with --only {args.only} "
                  "(no perf gate covers it)", file=sys.stderr)
        for name in gated:
            failed += _run_bench_json(name, GATED[name])
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
