# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
import argparse
import sys
import traceback

TABLES = {
    "table2": "benchmarks.table2_memory",    # step time/memory: DP vs GradAccum
    "table4": "benchmarks.table4_batch",     # batch-size ablation
    "zeroshot": "benchmarks.zero_shot",      # Tables 1/3 analog
    "theory": "benchmarks.theory_bound",     # Theorems 1-2 gap vs B
    "roofline": "benchmarks.roofline_table", # §Roofline aggregation
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(TABLES), default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = 0
    for name, mod_name in TABLES.items():
        if args.only and name != args.only:
            continue
        try:
            import importlib
            importlib.import_module(mod_name).run()
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc()
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
