# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
#
# ``--json`` additionally runs the kernel perf bench (benchmarks.kernel_bench),
# rewrites BENCH_kernels.json, and gates the fresh numbers against the
# previously committed content via scripts.check_bench (>1.3x fails).
import argparse
import importlib
import json
import os
import sys
import traceback

TABLES = {
    "table2": "benchmarks.table2_memory",    # step time/memory: DP vs GradAccum
    "table4": "benchmarks.table4_batch",     # batch-size ablation
    "zeroshot": "benchmarks.zero_shot",      # Tables 1/3 analog
    "theory": "benchmarks.theory_bound",     # Theorems 1-2 gap vs B
    "roofline": "benchmarks.roofline_table", # §Roofline aggregation
    "kernels": "benchmarks.kernel_bench",    # contrastive kernel perf (DESIGN.md §5)
}

# slow full-sweep benches only run when selected explicitly (or via --json)
_OPT_IN = {"kernels"}

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kernels.json")


def _run_kernel_bench_json() -> int:
    """Run the kernel bench and gate it against the checked-out
    BENCH_kernels.json. On pass the file is refreshed (committing it is how
    the perf trajectory ratchets forward — review its git diff, since
    sub-threshold drift accumulates by design); on failure the baseline is
    kept and the fresh numbers go to BENCH_kernels.json.new, so re-running
    can't silently accept a regression by comparing it against itself.
    Returns rc."""
    from benchmarks import kernel_bench
    from scripts import check_bench

    baseline = None
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            baseline = json.load(f)
    fresh = kernel_bench.run()
    if baseline is None:
        kernel_bench.write_json(BENCH_JSON, fresh)
        print("run.py --json: no prior baseline; wrote initial "
              f"{BENCH_JSON}", file=sys.stderr)
        return 0
    print(f"check_bench: {check_bench.summarize(fresh, baseline)}")
    failures = check_bench.compare(fresh, baseline)
    for line in failures:
        print(f"check_bench: REGRESSION {line}", file=sys.stderr)
    if failures:
        kernel_bench.write_json(BENCH_JSON + ".new", fresh)
        print(f"run.py --json: baseline kept; fresh (regressed) numbers in "
              f"{BENCH_JSON}.new", file=sys.stderr)
        return 1
    kernel_bench.write_json(BENCH_JSON, fresh)
    if os.path.exists(BENCH_JSON + ".new"):
        os.remove(BENCH_JSON + ".new")  # stale output of an older failed run
    print("check_bench: OK")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(TABLES), default=None)
    ap.add_argument("--json", action="store_true",
                    help="run the kernel bench, rewrite BENCH_kernels.json, "
                         "and fail on >1.3x regression vs the committed file")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = 0
    for name, mod_name in TABLES.items():
        if args.only and name != args.only:
            continue
        if name in _OPT_IN and (args.json or args.only != name):
            continue  # opt-in only; with --json the gate runs it instead
        try:
            importlib.import_module(mod_name).run()
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        if args.only not in (None, "kernels"):
            print(f"run.py: --json ignored with --only {args.only} "
                  "(the kernel gate is out of scope)", file=sys.stderr)
        else:
            failed += _run_kernel_bench_json()
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
