"""Host-side input-pipeline throughput bench (DESIGN.md §9.4).

The input layer must hide its host-side cost behind the device step — at
paper scale (6.6B pairs, 65536 global batch) an unprefetched loader stalls
every step by the full generation latency. This bench measures, per batch:

  gen_ref/clean            raw sharded-loader batch generation (images +
                           captions + tokenization) — the ``*_ref``
                           host-drift anchor (scripts/check_bench.py)
  gen/augmented            generation + the default augmentation pipeline
                           (crop jitter, flip, channel noise). UNGATED
                           ride-along: its absolute time tracks the clean
                           entry; the derived overhead ratio is the number
                           DESIGN.md §9.4 quotes
  pipeline_ref/unprefetched  produce → consume serially (consumer = a
                           fixed simulated device step)
  pipeline/prefetch_d2     the same consumer fed by data.pipeline's
                           2-deep background Prefetcher — generation
                           overlaps the step, so per-batch time must drop
                           toward max(gen, step)
  pipeline/prefetch_d4     depth sweep point (deeper buffering only pays
                           off under jittery consumers; recorded for the
                           trajectory)

Committed invariant (BENCH_data.json, gated through benchmarks/run.py
--json): ``pipeline/prefetch_d2`` carries ``must_beat:
pipeline_ref/unprefetched`` — prefetching must beat the serial loop on
every host. Absolute timings ride the normal 1.3x cross-run gate (they sit
under the 50ms interpret floor, so in practice the must_beat carries it).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import csv_line, write_json
from repro.data import make_world
from repro.data.pipeline import Prefetcher
from repro.data.sharded import (ShardedLoader, default_augmentations,
                                load_tokenizer)

BATCH = 512
TEXT_LEN = 16
N_BATCHES = 12          # batches per timed run
REPEATS = 3             # min-of-N runs (scheduler-noise robustness)
STEP_S = 0.010          # simulated device-step latency the pipeline must
                        # hide; sleep-based (GIL-free) so generation — which
                        # is partly GIL-bound Python — can actually overlap


def _loader(augment: bool) -> ShardedLoader:
    world = make_world(np.random.default_rng(0), n_classes=32)
    return ShardedLoader(world, load_tokenizer(), BATCH, seed=0,
                         text_len=TEXT_LEN,
                         augment=default_augmentations() if augment else ())


def _consume(batch) -> float:
    """The simulated device step: fixed latency + a touch of every leaf
    (so laziness can't fake the overlap)."""
    s = float(batch["images"]["image"][0, 0, 0, 0])
    s += float(batch["texts"]["tokens"][0, 0])
    time.sleep(STEP_S)
    return s


def _us_per_batch(run_once) -> float:
    """Min-of-REPEATS wall time of ``run_once()`` (N_BATCHES batches),
    in µs per batch."""
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        run_once()
        best = min(best, time.perf_counter() - t0)
    return best / N_BATCHES * 1e6


def _time_generation(loader: ShardedLoader) -> float:
    def once():
        for step in range(N_BATCHES):
            loader.local_batch_at(step)
    return _us_per_batch(once)


def _time_unprefetched(loader: ShardedLoader) -> float:
    def once():
        for step in range(N_BATCHES):
            _consume(loader.local_batch_at(step))
    return _us_per_batch(once)


def _time_prefetched(loader: ShardedLoader, depth: int) -> float:
    def once():
        pf = Prefetcher(loader.local_batch_at, depth=depth)
        try:
            for _ in range(N_BATCHES):
                _consume(next(pf))
        finally:
            pf.close()
    return _us_per_batch(once)


def run(json_path: str | None = None):
    """Run the bench; optionally write the BENCH_data.json payload."""
    clean, aug = _loader(augment=False), _loader(augment=True)
    entries: dict = {}

    us_clean = round(_time_generation(clean), 1)
    us_aug = round(_time_generation(aug), 1)
    entries["gen_ref/clean"] = {"us": us_clean}
    entries["gen/augmented"] = {
        "us": us_aug, "ungated": True,
        "overhead_vs_clean": round(us_aug / us_clean, 2)}
    csv_line("data/gen_ref/clean", us_clean, f"B={BATCH}")
    csv_line("data/gen/augmented", us_aug,
             f"{us_aug / us_clean:.2f}x_overhead")

    us_serial = round(_time_unprefetched(aug), 1)
    entries["pipeline_ref/unprefetched"] = {"us": us_serial}
    csv_line("data/pipeline_ref/unprefetched", us_serial,
             f"step={STEP_S*1e3:.0f}ms")
    for depth in (2, 4):
        us_p = round(_time_prefetched(aug, depth), 1)
        entries[f"pipeline/prefetch_d{depth}"] = {
            "us": us_p, "speedup_vs_serial": round(us_serial / us_p, 2)}
        csv_line(f"data/pipeline/prefetch_d{depth}", us_p,
                 f"{us_serial / us_p:.2f}x_vs_serial")
    entries["pipeline/prefetch_d2"]["must_beat"] = "pipeline_ref/unprefetched"

    result = {
        "meta": {
            "backend": "host",          # pure numpy — no accelerator at all
            "interpret": True,          # keeps the 50ms jitter floor active
            "shape": {"batch": BATCH, "text_len": TEXT_LEN,
                      "n_batches": N_BATCHES, "step_ms": STEP_S * 1e3},
        },
        "entries": entries,
    }
    if json_path:
        write_json(json_path, result)
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write BENCH_data.json-style output here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(json_path=args.json)


if __name__ == "__main__":
    main()
