"""Paper Table 2 analog: step time & peak activation memory for
  (a) vanilla data-parallel (monolithic batch),
  (b) Pipelining & GradAccum (Algorithm 1, microbatched),
as the contrastive batch B grows, measured on CPU at reduced scale; the SPMD
column is roofline-derived from the dry-run artifacts (no multi-device
hardware here — see EXPERIMENTS.md §Dry-run).

Derived column: peak live activation bytes estimated from the batch actually
materialized per tower pass (B·Mem vs M·Mem — the paper's Θ analysis)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line, timeit, tiny_dual_cfg, world_and_tok
from repro.core.contrastive import contrastive_loss
from repro.core.gradaccum import contrastive_step
from repro.data import contrastive_batch
from repro.models import dual_encoder as de


def run():
    cfg = tiny_dual_cfg()
    world, tok, rng = world_and_tok(cfg)
    params = de.init_params(cfg, jax.random.key(0))
    enc_i = lambda p, im: de.encode_image(cfg, p, im)   # noqa: E731
    enc_t = lambda p, tx: de.encode_text(cfg, p, tx)    # noqa: E731

    def monolithic(p, batch):
        def loss_fn(p):
            x = enc_i(p, batch["images"])
            y = enc_t(p, batch["texts"])
            return contrastive_loss(x, y, jnp.exp(p["log_tau"]))[0]
        return jax.grad(loss_fn)(p)

    d_model = cfg.image_tower.d_model
    act_per_example = (cfg.image_tower.frontend_len * d_model * 4
                       * (cfg.image_tower.n_layers * 6))  # rough live set

    for B in (32, 64, 128):
        batch, _ = contrastive_batch(world, tok, B, rng)
        batch = jax.tree.map(jnp.asarray, batch)
        us_mono, _ = timeit(jax.jit(monolithic), params, batch, iters=3)
        csv_line(f"table2/dp_B{B}", us_mono, f"act_bytes={B*act_per_example}")
        for M in (8, 32):
            if M > B:
                continue
            K = B // M
            fn = jax.jit(lambda p, b: contrastive_step(
                enc_i, enc_t, p, b, K)[2])
            us_ga, _ = timeit(fn, params, batch, iters=3)
            csv_line(f"table2/gradaccum_B{B}_M{M}", us_ga,
                     f"act_bytes={M*act_per_example}")
