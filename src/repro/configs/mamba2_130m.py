"""Mamba2-130M [arXiv:2405.21060] — SSD (state-space duality), attention-free.

24L d_model=768, ssm_state=128, vocab=50280. No attention, no FFN (the Mamba2
block is the whole mixer). Decode uses O(1) recurrent state.
"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    source="arXiv:2405.21060",
)
register(CONFIG)
