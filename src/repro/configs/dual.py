"""Dual-encoder (BASIC) config: an image tower + a text tower + shared embed dim."""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DualEncoderConfig:
    name: str
    image_tower: ArchConfig      # encoder-family ArchConfig consuming patch embeds
    text_tower: ArchConfig       # encoder-family ArchConfig consuming tokens
    embed_dim: int               # D: shared unit-sphere embedding size
    init_temperature: float = 0.07   # tau; learnable log-temperature parameter
    # text pooling: BASIC averages top-layer representations (paper §7.2),
    # unlike ALIGN/BERT's [CLS].
    text_pool: str = "mean"
    image_pool: str = "mean"
    source: str = "arXiv:2111.10050"


def _tower(name, L, d, H, dff, vocab, frontend=None, frontend_len=0,
           head_dim=None, image_size=0, patch_size=0) -> ArchConfig:
    return ArchConfig(
        name=name, family="encoder", n_layers=L, d_model=d, n_heads=H,
        n_kv_heads=H, d_ff=dff, vocab=vocab, causal=False, frontend=frontend,
        frontend_len=frontend_len, head_dim=head_dim, rope_theta=1e4,
        image_size=image_size, patch_size=patch_size,
        source="arXiv:2111.10050",
    )


def smoke_dual_variant(cfg: DualEncoderConfig,
                       embed_dim: int = 32) -> DualEncoderConfig:
    """CPU-sized variant of a dual-encoder config: both towers shrunk via
    ``smoke_variant`` and the shared embedding dim reduced. The ONE
    smoke-dual transform — trainer smoke runs, memstats accounting rows,
    bench tiny configs and tests must all build theirs here so the model
    they describe cannot drift apart."""
    from repro.configs.base import smoke_variant
    return dataclasses.replace(
        cfg, image_tower=smoke_variant(cfg.image_tower),
        text_tower=smoke_variant(cfg.text_tower), embed_dim=embed_dim)
