from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    ArchConfig,
    InputShape,
    MoEConfig,
    SSMConfig,
    applicable_shapes,
    get_arch,
    list_archs,
    register,
    smoke_variant,
)
from repro.configs.dual import (  # noqa: F401
    DualEncoderConfig,
    smoke_dual_variant,
)
