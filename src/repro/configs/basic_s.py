"""BASIC-S (paper Table 5): CoAtNet-0 image tower (25M) + 6L/1024 text tower.

The image tower is a transformer backbone over a REAL linear-patchify
frontend (models.frontends): raw 224×224×3 images, 16-pixel patches →
196 patch embeddings (the CoAtNet conv *stages* are approximated by the
single patchify conv; DESIGN.md §8). Text tower: 6 layers, hidden 1024,
head dim 64 (Table 5).
"""
from repro.configs.base import register
from repro.configs.dual import DualEncoderConfig, _tower

IMAGE = _tower("basic-s-image", L=8, d=768, H=12, dff=3072, vocab=0,
               frontend="vision", frontend_len=196,
               image_size=224, patch_size=16)
TEXT = _tower("basic-s-text", L=6, d=1024, H=16, dff=4096, vocab=32768,
              head_dim=64)

CONFIG = DualEncoderConfig(name="basic-s", image_tower=IMAGE, text_tower=TEXT,
                           embed_dim=512)
register(CONFIG)
