"""Qwen3-32B [hf:Qwen/Qwen3-8B family] — dense decoder with qk_norm + GQA.

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    source="hf:Qwen/Qwen3-8B",
)
register(CONFIG)
