"""Config system: architecture configs, input shapes, and the registry.

Every assigned architecture is a frozen dataclass instance constructed in its own
``configs/<id>.py`` module and registered here by importing ``repro.configs``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    # Arctic-style: a small dense FFN runs in parallel with the MoE and is added
    # residually.
    dense_residual: bool = False
    # apply MoE every Nth block (1 = every block). Jamba uses 2.
    every: int = 1
    load_balance_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128          # N (SSD state size)
    head_dim: int = 64            # P (channels per SSD head)
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256              # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attention-free
    n_kv_heads: int
    d_ff: int                     # 0 for attention-free (mamba)
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # tokens; None = full attention
    causal: bool = True           # False for encoder-only (hubert)
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: attention appears at layer indices where (i % attn_every == attn_every-1);
    # all other layers are mamba. attn_every=1 means pure attention.
    attn_every: int = 1
    # attention backend (models.attention registry): 'naive' (materialized
    # scores; paper-era baseline), 'chunked' (flash-style online blocks in
    # pure XLA), 'pallas' (kernels/flash_attention fwd+bwd kernels), or
    # 'auto' (platform pick with graceful fallback).
    attn_impl: str = "naive"
    attn_block: int = 512
    # modality frontend: 'vision' is REAL (raw images linear-patchified by
    # models.frontends using the geometry below); 'audio' remains a stub
    # (input_specs provides precomputed frame embeddings).
    frontend: Optional[str] = None
    frontend_len: int = 0         # number of frontend positions (vision patches)
    image_size: int = 0           # vision: square input side, pixels
    patch_size: int = 0           # vision: patchify window/stride, pixels
    channels: int = 3             # vision: input channels
    source: str = ""              # citation

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        assert self.n_heads > 0
        return self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind: 'attn', 'mamba'."""
        if self.family == "ssm":
            return tuple("mamba" for _ in range(self.n_layers))
        if self.family == "hybrid":
            return tuple(
                "attn" if (i % self.attn_every) == self.attn_every - 1 else "mamba"
                for i in range(self.n_layers)
            )
        return tuple("attn" for _ in range(self.n_layers))

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        if self.moe is None:
            return tuple(False for _ in range(self.n_layers))
        return tuple((i % self.moe.every) == self.moe.every - 1
                     for i in range(self.n_layers))

    # ---- parameter counting (analytic; used by roofline / MODEL_FLOPS) ----
    def param_counts(self) -> dict:
        """Returns dict with total and active parameter counts."""
        d, dff, V = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim if self.n_heads else 0
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd if self.n_heads else 0
        attn = d * q + 2 * d * kv + q * d          # wq, wk, wv, wo
        ffn = 3 * d * dff                           # swiglu: gate, up, down
        mamba = 0
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            # in_proj (z,x,B,C,dt), conv, out_proj, A,D per head
            mamba = d * (2 * d_in + 2 * s.state_dim + nheads) \
                + s.conv_width * (d_in + 2 * s.state_dim) \
                + d_in * d + 2 * nheads
        total = 0
        active = 0
        kinds = self.layer_kinds()
        moe_mask = self.moe_layer_mask()
        for i in range(self.n_layers):
            if kinds[i] == "attn":
                total += attn + 2 * d  # block norms
                active += attn + 2 * d
            else:
                total += mamba + 2 * d
                active += mamba + 2 * d
            if kinds[i] == "attn" or self.family in ("hybrid",):
                pass
            if self.family == "ssm":
                continue  # mamba2 has no separate FFN
            if moe_mask[i]:
                m = self.moe
                total += m.num_experts * ffn
                active += m.top_k * ffn
                if m.dense_residual:
                    total += ffn
                    active += ffn
            else:
                total += ffn
                active += ffn
        emb = V * d
        total += emb + d
        active += emb + d
        if not self.tie_embeddings:
            total += V * d
            active += V * d
        return {"total": total, "active": active}


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    # the paper's own training shape: B=65536 image-text pairs, 64-token
    # captions (paper SS7.1), Algorithm-1 GradAccum with M=8192 (App. E)
    "contrastive_64k": InputShape("contrastive_64k", 64, 65536, "contrastive"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}

_ARCH_MODULES = [
    "hubert_xlarge", "internvl2_76b", "minitron_4b", "mamba2_130m",
    "mixtral_8x22b", "internlm2_20b", "jamba_1_5_large_398b", "qwen3_32b",
    "llama3_2_1b", "arctic_480b",
    # the paper's own models (dual-encoder towers)
    "basic_s", "basic_m", "basic_l",
]


def register(cfg) -> None:
    _REGISTRY[cfg.name] = cfg


def get_arch(name: str):
    """Look up an arch config by id (dashes or underscores)."""
    _ensure_loaded()
    key = name.replace("-", "_").replace(".", "_")
    for k, v in _REGISTRY.items():
        if k.replace("-", "_").replace(".", "_") == key:
            return v
    raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


_loaded = False


def _ensure_loaded():
    global _loaded
    if _loaded:
        return
    _loaded = True
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def applicable_shapes(cfg: ArchConfig):
    """The (documented) skip matrix from DESIGN.md §4."""
    names = ["train_4k", "prefill_32k"]
    if cfg.causal:  # encoder-only archs have no decode step
        names.append("decode_32k")
        subquadratic = (
            cfg.family in ("ssm", "hybrid")
            or cfg.sliding_window is not None
        )
        if subquadratic:
            names.append("long_500k")
    return [INPUT_SHAPES[n] for n in names]


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """A reduced config of the same family: 2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    if heads and cfg.n_kv_heads == cfg.n_heads:
        kv = heads  # preserve MHA (e.g. hubert)
    else:
        kv = min(cfg.n_kv_heads, max(1, heads // 2)) if heads else 0
    changes = dict(
        n_layers=2,
        d_model=d,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=(d // heads if heads else None),
        d_ff=min(cfg.d_ff, 512),
        vocab=min(cfg.vocab, 512),
        frontend_len=min(cfg.frontend_len, 16),
    )
    if cfg.frontend == "vision":
        # keep frontend_len == (image_size // patch_size)² after shrinking
        side = int(changes["frontend_len"] ** 0.5)
        assert side * side == changes["frontend_len"], changes["frontend_len"]
        ps = min(cfg.patch_size or 4, 4)
        changes["patch_size"] = ps
        changes["image_size"] = side * ps
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4))
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=32, chunk=32)
    if cfg.family == "hybrid":
        changes["attn_every"] = 2
    if cfg.sliding_window is not None:
        changes["sliding_window"] = 64
    return dataclasses.replace(cfg, **changes)
