"""HuBERT-XLarge [arXiv:2106.07447] — audio encoder-only transformer.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (k-means cluster targets).
The conv waveform feature extractor is a STUB: ``input_specs`` provides
precomputed frame embeddings (batch, seq, d_model). Training objective is
masked-frame cluster prediction (BERT-style) over the 504-unit codebook.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    frontend="audio",
    rope_theta=1e4,
    source="arXiv:2106.07447",
)
register(CONFIG)
