"""InternVL2-76B [arXiv:2404.16821] — InternViT-6B vision encoder + InternLM2 LLM.

We implement the language backbone (80L d_model=8192 64H GQA kv=8 d_ff=28672
vocab=128256). The InternViT encoder + MLP projector is a STUB: ``input_specs``
provides precomputed patch embeddings (batch, n_patches, d_model) that are
prepended to the token embeddings.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    frontend="vision",
    frontend_len=256,   # patch embeddings per image
    source="arXiv:2404.16821",
)
register(CONFIG)
