"""InternVL2-76B [arXiv:2404.16821] — InternViT-6B vision encoder + InternLM2 LLM.

We implement the language backbone (80L d_model=8192 64H GQA kv=8 d_ff=28672
vocab=128256). The InternViT encoder + MLP projector is approximated by the
shared linear-patchify vision frontend (models.frontends): raw 256×256×3
images → 256 patch embeddings prepended to the token embeddings.
"""
from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    frontend="vision",
    frontend_len=256,   # (256/16)² patches per image
    image_size=256,
    patch_size=16,
    source="arXiv:2404.16821",
)
register(CONFIG)
