"""BASIC-L (paper Table 5): CoAtNet-7 image tower (2.4B) + 12L/2048 text tower."""
from repro.configs.base import register
from repro.configs.dual import DualEncoderConfig, _tower

IMAGE = _tower("basic-l-image", L=48, d=2048, H=32, dff=8192, vocab=0,
               frontend="vision", frontend_len=196,
               image_size=224, patch_size=16)
TEXT = _tower("basic-l-text", L=12, d=2048, H=16, dff=8192, vocab=32768,
              head_dim=128)

CONFIG = DualEncoderConfig(name="basic-l", image_tower=IMAGE, text_tower=TEXT,
                           embed_dim=1024)
register(CONFIG)
