"""Snowflake Arctic (480B) [hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8), MoE 128 experts top-2 with d_ff=4864 each,
plus a dense residual FFN in parallel (dense-MoE hybrid), vocab=32000.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    moe=MoEConfig(num_experts=128, top_k=2, dense_residual=True),
    source="hf:Snowflake/snowflake-arctic-base",
)
register(CONFIG)
