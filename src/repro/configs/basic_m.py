"""BASIC-M (paper Table 5): CoAtNet-3 image tower (168M) + 12L/1024 text tower."""
from repro.configs.base import register
from repro.configs.dual import DualEncoderConfig, _tower

IMAGE = _tower("basic-m-image", L=24, d=1024, H=16, dff=4096, vocab=0,
               frontend="vision", frontend_len=196,
               image_size=224, patch_size=16)
TEXT = _tower("basic-m-text", L=12, d=1024, H=8, dff=4096, vocab=32768,
              head_dim=128)

CONFIG = DualEncoderConfig(name="basic-m", image_tower=IMAGE, text_tower=TEXT,
                           embed_dim=768)
register(CONFIG)
