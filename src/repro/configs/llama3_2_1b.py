"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B] — small llama3 dense decoder.

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.

``sliding_window`` is set (beyond-paper SWA variant, DESIGN.md §4) so the dense
long-context decode path (long_500k) is exercised with a bounded ring KV cache.
The canonical model is full-attention; pass ``--variant full`` to drop SWA.
"""
import dataclasses

from repro.configs.base import ArchConfig, register

CONFIG = ArchConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    tie_embeddings=True,
    sliding_window=8192,
    rope_theta=5e5,
    source="hf:meta-llama/Llama-3.2-1B",
)
register(CONFIG)

FULL_ATTENTION_VARIANT = dataclasses.replace(
    CONFIG, name="llama3.2-1b-full", sliding_window=None)
