"""Mixtral-8x22B [arXiv:2401.04088] — 8-expert top-2 MoE with sliding-window attn.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2, SWA.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2),
    source="arXiv:2401.04088",
)
register(CONFIG)
