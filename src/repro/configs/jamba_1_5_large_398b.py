"""Jamba-1.5-Large (398B total) [arXiv:2403.19887] — Mamba+attention hybrid MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536; attention every 8th
layer (1:7 attn:mamba interleave), MoE 16 experts top-2 every other layer.
Decode: mamba layers keep O(1) state; attention layers keep a KV cache.
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    attn_every=8,
    moe=MoEConfig(num_experts=16, top_k=2, every=2),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    source="arXiv:2403.19887",
)
register(CONFIG)
