"""Schema-versioned run log: one JSONL record per train step (§11.3).

The committed artifact of a run is its metric TRAJECTORY (Cherti et al.,
PAPERS.md) — not a final number — so the trainer streams one record per
step to ``<run_dir>/runlog.jsonl``:

  run_start   — schema version, wall-clock time, run meta (arch, batch,
                objective, flags) — always the file's first record
  resume      — ``{"resumed_from": step}`` marker appended when a
                ``--resume`` relaunch continues the SAME file, so the two
                segments never silently interleave
  step        — loss, grad_norm, examples_per_sec, and the full step-time
                breakdown (``data_wait_s`` / ``device_step_s`` /
                ``ckpt_stall_s`` + total ``step_s``)
  checkpoint  — save/retention/degrade/preempt events with their step
  metrics     — a final ``Registry.snapshot()`` dump
  anomaly     — a health detector fired (detector, step, severity,
                value — written by ``obs/health.py``'s ``HealthMonitor``)
  event       — anything else worth a timestamped line

Every record carries ``{"schema": SCHEMA_VERSION, "kind": ..., "t": ...}``.
Readers REJECT records from a different schema version (``RunlogError``)
instead of guessing: the version only moves when the record shape does,
and ``scripts/check_runlog.py`` gates committed samples against it.

Writes are append-only line-buffered JSON — cheap enough for every step
(``benchmarks/obs_bench.py`` ``micro/runlog_step``), crash-tolerant by
construction (a torn final line is detected and reported by the reader,
never fatal to earlier records).
"""
from __future__ import annotations

import json
import os
import time
from typing import Iterator, List, Optional

SCHEMA_VERSION = 1

# the step-time breakdown every step record must carry (§11.3): host time
# waiting on the input pipeline, device time under the jitted step, and
# time the checkpoint path held the loop
STEP_BREAKDOWN_KEYS = ("data_wait_s", "device_step_s", "ckpt_stall_s")
STEP_REQUIRED_KEYS = (("step", "loss", "examples_per_sec", "step_s")
                      + STEP_BREAKDOWN_KEYS)
KINDS = ("run_start", "resume", "step", "checkpoint", "metrics",
         "anomaly", "event")

# an anomaly record names its detector, anchors to a step, grades itself,
# and carries the offending value (obs/health.py emits these)
ANOMALY_SEVERITIES = ("warn", "critical")
ANOMALY_REQUIRED_KEYS = ("detector", "step", "severity", "value")


class RunlogError(ValueError):
    """A runlog record failed schema validation (wrong version, unknown
    kind, missing/ill-typed required keys)."""


def validate_record(rec: object) -> List[str]:
    """Schema-v1 errors for one decoded record (empty list = valid)."""
    if not isinstance(rec, dict):
        return [f"record is {type(rec).__name__}, not an object"]
    errors = []
    schema = rec.get("schema")
    if schema != SCHEMA_VERSION:
        errors.append(f"schema {schema!r} != supported {SCHEMA_VERSION}")
    kind = rec.get("kind")
    if kind not in KINDS:
        errors.append(f"unknown kind {kind!r} (have {KINDS})")
    if not isinstance(rec.get("t"), (int, float)):
        errors.append("missing/non-numeric wall-clock key 't'")
    if kind == "step":
        for key in STEP_REQUIRED_KEYS:
            if not isinstance(rec.get(key), (int, float)):
                errors.append(f"step record missing/non-numeric {key!r}")
    if kind == "resume" and not isinstance(rec.get("resumed_from"), int):
        errors.append("resume record missing integer 'resumed_from'")
    if kind == "anomaly":
        if not isinstance(rec.get("detector"), str):
            errors.append("anomaly record missing string 'detector'")
        if not isinstance(rec.get("step"), int):
            errors.append("anomaly record missing integer 'step'")
        if rec.get("severity") not in ANOMALY_SEVERITIES:
            errors.append(f"anomaly severity {rec.get('severity')!r} not "
                          f"in {ANOMALY_SEVERITIES}")
        if not isinstance(rec.get("value"), (int, float)):
            errors.append("anomaly record missing numeric 'value'")
    return errors


class RunLogger:
    """Append-only JSONL writer for one run directory.

    Fresh file: writes the ``run_start`` header. Resumed run
    (``resumed_from=step``): appends a ``resume`` marker to the SAME file
    instead of a second header, so a reader sees one continuous
    trajectory with explicit segment boundaries. Context-manager
    friendly; ``close()`` is idempotent.
    """

    def __init__(self, path: str, *, meta: Optional[dict] = None,
                 resumed_from: Optional[int] = None):
        self.path = path
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        fresh = not os.path.exists(path) or os.path.getsize(path) == 0
        self._f = open(path, "a", buffering=1)   # line-buffered: one
        # record per write() — a crash tears at most the final line
        if fresh:
            self.log("run_start", meta=dict(meta or {}))
        if resumed_from is not None:
            self.log("resume", resumed_from=int(resumed_from),
                     meta=dict(meta or {}))

    def log(self, kind: str, **fields) -> dict:
        """Write one ``kind`` record with ``fields``; returns the record
        as written (schema/kind/t filled in)."""
        if kind not in KINDS:
            raise RunlogError(f"unknown record kind {kind!r}")
        rec = {"schema": SCHEMA_VERSION, "kind": kind, "t": time.time()}
        rec.update(fields)
        errors = validate_record(rec)
        if errors:
            raise RunlogError(f"refusing to write invalid {kind} record: "
                              + "; ".join(errors))
        self._f.write(json.dumps(rec) + "\n")
        return rec

    def log_step(self, step: int, *, loss: float, data_wait_s: float,
                 device_step_s: float, ckpt_stall_s: float, step_s: float,
                 examples_per_sec: float, **extra) -> dict:
        """The per-step record: loss + the full time breakdown, plus any
        ``extra`` numeric fields (grad_norm, lr, ...)."""
        return self.log("step", step=int(step), loss=float(loss),
                        data_wait_s=float(data_wait_s),
                        device_step_s=float(device_step_s),
                        ckpt_stall_s=float(ckpt_stall_s),
                        step_s=float(step_s),
                        examples_per_sec=float(examples_per_sec), **extra)

    def close(self) -> None:
        """Flush and close the underlying file (idempotent)."""
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def iter_runlog(path: str, *, strict: bool = True) -> Iterator[dict]:
    """Yield validated records from a runlog JSONL file.

    ``strict=True`` raises ``RunlogError`` on the first invalid or
    unparseable record — EXCEPT a torn final line (truncated by a crash
    mid-write), which is skipped: earlier records are still a valid
    trajectory. ``strict=False`` skips invalid records silently."""
    with open(path) as f:
        lines = f.read().splitlines()
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            if i == len(lines) - 1:
                return            # torn final line: crash mid-write
            if strict:
                raise RunlogError(f"{path}:{i + 1}: unparseable JSON "
                                  f"({e})") from e
            continue
        errors = validate_record(rec)
        if errors:
            if strict:
                raise RunlogError(f"{path}:{i + 1}: " + "; ".join(errors))
            continue
        yield rec


def read_runlog(path: str, *, strict: bool = True) -> List[dict]:
    """All validated records of ``path`` (see ``iter_runlog``)."""
    return list(iter_runlog(path, strict=strict))
