"""Live metrics endpoint: Prometheus text exposition + stdlib HTTP (§14.3).

Two layers, deliberately separable:

  render_prometheus(snapshot)   pure function from any ``Registry``
                                snapshot to Prometheus text-exposition
                                format 0.0.4 — counters/gauges as single
                                samples, histograms as the full
                                ``_bucket{le=...}`` / ``_sum`` /
                                ``_count`` ladder. Golden-file tested.
  MetricsServer                 a ``http.server.ThreadingHTTPServer`` on
                                a daemon thread serving ``/metrics``
                                (scrape), ``/healthz`` (readiness: 200 or
                                503 from the attached health source), and
                                ``/snapshot.json`` (the raw registry
                                JSON, for humans and tests).

Security posture: the server binds ``127.0.0.1`` by DEFAULT — the
endpoint exposes run internals with no auth, so exposure beyond the host
is an explicit ``host="0.0.0.0"`` opt-in behind whatever network policy
the deployment provides (DESIGN.md §14.3). ``port=0`` asks the kernel for
an ephemeral port; the bound port is re-read from ``server.port`` and,
when a ``run_dir`` is given, written to ``<run_dir>/metrics_port`` so
out-of-process scrapers (and tests) can find it.
"""
from __future__ import annotations

import http.server
import json
import os
import re
import threading
from typing import Callable, Optional

from repro.obs import metrics as obs_metrics

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")
_SERIES = re.compile(r"^(?P<name>[^{]+)(?:\{(?P<labels>.*)\})?$")


def _sanitize_name(name: str) -> str:
    """Map a registry name (``serve/requests``) onto the Prometheus
    metric-name alphabet (``serve_requests``)."""
    out = _NAME_OK.sub("_", name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _parse_series(series: str):
    """Split a snapshot series key (``name{k=v,k2=v2}``) back into
    (sanitized_name, [(k, v), ...])."""
    m = _SERIES.match(series)
    name = _sanitize_name(m.group("name"))
    raw = m.group("labels")
    labels = []
    if raw:
        for pair in raw.split(","):
            k, _, v = pair.partition("=")
            labels.append((_sanitize_name(k), v))
    return name, labels


def _label_str(labels) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape_label(str(v))}"' for k, v in labels)
    return "{" + inner + "}"


def _fmt(v: float) -> str:
    f = float(v)
    if f != f:
        return "NaN"
    if f in (float("inf"), float("-inf")):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def render_prometheus(snapshot: dict) -> str:
    """Render a ``Registry.snapshot()`` dict as Prometheus text
    exposition format 0.0.4.

    Series sharing a base name are grouped under one ``# TYPE`` header;
    histogram summaries become the cumulative ``_bucket{le=...}`` ladder
    (finite bounds from the summary's ``buckets`` key, then the implied
    ``le="+Inf"`` = ``count``) plus ``_sum`` and ``_count`` samples.
    Output ends with a trailing newline as the format requires.
    """
    lines = []

    def emit_scalars(kind: str, table: dict) -> None:
        by_name: dict = {}
        for series, value in sorted(table.items()):
            name, labels = _parse_series(series)
            by_name.setdefault(name, []).append((labels, value))
        for name, rows in sorted(by_name.items()):
            lines.append(f"# TYPE {name} {kind}")
            for labels, value in rows:
                lines.append(f"{name}{_label_str(labels)} {_fmt(value)}")

    emit_scalars("counter", snapshot.get("counters", {}))
    emit_scalars("gauge", snapshot.get("gauges", {}))

    by_name: dict = {}
    for series, summ in sorted(snapshot.get("histograms", {}).items()):
        name, labels = _parse_series(series)
        by_name.setdefault(name, []).append((labels, summ))
    for name, rows in sorted(by_name.items()):
        lines.append(f"# TYPE {name} histogram")
        for labels, summ in rows:
            for le, cum in summ.get("buckets", []):
                lines.append(
                    f"{name}_bucket"
                    f"{_label_str(labels + [('le', _fmt(le))])} {cum}")
            lines.append(
                f"{name}_bucket"
                f"{_label_str(labels + [('le', '+Inf')])} {summ['count']}")
            lines.append(f"{name}_sum{_label_str(labels)} "
                         f"{_fmt(summ['sum'])}")
            lines.append(f"{name}_count{_label_str(labels)} "
                         f"{summ['count']}")
    return "\n".join(lines) + "\n"


class _Handler(http.server.BaseHTTPRequestHandler):
    """Routes ``/metrics`` / ``/healthz`` / ``/snapshot.json`` against the
    owning ``MetricsServer``'s registry and health source."""

    server_version = "repro-obs/1"

    def do_GET(self):  # noqa: N802 (stdlib handler naming)
        owner: "MetricsServer" = self.server.owner  # type: ignore[attr-defined]
        path = self.path.split("?", 1)[0]
        if path == "/metrics":
            body = render_prometheus(owner.registry.snapshot())
            self._reply(200, body, CONTENT_TYPE)
        elif path == "/healthz":
            status = owner.health_status()
            code = 200 if status.get("healthy", True) else 503
            self._reply(code, json.dumps(status, sort_keys=True) + "\n",
                        "application/json")
        elif path == "/snapshot.json":
            self._reply(200, owner.registry.to_json(indent=2) + "\n",
                        "application/json")
        else:
            self._reply(404, "not found\n", "text/plain")

    def _reply(self, code: int, body: str, ctype: str) -> None:
        data = body.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def log_message(self, fmt, *args):
        """Silence per-request stderr lines (scrapes arrive every few
        seconds; the trainer's stdout is for training)."""


class MetricsServer:
    """Serves a ``Registry`` (and optional health source) over HTTP.

    ``health`` is any zero-arg callable returning a dict with a boolean
    ``healthy`` key — ``HealthMonitor.status`` and ``SLOTracker.status``
    both fit; ``/healthz`` answers 200/503 from it (absent source: always
    healthy). The server thread is a daemon: it never blocks process
    exit, and ``stop()`` shuts it down deterministically for tests.
    """

    def __init__(self, registry: obs_metrics.Registry, *,
                 health: Optional[Callable[[], dict]] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 run_dir: Optional[str] = None):
        self.registry = registry
        self._health = health
        self.host = host
        self.run_dir = run_dir
        self._httpd = http.server.ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.owner = self  # type: ignore[attr-defined]
        self.port = self._httpd.server_address[1]
        self._thread: Optional[threading.Thread] = None

    def health_status(self) -> dict:
        """The current health payload (``{"healthy": True}`` when no
        source is attached)."""
        if self._health is None:
            return {"healthy": True}
        return self._health()

    def start(self) -> "MetricsServer":
        """Start serving on the daemon thread; idempotent. Writes the
        bound port to ``<run_dir>/metrics_port`` when a run dir was
        given, so other processes can discover an ephemeral port."""
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever, name="obs-metrics-http",
                daemon=True)
            self._thread.start()
            if self.run_dir:
                with open(os.path.join(self.run_dir, "metrics_port"),
                          "w") as f:
                    f.write(f"{self.port}\n")
        return self

    def stop(self) -> None:
        """Shut the server down and join its thread; idempotent."""
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join(timeout=5)
            self._thread = None
        self._httpd.server_close()

    @property
    def url(self) -> str:
        """Base URL of the endpoint (``http://host:port``)."""
        return f"http://{self.host}:{self.port}"

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
