"""Runlog trajectory summarizer: ``python -m repro.obs.report <runlog>``.

Reads a schema-v1 runlog JSONL (obs/runlog.py) and prints the run's
trajectory the way the paper-scale fights are judged (§11.3): loss
first→last, throughput, and EXACT p50/p90/p99 of every step-time
component (computed from the raw per-step records, not histogram
buckets — the runlog keeps full resolution; registry histograms are the
in-process approximation), plus checkpoint / resume / degrade events.
``--health`` adds the run's anomaly trail and the ``health/*`` / SLO
series from the final metrics snapshot (§14). A runlog with a
``run_start`` but zero ``step`` records (a run that died before step 1)
reports "no steps" instead of crashing.
"""
from __future__ import annotations

import argparse
import math
import sys
from typing import List, Sequence

from repro.obs import runlog as rl
from repro.obs import windows as _windows

_PCTS = (50, 90, 99)
_PHASES = rl.STEP_BREAKDOWN_KEYS + ("step_s",)


def _percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile of ``values`` (exact, numpy
    'linear' convention); NaN for an empty sequence — a zero-step runlog
    must summarize, not crash."""
    return _windows.percentile(values, q)


def summarize(records: List[dict]) -> dict:
    """Aggregate a record list into the report's plain-dict form:
    ``{"steps", "loss", "throughput", "phases", "events", "resumes",
    "anomalies", "final_metrics"}``."""
    steps = [r for r in records if r["kind"] == "step"]
    out = {
        "n_records": len(records),
        "steps": len(steps),
        "resumes": [r["resumed_from"] for r in records
                    if r["kind"] == "resume"],
        "events": [r for r in records
                   if r["kind"] in ("checkpoint", "event")],
        "anomalies": [r for r in records if r["kind"] == "anomaly"],
        "final_metrics": next(
            ({k: r.get(k, {}) for k in ("counters", "gauges", "histograms")}
             for r in reversed(records) if r["kind"] == "metrics"), {}),
        "meta": next((r.get("meta", {}) for r in records
                      if r["kind"] == "run_start"), {}),
    }
    if steps:
        losses = [r["loss"] for r in steps]
        out["loss"] = {"first": losses[0], "last": losses[-1],
                       "min": min(losses)}
        eps = [r["examples_per_sec"] for r in steps]
        out["throughput"] = {"examples_per_sec_mean": sum(eps) / len(eps)}
        out["phases"] = {
            phase: {f"p{q}": _percentile([r[phase] for r in steps], q)
                    for q in _PCTS}
            for phase in _PHASES}
        total = sum(r["step_s"] for r in steps) or 1.0
        out["phase_share"] = {
            phase: sum(r[phase] for r in steps) / total
            for phase in rl.STEP_BREAKDOWN_KEYS}
    return out


def format_report(summary: dict) -> str:
    """Human-readable multi-line rendering of ``summarize()``'s output."""
    lines = [f"runlog: {summary['steps']} step records "
             f"({summary['n_records']} total)"]
    if summary["meta"]:
        meta = ", ".join(f"{k}={v}" for k, v in
                         sorted(summary["meta"].items()))
        lines.append(f"run: {meta}")
    if summary["resumes"]:
        lines.append("resumed at step(s): "
                     + ", ".join(str(s) for s in summary["resumes"]))
    if not summary["steps"]:
        lines.append("no steps recorded (run ended before step 1)")
    if summary["steps"]:
        loss = summary["loss"]
        lines.append(f"loss: {loss['first']:.4f} -> {loss['last']:.4f} "
                     f"(min {loss['min']:.4f})")
        lines.append(f"throughput: "
                     f"{summary['throughput']['examples_per_sec_mean']:.1f} "
                     f"examples/sec (mean)")
        lines.append(f"{'phase':<16}" + "".join(f"{f'p{q}':>12}"
                                                for q in _PCTS) + "   share")
        for phase in _PHASES:
            p = summary["phases"][phase]
            share = summary.get("phase_share", {}).get(phase)
            tail = f"  {share * 100:5.1f}%" if share is not None else ""
            lines.append(f"{phase:<16}"
                         + "".join(f"{p[f'p{q}'] * 1e3:10.2f}ms"
                                   for q in _PCTS) + tail)
    for ev in summary["events"]:
        what = ev.get("event", ev["kind"])
        extra = {k: v for k, v in ev.items()
                 if k not in ("schema", "kind", "t", "event")}
        lines.append(f"event: {what} "
                     + " ".join(f"{k}={v}" for k, v in sorted(extra.items())))
        if what == "trace_export" and ev.get("dropped", 0):
            lines.append(f"WARNING: trace ring dropped {ev['dropped']} "
                         f"events past capacity — timeline truncated at "
                         f"the old end")
    n_anom = len(summary.get("anomalies", []))
    if n_anom:
        lines.append(f"anomalies: {n_anom} (rerun with --health for "
                     f"detail)")
    return "\n".join(lines)


def format_health(summary: dict) -> str:
    """``--health`` rendering: the run's anomaly trail plus the
    ``health/*`` and ``*/slo_*`` series from the final metrics record."""
    lines = []
    anomalies = summary.get("anomalies", [])
    lines.append(f"health: {len(anomalies)} anomaly record(s)")
    for a in anomalies:
        msg = a.get("message", "")
        lines.append(f"  [{a['severity']:>8}] step {a['step']:>6} "
                     f"{a['detector']}: value={a['value']:.4g}"
                     + (f"  {msg}" if msg else ""))
    snap = summary.get("final_metrics", {})
    rows = []
    for table in ("counters", "gauges"):
        for name, v in sorted(snap.get(table, {}).items()):
            if name.startswith("health/") or "/slo_" in name:
                rows.append(f"  {name} = {v:g}" if isinstance(v, float)
                            else f"  {name} = {v}")
    if rows:
        lines.append("health/SLO series (final metrics snapshot):")
        lines.extend(rows)
    burn = snap.get("gauges", {}).get("serve/slo_error_budget_burn")
    if burn is not None and math.isfinite(burn):
        lines.append(f"error budget: {'EXHAUSTED' if burn >= 1 else 'ok'} "
                     f"(burn {burn:.2f}; >=1 flips readiness)")
    return "\n".join(lines)


def format_serving(snapshot: dict) -> str:
    """Render a serving metrics snapshot (``Registry.snapshot()`` JSON, or
    the full ``ZeroShotService.stats()`` dict — the ``metrics`` key is
    unwrapped automatically) with the retrieval path front and centre:
    per-stage latency percentiles, the two-stage prune ratio, and
    per-shard winner skew (``serve/retrieval_shard_share`` records the
    MAX per-shard share of top-k winners each call; 1/S is perfectly
    balanced, 1.0 means one shard owns every winner)."""
    snap = snapshot.get("metrics", snapshot)
    hists = snap.get("histograms", {})
    counters = snap.get("counters", {})
    lines = []

    latency = {k: v for k, v in sorted(hists.items())
               if k.startswith("serve/retrieval_latency_s")}
    if latency:
        lines.append(f"{'retrieval latency':<34}{'count':>7}"
                     + "".join(f"{f'p{q}':>12}" for q in _PCTS))
        for name, h in latency.items():
            lines.append(f"{name:<34}{h['count']:>7}"
                         + "".join(f"{h[f'p{q}'] * 1e3:10.2f}ms"
                                   for q in _PCTS))
    for name, h in sorted(hists.items()):
        if name.startswith("serve/retrieval_prune_ratio") and h["count"]:
            mean = h["sum"] / h["count"]
            lines.append(f"prune ratio ({name}): mean {mean:.3f} "
                         f"p50 {h['p50']:.3f} p99 {h['p99']:.3f} "
                         f"over {h['count']} calls "
                         f"(fraction of gallery reranked; lower = "
                         f"coarser stage pruned more)")
        elif name.startswith("serve/retrieval_shard_share") and h["count"]:
            mean = h["sum"] / h["count"]
            lines.append(f"shard skew ({name}): max-share mean {mean:.3f} "
                         f"p99 {h['p99']:.3f} over {h['count']} calls "
                         f"(1/S balanced, 1.0 one shard wins all)")
    serve_counters = {k: v for k, v in sorted(counters.items())
                      if k.startswith("serve/")}
    if serve_counters:
        lines.append("counters: " + " ".join(f"{k}={v}" for k, v in
                                             serve_counters.items()))
    if not lines:
        lines.append("no serve/retrieval_* series in snapshot")
    return "\n".join(lines)


def main(argv=None) -> int:
    """CLI entry: summarize one runlog; non-zero on schema failures."""
    ap = argparse.ArgumentParser(
        description="summarize a runlog JSONL's trajectory and step-time "
                    "percentiles (obs/runlog.py schema v1), or a serving "
                    "metrics snapshot with --serving")
    ap.add_argument("runlog", help="path to runlog.jsonl (or, with "
                                   "--serving, a metrics snapshot JSON)")
    ap.add_argument("--lenient", action="store_true",
                    help="skip invalid records instead of failing")
    ap.add_argument("--serving", action="store_true",
                    help="treat the input as a JSON metrics snapshot "
                         "(Registry.snapshot() or ZeroShotService.stats()) "
                         "and report the serve/retrieval_* series")
    ap.add_argument("--health", action="store_true",
                    help="also render the run's anomaly records and "
                         "health/SLO series (obs/health.py)")
    args = ap.parse_args(argv)
    if args.serving:
        import json
        with open(args.runlog) as f:
            print(format_serving(json.load(f)))
        return 0
    try:
        records = rl.read_runlog(args.runlog, strict=not args.lenient)
    except rl.RunlogError as e:
        print(f"report: INVALID RUNLOG {e}", file=sys.stderr)
        return 1
    summary = summarize(records)
    print(format_report(summary))
    if args.health:
        print(format_health(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
