"""Span tracing: wall-time events in a ring buffer, Perfetto-exportable.

``with tracer.span("data_wait"):`` records one complete event (begin +
duration) into a bounded ring buffer — a long run never grows the buffer
past ``capacity``, the newest events win (``dropped`` counts evictions).
``to_chrome_trace()`` renders the buffer as Chrome ``trace_event`` JSON
(the ``{"traceEvents": [...]}`` object form) that loads directly in
Perfetto / ``chrome://tracing``; every event carries the required
``ph/ts/dur/pid/tid/name`` keys.

Lanes: ``pid`` is the LOGICAL process lane — the trainer records its
data-wait / device-step / ckpt-stall spans on pid 0 while the simulated
multi-host loader records each host's block generation on pid 1+host, so
a single-process simulation renders as the multi-host timeline it models.
``tid`` defaults to a small per-tracer id for the calling OS thread (the
prefetch / flush / checkpoint-writer threads get their own rows).

A ``None`` tracer is the disabled state: the module-level ``span(tracer,
name)`` helper yields immediately without reading the clock, so
uninstrumented runs pay nothing (``benchmarks/obs_bench.py``
``micro/span`` measures the enabled cost).
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from collections import deque
from typing import Optional

REQUIRED_EVENT_KEYS = ("ph", "ts", "dur", "pid", "tid", "name")


class Tracer:
    """Ring-buffered span recorder with Chrome ``trace_event`` export."""

    def __init__(self, capacity: int = 8192):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._events: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._t0 = time.perf_counter_ns()
        self._tids: dict = {}
        self._process_names: dict = {0: "trainer"}
        self.dropped = 0

    # -- recording ---------------------------------------------------------
    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            tid = self._tids.get(ident)
            if tid is None:
                tid = self._tids[ident] = len(self._tids)
            return tid

    def _append(self, event: dict) -> None:
        with self._lock:
            if len(self._events) == self.capacity:
                self.dropped += 1
            self._events.append(event)

    @contextlib.contextmanager
    def span(self, name: str, *, pid: int = 0, tid: Optional[int] = None,
             **args):
        """Record a complete event named ``name`` around the ``with``
        body; ``args`` become the event's Perfetto-visible args."""
        t0 = self._now_us()
        try:
            yield self
        finally:
            event = {"ph": "X", "name": str(name), "ts": t0,
                     "dur": self._now_us() - t0, "pid": int(pid),
                     "tid": self._tid() if tid is None else int(tid)}
            if args:
                event["args"] = {k: _jsonable(v) for k, v in args.items()}
            self._append(event)

    def instant(self, name: str, *, pid: int = 0,
                tid: Optional[int] = None, **args) -> None:
        """Record a zero-duration marker (checkpoint published, resume,
        preemption)."""
        event = {"ph": "i", "s": "t", "name": str(name),
                 "ts": self._now_us(), "dur": 0.0, "pid": int(pid),
                 "tid": self._tid() if tid is None else int(tid)}
        if args:
            event["args"] = {k: _jsonable(v) for k, v in args.items()}
        self._append(event)

    def set_process_name(self, pid: int, name: str) -> None:
        """Label lane ``pid`` (rendered by Perfetto as the process name —
        e.g. pid 1+h as ``host h``)."""
        with self._lock:
            self._process_names[int(pid)] = str(name)

    # -- export ------------------------------------------------------------
    def events(self) -> list:
        """The buffered events, oldest first (copies — safe to mutate)."""
        with self._lock:
            return [dict(e) for e in self._events]

    def to_chrome_trace(self) -> dict:
        """Chrome ``trace_event`` object form: ``process_name`` metadata
        records for every named lane, then the buffered events. The
        top-level ``metadata`` object reports ``dropped`` (events evicted
        past ``capacity`` — a nonzero value means the timeline is
        truncated at the old end) alongside ``capacity`` and the exported
        event count."""
        with self._lock:
            events = [dict(e) for e in self._events]
            names = dict(self._process_names)
            dropped = self.dropped
        meta = [{"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                 "ts": 0, "dur": 0, "args": {"name": label}}
                for pid, label in sorted(names.items())]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms",
                "metadata": {"dropped": dropped, "capacity": self.capacity,
                             "events": len(events)}}

    def export(self, path: str) -> str:
        """Write ``to_chrome_trace()`` JSON to ``path``; returns the
        path (point Perfetto's "Open trace file" at it)."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
            f.write("\n")
        return path


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


@contextlib.contextmanager
def span(tracer: Optional[Tracer], name: str, **kw):
    """``tracer.span(name, **kw)`` when ``tracer`` is a ``Tracer``; a free
    no-op when it is ``None`` — the one helper hot paths call so disabled
    tracing costs nothing."""
    if tracer is None:
        yield None
    else:
        with tracer.span(name, **kw):
            yield tracer
