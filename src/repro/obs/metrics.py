"""Process-wide metrics registry: counters, gauges, histograms (§11.1).

The repo's ONE stats mechanism: ``MicroBatcher``, ``AsyncCheckpointManager``
and ``ShardedLoader`` all hang their instruments off a ``Registry`` instead
of private ad-hoc dicts (their legacy dict-shaped ``stats`` accessors are
now thin views over these counters, back-compat tested).

Design constraints, in order:

  * off-hot-path cheap: an ``inc``/``observe`` is a couple of Python int
    ops under a per-instrument lock (measured in
    ``benchmarks/obs_bench.py`` ``micro/*`` entries);
  * thread-safe: instruments are mutated from the prefetch thread, the
    micro-batcher flush thread, and the checkpoint writer thread
    concurrently — every mutation and every read of an instrument's state
    takes its lock, and child creation takes the registry lock;
  * fixed memory: histograms are FIXED-BUCKET — ``observe`` never
    allocates, percentiles are interpolated from bucket counts at
    ``snapshot()`` time (§11.1 error bound: one bucket width).

Labeled children: ``registry.counter("serve/flushes", reason="size")``
returns the same child for the same ``(name, labels)`` — label maps are
part of the instrument identity, so per-tower / per-host series coexist
under one name.
"""
from __future__ import annotations

import bisect
import json
import math
import threading
from typing import Dict, Optional, Sequence, Tuple


def exponential_buckets(start: float, factor: float,
                        count: int) -> Tuple[float, ...]:
    """``count`` bucket upper bounds growing geometrically from ``start``
    (the standard latency-histogram ladder; an implicit +inf overflow
    bucket always follows)."""
    if start <= 0 or factor <= 1 or count < 1:
        raise ValueError(f"bad bucket spec start={start} factor={factor} "
                         f"count={count}")
    return tuple(start * factor ** i for i in range(count))


# 100µs … ~107s in ×2 steps: covers span costs through checkpoint writes
DEFAULT_LATENCY_BUCKETS_S = exponential_buckets(1e-4, 2.0, 20)
# occupancy/ratio instruments: linear [0, 1] in 0.1 steps
RATIO_BUCKETS = tuple(round(0.1 * i, 1) for i in range(1, 11))


def _label_key(labels: Dict[str, object]) -> Tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _series_name(name: str, labels: Dict[str, object]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in _label_key(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing count (requests, flushes, retries)."""

    __slots__ = ("name", "labels", "_v", "_lock")

    def __init__(self, name: str, labels: Optional[Dict] = None):
        self.name = name
        self.labels = dict(labels or {})
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        """Add ``n`` (must be >= 0) to the count."""
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        with self._lock:
            self._v += n

    @property
    def value(self) -> int:
        """Current count."""
        with self._lock:
            return self._v


class Gauge:
    """Point-in-time level (queue depth, last checkpoint stall)."""

    __slots__ = ("name", "labels", "_v", "_lock")

    def __init__(self, name: str, labels: Optional[Dict] = None):
        self.name = name
        self.labels = dict(labels or {})
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        """Set the level to ``v``."""
        with self._lock:
            self._v = float(v)

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (may be negative) to the level."""
        with self._lock:
            self._v += n

    def dec(self, n: float = 1.0) -> None:
        """Subtract ``n`` from the level."""
        self.inc(-n)

    @property
    def value(self) -> float:
        """Current level."""
        with self._lock:
            return self._v


class Histogram:
    """Fixed-bucket histogram with interpolated percentile summaries.

    ``buckets`` are finite upper bounds (ascending); an implicit +inf
    overflow bucket follows. ``observe`` is O(log n_buckets) and never
    allocates; ``percentile`` linearly interpolates inside the bucket
    containing the target rank (clamped to the observed min/max), so its
    error is bounded by one bucket width — the policy trade for a
    fixed-memory hot-path instrument (§11.1).
    """

    __slots__ = ("name", "labels", "_bounds", "_counts", "_count", "_sum",
                 "_min", "_max", "_lock")

    def __init__(self, name: str, labels: Optional[Dict] = None,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S):
        bounds = tuple(float(b) for b in buckets)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(f"histogram {name}: bucket bounds must be "
                             f"non-empty and strictly ascending: {bounds}")
        self.name = name
        self.labels = dict(labels or {})
        self._bounds = bounds
        self._counts = [0] * (len(bounds) + 1)   # +1: overflow bucket
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        """Record one value (seconds for latency instruments)."""
        v = float(v)
        idx = bisect.bisect_left(self._bounds, v)
        with self._lock:
            self._counts[idx] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        """Number of observations."""
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        """Sum of observations."""
        with self._lock:
            return self._sum

    def percentile(self, q: float) -> float:
        """Interpolated ``q``-th percentile (0 <= q <= 100); NaN when
        empty. Exact to within one bucket width vs a sorted-array oracle
        (tests pin this against numpy)."""
        if not 0 <= q <= 100:
            raise ValueError(f"percentile q={q} outside [0, 100]")
        with self._lock:
            return self._percentile_locked(q)

    def _percentile_locked(self, q: float) -> float:
        if self._count == 0:
            return math.nan
        target = q / 100.0 * self._count
        cum = 0
        for i, n in enumerate(self._counts):
            if n == 0:
                continue
            if cum + n >= target:
                lo = self._bounds[i - 1] if i > 0 else self._min
                hi = self._bounds[i] if i < len(self._bounds) else self._max
                lo = max(lo, self._min)
                hi = min(hi, self._max)
                if hi <= lo:
                    return lo
                frac = (max(target, cum) - cum) / n
                return lo + (hi - lo) * frac
            cum += n
        return self._max

    def summary(self) -> dict:
        """``{count, sum, min, max, p50, p90, p99, buckets}`` snapshot
        (one lock acquisition — consistent across fields). ``buckets`` is
        the finite ``[upper_bound, cumulative_count]`` ladder the
        Prometheus exporter renders as ``_bucket{le=...}`` lines
        (``+Inf`` is implied by ``count``)."""
        with self._lock:
            buckets = []
            cum = 0
            for le, n in zip(self._bounds, self._counts):
                cum += n
                buckets.append([le, cum])
            if self._count == 0:
                return {"count": 0, "sum": 0.0, "min": None, "max": None,
                        "p50": None, "p90": None, "p99": None,
                        "buckets": buckets}
            return {"count": self._count, "sum": self._sum,
                    "min": self._min, "max": self._max,
                    "p50": self._percentile_locked(50),
                    "p90": self._percentile_locked(90),
                    "p99": self._percentile_locked(99),
                    "buckets": buckets}


class Registry:
    """Namespace of instruments; get-or-create by ``(name, labels)``.

    ``counter`` / ``gauge`` / ``histogram`` return the SAME child for the
    same name + label map (so call sites need not cache them, though hot
    paths do), and raise when a name is reused across instrument kinds.
    ``snapshot()`` renders everything into one plain dict — the shape the
    runlog's final ``metrics`` record and ``ZeroShotService.stats`` use.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[Tuple, object] = {}

    def _get(self, cls, name: str, labels: Dict, **kw):
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, labels, **kw)
                self._instruments[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"{name!r} already registered as "
                    f"{type(inst).__name__}, requested {cls.__name__}")
            return inst

    def counter(self, name: str, **labels) -> Counter:
        """Get-or-create the counter ``name`` with ``labels``."""
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        """Get-or-create the gauge ``name`` with ``labels``."""
        return self._get(Gauge, name, labels)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S,
                  **labels) -> Histogram:
        """Get-or-create the histogram ``name`` with ``labels``;
        ``buckets`` only applies at creation."""
        return self._get(Histogram, name, labels, buckets=buckets)

    def series(self, prefix: str) -> dict:
        """Live instruments whose name starts with ``prefix``, keyed by
        label-qualified series name (``name{k=v}``) — the cheap way for a
        watcher (e.g. the straggler detector) to scan one instrument
        family without rendering a full ``snapshot()``."""
        with self._lock:
            instruments = list(self._instruments.values())
        return {_series_name(i.name, i.labels): i for i in instruments
                if i.name.startswith(prefix)}

    def snapshot(self) -> dict:
        """``{"counters": {...}, "gauges": {...}, "histograms": {...}}``
        with label-qualified series names (``name{k=v}``)."""
        with self._lock:
            instruments = list(self._instruments.values())
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for inst in instruments:
            series = _series_name(inst.name, inst.labels)
            if isinstance(inst, Counter):
                out["counters"][series] = inst.value
            elif isinstance(inst, Gauge):
                out["gauges"][series] = inst.value
            else:
                out["histograms"][series] = inst.summary()
        return out

    def to_json(self, *, indent: Optional[int] = None) -> str:
        """``snapshot()`` as a JSON string."""
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)


# the process-wide default registry: ad-hoc instrumentation that has no
# natural owner hangs off this one; subsystems that are instantiated many
# times per process (batcher, checkpoint manager, loader) default to a
# PRIVATE registry instead so their per-instance stats stay isolated
_REGISTRY = Registry()


def get_registry() -> Registry:
    """The process-wide default ``Registry``."""
    return _REGISTRY
