"""Fixed-memory sliding-window aggregators for live SLO/health math (§14.1).

The registry's ``Histogram`` is an ALL-TIME instrument: fixed buckets,
percentiles over every observation since process start. Health monitoring
needs the opposite — "what does the LAST minute look like" — without
letting a week-long run grow state. This module is the windowed
counterpart, three primitives, all O(capacity) memory forever:

  SlidingWindow   ring buffer over the last ``capacity`` values: EXACT
                  p50/p90/p99 (numpy 'linear' convention), mean/min/max,
                  median, MAD, and the robust MAD z-score the anomaly
                  detectors run on (obs/health.py).
  WindowedRate    ring buffer of event timestamps: events/sec over a
                  trailing wall-clock window (throughput, anomaly rates).

Why MAD and not stddev: one grad-norm blow-up at step N would inflate a
windowed stddev for the next ``capacity`` steps, masking follow-up
spikes exactly when they matter. Median/MAD have a 50% breakdown point —
half the window must be outliers before the scale estimate moves — so
detection stays sharp through the episode (DESIGN.md §14.1).

``push``/``mark`` are a few Python ops under a lock (priced in
``benchmarks/obs_bench.py`` ``window/observe``); percentile/MAD sort the
window on demand — the detectors call them once per step on windows of a
few hundred entries, microseconds of host time.
"""
from __future__ import annotations

import math
import threading
import time
from typing import List, Optional, Sequence

# Phi^-1(0.75): scales MAD to estimate sigma under normality, so the MAD
# z-score reads in ordinary "standard deviations" units
MAD_TO_SIGMA = 0.6744897501960817


def percentile(values: Sequence[float], q: float) -> float:
    """Exact linear-interpolated percentile of ``values`` (numpy 'linear'
    convention); NaN for an empty sequence, so callers render "no data"
    instead of crashing mid-report."""
    if not 0 <= q <= 100:
        raise ValueError(f"percentile q={q} outside [0, 100]")
    xs = sorted(values)
    if not xs:
        return math.nan
    pos = q / 100.0 * (len(xs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


class SlidingWindow:
    """Ring buffer over the last ``capacity`` float values.

    ``push`` overwrites the oldest entry once full — memory is fixed at
    construction no matter how many values flow through. All statistics
    are computed over the CURRENT window contents only; empty-window
    queries return NaN (never raise), so detectors warming up read as
    "no signal" rather than crashing.
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._buf: List[float] = [0.0] * self.capacity
        self._next = 0            # ring write cursor
        self._n = 0               # values currently held (<= capacity)
        self._total = 0           # values ever pushed
        self._lock = threading.Lock()

    def push(self, v: float) -> None:
        """Append one value, evicting the oldest once at capacity."""
        v = float(v)
        with self._lock:
            self._buf[self._next] = v
            self._next = (self._next + 1) % self.capacity
            self._n = min(self._n + 1, self.capacity)
            self._total += 1

    @property
    def count(self) -> int:
        """Values currently in the window (<= capacity)."""
        with self._lock:
            return self._n

    @property
    def total(self) -> int:
        """Values ever pushed (survives eviction)."""
        with self._lock:
            return self._total

    @property
    def full(self) -> bool:
        """True once the ring has wrapped at least once."""
        with self._lock:
            return self._n == self.capacity

    def values(self) -> List[float]:
        """Window contents, oldest first (a copy — safe to mutate)."""
        with self._lock:
            if self._n < self.capacity:
                return self._buf[:self._n]
            return self._buf[self._next:] + self._buf[:self._next]

    def mean(self) -> float:
        """Mean over the window; NaN when empty."""
        vals = self.values()
        return sum(vals) / len(vals) if vals else math.nan

    def min(self) -> float:
        """Smallest value in the window; NaN when empty."""
        vals = self.values()
        return min(vals) if vals else math.nan

    def max(self) -> float:
        """Largest value in the window; NaN when empty."""
        vals = self.values()
        return max(vals) if vals else math.nan

    def percentile(self, q: float) -> float:
        """EXACT windowed percentile (module-level ``percentile`` over the
        current contents — no bucket approximation; the window is small
        by construction)."""
        return percentile(self.values(), q)

    def median(self) -> float:
        """Windowed median (= ``percentile(50)``)."""
        return self.percentile(50)

    def mad(self) -> float:
        """Median absolute deviation around the windowed median; NaN when
        empty. The robust scale estimate the z-score uses."""
        vals = self.values()
        if not vals:
            return math.nan
        med = percentile(vals, 50)
        return percentile([abs(v - med) for v in vals], 50)

    def zscore(self, v: float) -> float:
        """Robust MAD z-score of ``v`` against the window:
        ``(v - median) / (MAD / MAD_TO_SIGMA)`` — reads in sigma units
        under normality. Degenerate windows degrade gracefully: when MAD
        is 0 (over half the window identical) the mean absolute deviation
        is the fallback scale; when that is 0 too (ALL values identical),
        the z-score is 0 for ``v == median`` and +/-inf otherwise — an
        exactly-flat signal makes any deviation infinitely surprising."""
        vals = self.values()
        if not vals:
            return math.nan
        med = percentile(vals, 50)
        scale = self.mad() / MAD_TO_SIGMA
        if scale == 0.0:
            # fallback: mean abs deviation, scaled by E|N(0,1)| = 0.7979
            scale = (sum(abs(x - med) for x in vals) / len(vals)) / 0.7979
        if scale == 0.0:
            if v == med:
                return 0.0
            return math.inf if v > med else -math.inf
        return (float(v) - med) / scale


class WindowedRate:
    """Events/sec over a trailing wall-clock window.

    Keeps up to ``capacity`` event timestamps in a ring; ``rate()``
    counts the ones inside the last ``window_s`` seconds. When events
    arrive faster than ``capacity`` per window the rate saturates at
    ``capacity / window_s`` (fixed memory beats exactness for a health
    signal — the saturated value still reads "very hot").
    """

    def __init__(self, window_s: float = 60.0, capacity: int = 1024,
                 clock=time.monotonic):
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        self.window_s = float(window_s)
        self._times = SlidingWindow(capacity)
        self._clock = clock

    def mark(self, n: int = 1) -> None:
        """Record ``n`` events at the current clock time."""
        now = self._clock()
        for _ in range(int(n)):
            self._times.push(now)

    @property
    def total(self) -> int:
        """Events ever marked."""
        return self._times.total

    def rate(self, now: Optional[float] = None) -> float:
        """Events/sec over the trailing window (0.0 when no recent
        events)."""
        now = self._clock() if now is None else float(now)
        cutoff = now - self.window_s
        recent = sum(1 for t in self._times.values() if t > cutoff)
        return recent / self.window_s
