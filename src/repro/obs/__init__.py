"""Unified telemetry subsystem (DESIGN.md §11).

One stats mechanism repo-wide, three layers:

  metrics  — process-wide registry of counters / gauges / fixed-bucket
             histograms (p50/p90/p99 summaries), thread-safe, labeled
             children, ``snapshot()``/``to_json()``.
  trace    — ``span(...)`` context managers recording wall-time events
             into a ring buffer, exportable as Chrome ``trace_event``
             JSON (load in Perfetto / chrome://tracing), with per-host
             ``pid`` lanes for the simulated multi-host runs.
  runlog   — one schema-versioned JSONL record per train step (loss,
             grad-norm, examples/sec, data-wait / device-step /
             ckpt-stall breakdown, checkpoint + retention events), plus
             the ``python -m repro.obs.report`` trajectory summarizer.

Everything is off-hot-path cheap: instruments mutate a couple of Python
ints under a lock, snapshotting and JSONL writes happen outside the
jitted step, and ``benchmarks/obs_bench.py`` gates the instrumented-vs-
bare step overhead at ≤1.05×.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               exponential_buckets, get_registry)
from repro.obs.runlog import (RunLogger, RunlogError, SCHEMA_VERSION,
                              STEP_BREAKDOWN_KEYS, read_runlog,
                              validate_record)
from repro.obs.trace import Tracer, span

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "exponential_buckets",
    "get_registry", "RunLogger", "RunlogError", "SCHEMA_VERSION",
    "STEP_BREAKDOWN_KEYS", "read_runlog", "validate_record", "Tracer",
    "span",
]
