"""Unified telemetry + active monitoring subsystem (DESIGN.md §11, §14).

One stats mechanism repo-wide. The passive layers (§11):

  metrics  — process-wide registry of counters / gauges / fixed-bucket
             histograms (p50/p90/p99 summaries), thread-safe, labeled
             children, ``snapshot()``/``to_json()``.
  trace    — ``span(...)`` context managers recording wall-time events
             into a ring buffer, exportable as Chrome ``trace_event``
             JSON (load in Perfetto / chrome://tracing), with per-host
             ``pid`` lanes for the simulated multi-host runs.
  runlog   — one schema-versioned JSONL record per train step (loss,
             grad-norm, examples/sec, data-wait / device-step /
             ckpt-stall breakdown, checkpoint + retention + anomaly
             events), plus the ``python -m repro.obs.report`` trajectory
             summarizer.

And the active tier built on them (§14):

  windows  — fixed-memory sliding-window aggregators: exact windowed
             percentiles, trailing event rates, robust MAD z-scores.
  health   — ``HealthMonitor`` + pluggable anomaly detectors (non-finite
             loss/grad, spikes, plateau, input stall, host straggler),
             flight recorder, serving ``SLOTracker``.
  export   — Prometheus text exposition of any registry snapshot and the
             stdlib-HTTP ``/metrics`` / ``/healthz`` / ``/snapshot.json``
             endpoint (localhost-only by default).

Everything is off-hot-path cheap: instruments mutate a couple of Python
ints under a lock, snapshotting and JSONL writes happen outside the
jitted step, and ``benchmarks/obs_bench.py`` gates the instrumented-vs-
bare step overhead at ≤1.05× — health checks included.
"""
from repro.obs.export import MetricsServer, render_prometheus
from repro.obs.health import (Anomaly, Detector, FlightRecorder,
                              HealthMonitor, NonFiniteDetector,
                              PlateauDetector, SLOTracker, SpikeDetector,
                              StallDetector, StepSample,
                              StragglerDetector, default_detectors,
                              set_step_fault_hook)
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry,
                               exponential_buckets, get_registry)
from repro.obs.runlog import (RunLogger, RunlogError, SCHEMA_VERSION,
                              STEP_BREAKDOWN_KEYS, read_runlog,
                              validate_record)
from repro.obs.trace import Tracer, span
from repro.obs.windows import SlidingWindow, WindowedRate, percentile

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "exponential_buckets",
    "get_registry", "RunLogger", "RunlogError", "SCHEMA_VERSION",
    "STEP_BREAKDOWN_KEYS", "read_runlog", "validate_record", "Tracer",
    "span",
    "SlidingWindow", "WindowedRate", "percentile",
    "Anomaly", "Detector", "FlightRecorder", "HealthMonitor",
    "NonFiniteDetector", "PlateauDetector", "SLOTracker", "SpikeDetector",
    "StallDetector", "StepSample", "StragglerDetector",
    "default_detectors", "set_step_fault_hook",
    "MetricsServer", "render_prometheus",
]
