"""Active run monitoring: anomaly detectors, flight recorder, SLOs (§14).

PR-7's telemetry RECORDS what happened; this layer WATCHES it happen.
Three pieces:

  detectors      small stateful objects fed one ``StepSample`` per train
                 step (or one latency per serving request). Each returns
                 ``Anomaly`` records when its signal trips: non-finite
                 loss/grad, grad-norm spike (windowed MAD z-score,
                 obs/windows.py), loss plateau/spike, data-wait stall
                 watchdog, per-host straggler skew read from the
                 ``data/gen_seconds{host=h}`` registry series.
  HealthMonitor  owns the detector set and the response: every anomaly
                 becomes a schema-v1 ``anomaly`` runlog record, a trace
                 instant, and a ``health/*`` counter bump — and the
                 flight recorder dumps the trace ring + registry snapshot
                 + last-K step records into the run dir, so the state
                 that PRECEDED the anomaly survives the crash that may
                 follow it.
  SLOTracker     serving-side: windowed p99 latency vs a target, error-
                 budget burn over the window, and a readiness bit that
                 flips when the budget is exhausted (and recovers as the
                 window slides). ``/healthz`` serves it (obs/export.py).

Everything is optional and cheap: a monitor without a runlog/tracer just
counts; detector checks are a handful of window pushes and one sorted
percentile over <=256 floats (priced in ``benchmarks/obs_bench.py``
``health/check`` against the same 5%-of-step budget as the passive
telemetry). DESIGN.md §14 derives the MAD z-score threshold.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import re
import threading
import time
from collections import deque
from typing import Callable, List, Optional, Sequence

from repro.obs import metrics as obs_metrics
from repro.obs import runlog as obs_runlog
from repro.obs import trace as obs_trace
from repro.obs.windows import SlidingWindow

SEVERITIES = ("warn", "critical")


@dataclasses.dataclass(frozen=True)
class Anomaly:
    """One detector firing: who, when, how bad, and the offending value.

    ``detector``/``step``/``severity``/``value`` are the schema-v1
    ``anomaly`` runlog record's required fields; ``message`` is the
    human line."""
    detector: str
    step: int
    severity: str                 # "warn" | "critical"
    value: float
    message: str


@dataclasses.dataclass(frozen=True)
class StepSample:
    """One train step's health-relevant signals, host-side floats only
    (the loop already fetched the loss; nothing here touches the
    device)."""
    step: int
    loss: float = math.nan
    grad_norm: float = math.nan
    data_wait_s: float = 0.0
    device_step_s: float = 0.0
    step_s: float = 0.0
    skipped: bool = False         # the step guard rejected this update


class Detector:
    """Base class: stateful, fed one ``StepSample`` per step.

    Subclasses implement ``_check(sample) -> list[Anomaly]``; the base
    adds a fire cooldown (a tripped plateau shouldn't re-fire every
    subsequent step — one anomaly per episode, then silence for
    ``cooldown`` steps)."""

    name = "detector"

    def __init__(self, *, cooldown: int = 0):
        self.cooldown = int(cooldown)
        self._last_fired: Optional[int] = None

    def observe(self, sample: StepSample) -> List[Anomaly]:
        """Feed one sample; returns the anomalies it trips (cooldown
        applied)."""
        found = self._check(sample)
        if not found:
            return []
        if self._last_fired is not None and \
                sample.step - self._last_fired <= self.cooldown:
            return []
        self._last_fired = sample.step
        return found

    def _check(self, sample: StepSample) -> List[Anomaly]:
        raise NotImplementedError


class NonFiniteDetector(Detector):
    """NaN/inf loss or grad norm — the canonical multi-day-run killer
    (EVA-CLIP-18B and the OpenCLIP scaling runs both report exactly
    this; PAPERS.md). Always critical: a non-finite update poisons every
    parameter it touches."""

    name = "nonfinite"

    def __init__(self, fields: Sequence[str] = ("loss", "grad_norm")):
        super().__init__(cooldown=0)
        self.fields = tuple(fields)

    def _check(self, sample: StepSample) -> List[Anomaly]:
        out = []
        for field in self.fields:
            v = float(getattr(sample, field))
            if not math.isfinite(v):
                out.append(Anomaly(
                    detector=self.name, step=sample.step,
                    severity="critical", value=v,
                    message=f"non-finite {field} at step {sample.step}: "
                            f"{v}"))
        return out

    def observe(self, sample: StepSample) -> List[Anomaly]:
        """No cooldown: every poisoned step is its own incident."""
        return self._check(sample)


class SpikeDetector(Detector):
    """Windowed robust-z spike watch on one sample field.

    Fires when the MAD z-score of the new value against the trailing
    window exceeds ``threshold`` (default 8 — DESIGN.md §14.1 argues the
    margin: grad-norm steps are heavy-tailed, and 8 sigma-equivalents
    under the robust scale keeps the false-positive rate per multi-day
    run below one while a real blow-up lands z in the hundreds). The
    window only absorbs the value AFTER the check, and only when it was
    not itself anomalous — a spike must not teach the window that spikes
    are normal. Non-finite values are ignored here (NonFiniteDetector
    owns them)."""

    def __init__(self, field: str, *, threshold: float = 8.0,
                 window: int = 128, min_count: int = 16,
                 cooldown: int = 0):
        super().__init__(cooldown=cooldown)
        self.name = f"{field}_spike"
        self.field = field
        self.threshold = float(threshold)
        self.min_count = int(min_count)
        self.window = SlidingWindow(window)

    def _check(self, sample: StepSample) -> List[Anomaly]:
        v = float(getattr(sample, self.field))
        if not math.isfinite(v):
            return []
        out = []
        if self.window.count >= self.min_count:
            z = self.window.zscore(v)
            if z > self.threshold:
                out.append(Anomaly(
                    detector=self.name, step=sample.step, severity="warn",
                    value=v,
                    message=f"{self.field} spike at step {sample.step}: "
                            f"{v:.4g} (robust z={z:.1f} > "
                            f"{self.threshold:g}, window median "
                            f"{self.window.median():.4g})"))
        if not out:
            self.window.push(v)
        return out


class PlateauDetector(Detector):
    """Loss plateau: the run is burning accelerator-hours without
    learning. Compares the older half of the window against the newer
    half; fires when relative improvement is below ``rel_improvement``
    once the window is full. Cooldown defaults to the window length —
    one anomaly per plateau episode, not one per step."""

    name = "loss_plateau"

    def __init__(self, *, window: int = 128, rel_improvement: float = 1e-3,
                 cooldown: Optional[int] = None):
        super().__init__(cooldown=window if cooldown is None else cooldown)
        self.rel_improvement = float(rel_improvement)
        self.window = SlidingWindow(window)

    def _check(self, sample: StepSample) -> List[Anomaly]:
        v = float(sample.loss)
        out = []
        if math.isfinite(v):
            self.window.push(v)
            if self.window.full:
                vals = self.window.values()
                half = len(vals) // 2
                older = sum(vals[:half]) / half
                newer = sum(vals[half:]) / (len(vals) - half)
                improvement = (older - newer) / max(abs(older), 1e-12)
                if improvement < self.rel_improvement:
                    out.append(Anomaly(
                        detector=self.name, step=sample.step,
                        severity="warn", value=newer,
                        message=f"loss plateau at step {sample.step}: "
                                f"{older:.4f} -> {newer:.4f} over "
                                f"{len(vals)} steps "
                                f"(rel improvement {improvement:.2e} < "
                                f"{self.rel_improvement:g})"))
        return out


class StallDetector(Detector):
    """Data-wait stall watchdog: a wedged input host shows up as one step
    whose ``data_wait_s`` dwarfs the trailing median. Fires warn past
    ``factor`` x the windowed median (with an absolute ``min_stall_s``
    floor so microsecond jitter on a fully-prefetched pipeline can never
    trip it), critical past ``hard_limit_s`` regardless of history."""

    name = "data_stall"

    def __init__(self, *, factor: float = 10.0, min_stall_s: float = 1.0,
                 hard_limit_s: float = 60.0, window: int = 128,
                 min_count: int = 8):
        super().__init__(cooldown=0)
        self.factor = float(factor)
        self.min_stall_s = float(min_stall_s)
        self.hard_limit_s = float(hard_limit_s)
        self.min_count = int(min_count)
        self.window = SlidingWindow(window)

    def _check(self, sample: StepSample) -> List[Anomaly]:
        v = float(sample.data_wait_s)
        out = []
        if v >= self.hard_limit_s:
            out.append(Anomaly(
                detector=self.name, step=sample.step, severity="critical",
                value=v,
                message=f"input pipeline stalled {v:.1f}s at step "
                        f"{sample.step} (hard limit "
                        f"{self.hard_limit_s:g}s)"))
        elif self.window.count >= self.min_count:
            floor = max(self.min_stall_s,
                        self.factor * self.window.median())
            if v > floor:
                out.append(Anomaly(
                    detector=self.name, step=sample.step, severity="warn",
                    value=v,
                    message=f"data wait {v:.3f}s at step {sample.step} > "
                            f"{floor:.3f}s ({self.factor:g}x trailing "
                            f"median {self.window.median():.4f}s)"))
        if not out:
            self.window.push(v)
        return out


_HOST_SERIES = re.compile(r"^data/gen_seconds\{host=(\d+)\}$")


class StragglerDetector(Detector):
    """Per-host input skew from the ``data/gen_seconds{host=h}`` series
    the ShardedLoader already emits (§11): fires when the slowest host's
    mean block time exceeds ``ratio`` x the median host's. Checked every
    ``every`` steps (the series move once per step; scanning the registry
    more often buys nothing). Cooldown = one full check interval."""

    name = "host_straggler"

    def __init__(self, registry: obs_metrics.Registry, *,
                 ratio: float = 3.0, min_count: int = 8, every: int = 16):
        super().__init__(cooldown=int(every))
        self.registry = registry
        self.ratio = float(ratio)
        self.min_count = int(min_count)
        self.every = int(every)

    def _check(self, sample: StepSample) -> List[Anomaly]:
        if sample.step % self.every:
            return []
        means = {}
        for series, inst in self.registry.series("data/gen_seconds").items():
            m = _HOST_SERIES.match(series)
            if not m or not isinstance(inst, obs_metrics.Histogram):
                continue
            if inst.count >= self.min_count:
                means[int(m.group(1))] = inst.sum / inst.count
        if len(means) < 2:
            return []                    # skew needs at least two hosts
        worst = max(means, key=means.get)
        med = sorted(means.values())[len(means) // 2]
        if med <= 0 or means[worst] <= self.ratio * med:
            return []
        return [Anomaly(
            detector=self.name, step=sample.step, severity="warn",
            value=means[worst] / med,
            message=f"host {worst} straggling at step {sample.step}: "
                    f"mean block {means[worst]*1e3:.2f}ms = "
                    f"{means[worst]/med:.1f}x the median host "
                    f"({med*1e3:.2f}ms) over {len(means)} hosts")]


def default_detectors(registry: Optional[obs_metrics.Registry] = None
                      ) -> List[Detector]:
    """The train-loop detector set (DESIGN.md §14.2): non-finite loss and
    grad, grad-norm + loss spikes, loss plateau, data-wait stall — plus
    the per-host straggler watch when a ``registry`` carries the loader's
    ``data/gen_seconds{host=h}`` series."""
    dets: List[Detector] = [
        NonFiniteDetector(),
        SpikeDetector("grad_norm"),
        SpikeDetector("loss"),
        PlateauDetector(),
        StallDetector(),
    ]
    if registry is not None:
        dets.append(StragglerDetector(registry))
    return dets


class FlightRecorder:
    """Dumps the run's in-memory state to disk when an anomaly fires.

    One directory per dump under ``<run_dir>/flight/``:

      anomaly.json   the triggering record (detector/step/severity/value)
      trace.json     the tracer's full ring as Chrome trace JSON
      metrics.json   the registry snapshot at dump time
      steps.jsonl    the last ``keep_steps`` step records (the runlog has
                     them too, but the dump is self-contained — ship the
                     directory, not the run)

    ``max_dumps`` bounds disk: a NaN storm dumps the first few incidents,
    then counts silently (``health/flight_dumps_suppressed``)."""

    def __init__(self, run_dir: str, *, keep_steps: int = 64,
                 max_dumps: int = 4):
        self.run_dir = run_dir
        self.keep_steps = int(keep_steps)
        self.max_dumps = int(max_dumps)
        self.dumps = 0
        self._recent: deque = deque(maxlen=self.keep_steps)

    def record_step(self, record: dict) -> None:
        """Retain one step record (plain dict) in the last-K ring."""
        self._recent.append(dict(record))

    def dump(self, anomaly: Anomaly, *,
             tracer: Optional[obs_trace.Tracer] = None,
             registry: Optional[obs_metrics.Registry] = None
             ) -> Optional[str]:
        """Write one dump directory for ``anomaly``; returns its path, or
        None when the ``max_dumps`` budget is spent."""
        if self.dumps >= self.max_dumps:
            return None
        self.dumps += 1
        d = os.path.join(self.run_dir, "flight",
                         f"step{anomaly.step:06d}_{anomaly.detector}")
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, "anomaly.json"), "w") as f:
            json.dump(dataclasses.asdict(anomaly), f, indent=2)
            f.write("\n")
        if tracer is not None:
            tracer.export(os.path.join(d, "trace.json"))
        if registry is not None:
            with open(os.path.join(d, "metrics.json"), "w") as f:
                f.write(registry.to_json(indent=2))
                f.write("\n")
        with open(os.path.join(d, "steps.jsonl"), "w") as f:
            for rec in self._recent:
                f.write(json.dumps(rec) + "\n")
        return d


class HealthMonitor:
    """The run's watchdog: detectors in, anomaly response out.

    Per step the trainer calls ``observe_step`` with the host-side floats
    it already has; the monitor runs every detector and, for each
    anomaly: appends a schema-v1 ``anomaly`` record to the runlog, drops
    a trace instant on the trainer lane, bumps
    ``health/anomalies{detector=,severity=}``, and (first ``max_dumps``
    times) triggers the flight recorder. ``status()`` is the
    ``/healthz`` payload: healthy until ``unhealthy_after`` CONSECUTIVE
    critical steps (one skipped NaN step is an incident, not an outage —
    the guard already contained it; a persistent storm is an outage).
    """

    def __init__(self, *, detectors: Optional[Sequence[Detector]] = None,
                 registry: Optional[obs_metrics.Registry] = None,
                 tracer: Optional[obs_trace.Tracer] = None,
                 runlog: Optional[obs_runlog.RunLogger] = None,
                 run_dir: Optional[str] = None,
                 keep_steps: int = 64, max_dumps: int = 4,
                 unhealthy_after: int = 3):
        self.registry = registry if registry is not None \
            else obs_metrics.Registry()
        self.detectors = list(detectors) if detectors is not None \
            else default_detectors(self.registry)
        self.tracer = tracer
        self.runlog = runlog
        self.recorder = FlightRecorder(run_dir, keep_steps=keep_steps,
                                       max_dumps=max_dumps) \
            if run_dir else None
        self.unhealthy_after = int(unhealthy_after)
        self.anomalies: List[Anomaly] = []
        self._consecutive_critical = 0
        self._lock = threading.Lock()
        self._m_checks = self.registry.counter("health/checks")
        self._m_skipped = self.registry.counter("health/steps_skipped")
        self._m_dumps = self.registry.counter("health/flight_dumps")
        self._m_suppressed = self.registry.counter(
            "health/flight_dumps_suppressed")
        self._m_last = self.registry.gauge("health/last_anomaly_step")
        self._m_healthy = self.registry.gauge("health/healthy")
        self._m_last.set(-1)
        self._m_healthy.set(1)

    def observe_step(self, sample: StepSample,
                     record: Optional[dict] = None) -> List[Anomaly]:
        """Run every detector on ``sample``; returns (and responds to)
        the anomalies. ``record``: the step's runlog dict, retained for
        the flight recorder's last-K ring."""
        with self._lock:
            self._m_checks.inc()
            if sample.skipped:
                self._m_skipped.inc()
            if self.recorder is not None and record is not None:
                self.recorder.record_step(record)
            found: List[Anomaly] = []
            for det in self.detectors:
                found.extend(det.observe(sample))
            for anomaly in found:
                self._respond(anomaly)
            if any(a.severity == "critical" for a in found):
                self._consecutive_critical += 1
            else:
                self._consecutive_critical = 0
            self._m_healthy.set(1 if self.healthy else 0)
            return found

    def _respond(self, anomaly: Anomaly) -> None:
        self.anomalies.append(anomaly)
        self.registry.counter("health/anomalies",
                              detector=anomaly.detector,
                              severity=anomaly.severity).inc()
        self._m_last.set(anomaly.step)
        if self.tracer is not None:
            self.tracer.instant(f"anomaly/{anomaly.detector}",
                                step=anomaly.step,
                                severity=anomaly.severity,
                                value=anomaly.value)
        if self.runlog is not None:
            self.runlog.log("anomaly", detector=anomaly.detector,
                            step=anomaly.step, severity=anomaly.severity,
                            value=float(anomaly.value),
                            message=anomaly.message)
        if self.recorder is not None:
            path = self.recorder.dump(anomaly, tracer=self.tracer,
                                      registry=self.registry)
            if path is not None:
                self._m_dumps.inc()
            else:
                self._m_suppressed.inc()

    @property
    def healthy(self) -> bool:
        """False only under a sustained critical episode
        (>= ``unhealthy_after`` consecutive critical steps)."""
        return self._consecutive_critical < self.unhealthy_after

    def status(self) -> dict:
        """The ``/healthz`` payload: healthy bit, totals, and the last
        anomaly (if any) inlined."""
        with self._lock:
            out = {
                "healthy": self.healthy,
                "checks": self._m_checks.value,
                "anomalies": len(self.anomalies),
                "steps_skipped": self._m_skipped.value,
                "consecutive_critical": self._consecutive_critical,
            }
            if self.anomalies:
                out["last_anomaly"] = dataclasses.asdict(self.anomalies[-1])
            return out


class SLOTracker:
    """Serving SLO: windowed p99 latency vs a target + error-budget burn.

    The SLO is "fraction of requests over ``target_s`` stays within
    ``1 - objective``" over the trailing ``window`` requests. ``burn``
    is the violating fraction divided by the allowance — burn 1.0 means
    the budget is exactly spent; past it ``ready`` flips False (and
    recovers as the window slides, so a transient brown-out self-heals
    without a restart). Gauges/counters land on the injected registry
    under ``<name>/slo_*`` and the endpoint's ``/healthz`` serves
    ``status()`` (obs/export.py).
    """

    def __init__(self, *, target_s: float, objective: float = 0.99,
                 window: int = 256,
                 registry: Optional[obs_metrics.Registry] = None,
                 name: str = "serve"):
        if not 0 < objective < 1:
            raise ValueError(f"objective={objective} outside (0, 1)")
        if target_s <= 0:
            raise ValueError(f"target_s={target_s} must be > 0")
        self.target_s = float(target_s)
        self.objective = float(objective)
        self.window = SlidingWindow(window)
        self._violations = SlidingWindow(window)   # 1.0 per violating req
        self.registry = registry if registry is not None \
            else obs_metrics.Registry()
        self._lock = threading.Lock()
        self._m_requests = self.registry.counter(f"{name}/slo_requests")
        self._m_violations = self.registry.counter(f"{name}/slo_violations")
        self._m_p99 = self.registry.gauge(f"{name}/slo_p99_s")
        self._m_burn = self.registry.gauge(f"{name}/slo_error_budget_burn")
        self._m_ready = self.registry.gauge(f"{name}/slo_ready")
        self._m_ready.set(1)

    def observe(self, latency_s: float) -> None:
        """Record one request latency and refresh the derived gauges."""
        v = float(latency_s)
        with self._lock:
            self.window.push(v)
            violated = v > self.target_s
            self._violations.push(1.0 if violated else 0.0)
            self._m_requests.inc()
            if violated:
                self._m_violations.inc()
            self._m_p99.set(self.window.percentile(99))
            self._m_burn.set(self._burn())
            self._m_ready.set(1 if self._ready() else 0)

    def _burn(self) -> float:
        n = self._violations.count
        if n == 0:
            return 0.0
        frac = sum(self._violations.values()) / n
        return frac / (1.0 - self.objective)

    def _ready(self) -> bool:
        return self._burn() < 1.0

    @property
    def ready(self) -> bool:
        """True while the windowed error budget is not exhausted."""
        with self._lock:
            return self._ready()

    def status(self) -> dict:
        """The ``/healthz`` payload: readiness + the SLO arithmetic."""
        with self._lock:
            return {
                "healthy": self._ready(),
                "target_s": self.target_s,
                "objective": self.objective,
                "p99_s": self.window.percentile(99),
                "error_budget_burn": self._burn(),
                "window_count": self.window.count,
                "requests": self._m_requests.value,
                "violations": self._m_violations.value,
            }


# -- step fault-hook seam ----------------------------------------------------
# The trainer applies this hook to every batch right before the device step
# (launch/train_distributed.py). Tests use it to inject a poisoned batch at
# an exact step (and to probe the live /metrics endpoint mid-run); it is
# also the natural seat for chaos drills against a real run. The hook
# signature is fn(step, batch) -> batch (return the input unchanged for a
# pure probe).
_STEP_FAULT_HOOK: Optional[Callable] = None


def set_step_fault_hook(fn: Optional[Callable]) -> None:
    """Install (or clear, with None) the process-wide step fault hook."""
    global _STEP_FAULT_HOOK
    _STEP_FAULT_HOOK = fn


def apply_step_fault_hook(step: int, batch):
    """Run the installed hook on (step, batch); identity when none."""
    if _STEP_FAULT_HOOK is None:
        return batch
    return _STEP_FAULT_HOOK(step, batch)


def monitor_wall_time(fn, slo: SLOTracker):
    """Wrap a callable so each invocation's wall time feeds ``slo`` —
    the one-liner for instrumenting an existing serving entry point."""
    def wrapped(*a, **kw):
        t0 = time.perf_counter()
        try:
            return fn(*a, **kw)
        finally:
            slo.observe(time.perf_counter() - t0)
    return wrapped
