"""Pure-jnp oracle for single-token decode attention over a (ring) KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, valid):
    """q: (b, h, d) one query per head; k/v: (b, kv, t, d) cache;
    valid: (t,) bool mask of live cache slots, or (b, t) bool per slot
    (ragged packed cache). Returns (b, h, d). Rows whose mask is all
    False (an empty continuous-batching slot) return ZEROS — not the
    normalized average a bare softmax over a fully -inf row would give —
    matching the kernel's guarded online-softmax divide."""
    b, h, d = q.shape
    kv = k.shape[1]
    g = h // kv
    if valid.ndim == 1:
        valid = jnp.broadcast_to(valid[None, :], (b, k.shape[2]))
    qg = q.reshape(b, kv, g, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, k.astype(jnp.float32)) * (d ** -0.5)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", w, v.astype(jnp.float32))
    any_valid = jnp.any(valid, axis=1)                   # (b,)
    out = jnp.where(any_valid[:, None, None, None], out, 0.0)
    return out.reshape(b, h, d).astype(q.dtype)
