"""Jitted wrapper: (b, h, d) GQA layout -> kernel (b*kv, g, d) layout."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_bkv


def decode_attention(q, k, v, valid, *, block_k=256, interpret=False):
    """q: (b, h, d); k/v: (b, kv, t, d) -> (b, h, d).

    ``valid``: (t,) bool shared by every row (the legacy fixed-batch
    decode, all slots at one position), or (b, t) bool PER SLOT — the
    continuous-batching packed cache, where each slot decodes at its own
    position and free slots may be fully masked (those rows return
    zeros; see ``decode_attention_ref``). All ``group`` query heads of a
    kv head share their slot's mask."""
    b, h, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    g = h // kv
    qb = q.reshape(b, kv, g, d).reshape(b * kv, g, d)
    kb = k.reshape(b * kv, t, d)
    vb = v.reshape(b * kv, t, d)
    if valid.ndim == 2:
        # per-slot mask: every kv head of slot i sweeps with slot i's mask
        valid = jnp.repeat(valid, kv, axis=0)            # (b*kv, t)
    out = decode_attention_bkv(qb, kb, vb, valid, block_k=block_k,
                               interpret=interpret)
    return out.reshape(b, kv, g, d).reshape(b, h, d)
