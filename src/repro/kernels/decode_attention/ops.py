"""Jitted wrapper: (b, h, d) GQA layout -> kernel (b*kv, g, d) layout."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_bkv


def decode_attention(q, k, v, valid, *, block_k=256, interpret=False):
    """q: (b, h, d); k/v: (b, kv, t, d); valid: (t,) bool -> (b, h, d)."""
    b, h, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    g = h // kv
    qb = q.reshape(b, kv, g, d).reshape(b * kv, g, d)
    kb = k.reshape(b * kv, t, d)
    vb = v.reshape(b * kv, t, d)
    out = decode_attention_bkv(qb, kb, vb, valid, block_k=block_k,
                               interpret=interpret)
    return out.reshape(b, kv, g, d).reshape(b, h, d)
