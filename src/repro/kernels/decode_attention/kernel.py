"""Pallas TPU kernel: single-token decode attention (the serving hot spot).

Decode is pure bandwidth: one query per head must stream the whole KV cache
from HBM. The kernel tiles the cache length; the online-softmax state for the
single query row lives in SMEM-sized VMEM scratch and the (1, block_k) score
tile never leaves VMEM. GQA: all `group` query heads of a kv head are carried
TOGETHER in one block so the k/v tile is streamed ONCE per kv head — the
bandwidth win over the broadcast-per-q-head reference (a real-TPU ~group×
reduction in cache reads).

Grid: (batch * kv_heads, cache_blocks), cache innermost.

Validity is PER ROW: each (batch, kv) row carries its own (t,) mask, so a
packed continuous-batching cache — slots at different decode positions,
ragged live lengths — sweeps in ONE launch. A row with no valid slot
(an empty/free batching slot) emits zeros rather than a normalized
average: its ``l`` accumulator never leaves 0 and the guarded divide
returns 0 exactly (the reference op pins this contract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale, block_k):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale            # (g, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (g, bk)
    vmask = valid_ref[0][None, :]                       # (1, bk)
    s = jnp.where(vmask, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # explicit zero at masked entries: when a whole block is masked while
    # m is still NEG_INF (a length-0 slot, or leading dead blocks),
    # exp(s - m) = exp(0) = 1 would leak them; where masked entries DO
    # see a finite m, exp(NEG_INF - m) underflows to 0 exactly, so this
    # is bit-identical on the partially-masked blocks
    p = jnp.where(vmask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(
                        o_ref.dtype)


def decode_attention_bkv(q, k, v, valid, *, block_k=256, interpret=False):
    """q: (b*kv, g, d); k/v: (b*kv, t, d); valid: (t,) bool shared across
    rows, or (b*kv, t) bool per row (ragged packed cache). Rows with no
    valid slot return zeros. Returns (b*kv, g, d) f32-accumulated
    attention output."""
    bkv, g, d = q.shape
    t = k.shape[1]
    block_k = min(block_k, t)
    assert t % block_k == 0, (t, block_k)
    if valid.ndim == 1:
        valid = jnp.broadcast_to(valid[None, :], (bkv, t))
    assert valid.shape == (bkv, t), (valid.shape, (bkv, t))
    grid = (bkv, t // block_k)
    scale = d ** -0.5

    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_k), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)
