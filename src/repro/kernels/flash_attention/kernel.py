"""Pallas TPU flash attention (forward): online-softmax tiles in VMEM.

Grid: (batch*q_heads, q_blocks, k_blocks) — k innermost so the output block
and the running (max, sum) scratch persist across the reduction. Causal and
sliding-window masks are applied from global indices; GQA is handled by the
ops.py wrapper mapping each q head to its kv group. Block shapes are
(block_q, head_dim) / (block_k, head_dim) — MXU-aligned multiples of 128 for
real TPU shapes; head_dim is kept whole.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale, block_q, block_k, causal, window, seq_k):
    qi, ki = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale               # (bq, d)
    k = k_ref[0].astype(jnp.float32)                       # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)

    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + qi * block_q
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + ki * block_k
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= (rows - cols) < window
    mask &= cols < seq_k
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_bh(q, k, v, *, causal=True, window=None, block_q=128,
                       block_k=128, interpret=False):
    """q: (bh, s, d); k/v: (bh, t, d) — heads already broadcast/flattened."""
    bh, s, d = q.shape
    t = k.shape[1]
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    grid = (bh, s // block_q, t // block_k)
    scale = d ** -0.5

    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal, window=window,
                          seq_k=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
