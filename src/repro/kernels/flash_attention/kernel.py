"""Pallas TPU flash attention: online-softmax forward + blockwise backward.

Forward grid: (batch*q_heads, q_blocks, k_blocks) — k innermost so the output
block and the running (max, sum) scratch persist across the reduction. The
forward also emits the per-row LSE (m + log l) consumed by the backward
kernels. Causal, sliding-window and *bidirectional* masks are applied from
global indices (the BASIC encoder towers run causal=False); an optional
additive key bias (one row per batch*head, e.g. -inf on padded text
positions) rides in as a (1, block_k) tile. GQA is handled by the ops.py
wrapper mapping each q head to its kv group.

Backward is the standard two-kernel flash split over the same tiles:
  dq  grid (bh, q_blocks, k_blocks), k innermost — dQ accumulates in VMEM
  dkv grid (bh, k_blocks, q_blocks), q innermost — dK/dV accumulate in VMEM
Both recompute the probability tile from (q, k, lse) instead of loading a
stored (s, t) matrix, so no attention matrix ever exists in HBM in either
direction. All tiles accumulate in fp32 regardless of input dtype
(bf16-in/fp32-accum, matching the PR-1 kernel conventions).

Block shapes are (block_q, head_dim) / (block_k, head_dim) — MXU-aligned
multiples of 128 for real TPU shapes; head_dim is kept whole. Every query
row must attend to at least one key (guaranteed by causal self-attention
and by ≥1-valid-token padding masks); fully-masked rows would produce
garbage rather than NaN-safe zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _tile_mask(shape, qi, ki, block_q, block_k, causal, window, seq_k):
    """Boolean validity mask of one (block_q, block_k) score tile from the
    tile's global row/col offsets."""
    rows = jax.lax.broadcasted_iota(jnp.int32, shape, 0) + qi * block_q
    cols = jax.lax.broadcasted_iota(jnp.int32, shape, 1) + ki * block_k
    mask = jnp.ones(shape, jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= (rows - cols) < window
    mask &= cols < seq_k
    return mask


def _fwd_kernel(*refs, scale, block_q, block_k, causal, window, seq_k,
                has_bias):
    if has_bias:
        q_ref, k_ref, v_ref, b_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr = refs
    else:
        q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr = refs
        b_ref = None
    qi, ki = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale               # (bq, d)
    k = k_ref[0].astype(jnp.float32)                       # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if b_ref is not None:
        s = s + b_ref[0].astype(jnp.float32)[None, :]
    mask = _tile_mask(s.shape, qi, ki, block_q, block_k, causal, window,
                      seq_k)
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_scr[...] + jnp.log(l)


def flash_fwd_bh(q, k, v, bias=None, *, causal=True, window=None,
                 block_q=128, block_k=128, interpret=False):
    """Forward pass on flattened heads. q: (bh, s, d); k/v: (bh, t, d);
    bias: optional (bh, t) fp32 additive key bias. Returns (out (bh, s, d)
    in q.dtype, lse (bh, s) fp32)."""
    bh, s, d = q.shape
    t = k.shape[1]
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    grid = (bh, s // block_q, t // block_k)
    scale = d ** -0.5

    in_specs = [
        pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
    ]
    args = [q, k, v]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, block_k), lambda b, i, j: (b, j)))
        args.append(bias.astype(jnp.float32))

    return pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal, window=window,
                          seq_k=t, has_bias=bias is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------


def _recompute_p_ds(q_ref, k_ref, v_ref, b_ref, do_ref, lse_ref, d_ref,
                    qi, ki, scale, block_q, block_k, causal, window, seq_k):
    """Shared tile recomputation for both backward kernels: rebuild the
    probability tile p from (q·k, lse) and form ds = p * (do·v - delta).
    Returns q already scaled by d^-1/2 (so dsᵀ·q IS dk)."""
    q = q_ref[0].astype(jnp.float32) * scale               # (bq, d)
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if b_ref is not None:
        s = s + b_ref[0].astype(jnp.float32)[None, :]
    mask = _tile_mask(s.shape, qi, ki, block_q, block_k, causal, window,
                      seq_k)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse_ref[0][:, None])                   # (bq, bk)
    do = do_ref[0].astype(jnp.float32)
    dp = jax.lax.dot_general(do, v_ref[0].astype(jnp.float32),
                             (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - d_ref[0][:, None])
    return q, p, do, ds


def _dq_kernel(*refs, scale, block_q, block_k, causal, window, seq_k,
               has_bias):
    if has_bias:
        (q_ref, k_ref, v_ref, b_ref, do_ref, lse_ref, d_ref, dq_ref,
         acc_scr) = refs
    else:
        q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref, dq_ref, acc_scr = refs
        b_ref = None
    qi, ki = pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    _, _, _, ds = _recompute_p_ds(q_ref, k_ref, v_ref, b_ref, do_ref,
                                  lse_ref, d_ref, qi, ki, scale, block_q,
                                  block_k, causal, window, seq_k)
    acc_scr[...] += jax.lax.dot_general(
        ds, k_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        dq_ref[0] = (acc_scr[...] * scale).astype(dq_ref.dtype)


def _dkv_kernel(*refs, scale, block_q, block_k, causal, window, seq_k,
                has_bias):
    if has_bias:
        (q_ref, k_ref, v_ref, b_ref, do_ref, lse_ref, d_ref, dk_ref, dv_ref,
         dk_scr, dv_scr) = refs
    else:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref, dk_ref, dv_ref,
         dk_scr, dv_scr) = refs
        b_ref = None
    ki, qi = pl.program_id(1), pl.program_id(2)   # grid = (bh, nk, nq)
    nq = pl.num_programs(2)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q, p, do, ds = _recompute_p_ds(q_ref, k_ref, v_ref, b_ref, do_ref,
                                   lse_ref, d_ref, qi, ki, scale, block_q,
                                   block_k, causal, window, seq_k)
    # q arrives pre-scaled by d^-1/2, so dsᵀ·q IS dk (no extra scale)
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _finish():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def flash_bwd_bh(q, k, v, bias, out, lse, dout, *, causal=True, window=None,
                 block_q=128, block_k=128, interpret=False):
    """Backward pass on flattened heads: returns (dq, dk, dv) in the input
    dtypes. Recomputes probability tiles from (q, k, lse); ``delta`` —
    rowsum(dout·out) — is formed in XLA (one fused elementwise+reduce)."""
    bh, s, d = q.shape
    t = k.shape[1]
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    scale = d ** -0.5
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                               # (bh, s)

    has_bias = bias is not None
    common = dict(scale=scale, block_q=block_q, block_k=block_k,
                  causal=causal, window=window, seq_k=t, has_bias=has_bias)

    q_spec = pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0))
    kv_spec_j = pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0))
    row_spec = pl.BlockSpec((1, block_q), lambda b, i, j: (b, i))
    bias_spec_j = pl.BlockSpec((1, block_k), lambda b, i, j: (b, j))

    dq_in_specs = [q_spec, kv_spec_j, kv_spec_j]
    dq_args = [q, k, v]
    if has_bias:
        dq_in_specs.append(bias_spec_j)
        dq_args.append(bias.astype(jnp.float32))
    dq_in_specs += [q_spec, row_spec, row_spec]
    dq_args += [dout, lse, delta]

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(bh, s // block_q, t // block_k),
        in_specs=dq_in_specs,
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(*dq_args)

    # dkv grid: (bh, k_blocks, q_blocks) — index_map args are (b, j, i)
    q_spec_i = pl.BlockSpec((1, block_q, d), lambda b, j, i: (b, i, 0))
    kv_spec = pl.BlockSpec((1, block_k, d), lambda b, j, i: (b, j, 0))
    row_spec_i = pl.BlockSpec((1, block_q), lambda b, j, i: (b, i))
    bias_spec = pl.BlockSpec((1, block_k), lambda b, j, i: (b, j))

    dkv_in_specs = [q_spec_i, kv_spec, kv_spec]
    dkv_args = [q, k, v]
    if has_bias:
        dkv_in_specs.append(bias_spec)
        dkv_args.append(bias.astype(jnp.float32))
    dkv_in_specs += [q_spec_i, row_spec_i, row_spec_i]
    dkv_args += [dout, lse, delta]

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, **common),
        grid=(bh, t // block_k, s // block_q),
        in_specs=dkv_in_specs,
        out_specs=[kv_spec, kv_spec],
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_k, d), jnp.float32),
                        pltpu.VMEM((block_k, d), jnp.float32)],
        interpret=interpret,
    )(*dkv_args)
    return dq, dk, dv


def flash_attention_bh(q, k, v, *, causal=True, window=None, block_q=128,
                       block_k=128, interpret=False):
    """Forward-only convenience (the pre-backward public entry point):
    q: (bh, s, d); k/v: (bh, t, d) — heads already broadcast/flattened."""
    out, _ = flash_fwd_bh(q, k, v, None, causal=causal, window=window,
                          block_q=block_q, block_k=block_k,
                          interpret=interpret)
    return out
