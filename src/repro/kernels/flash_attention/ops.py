"""Differentiable, jitted GQA wrapper for the flash attention kernels.

``flash_attention`` is a drop-in attention op for the tower runtime
(models/attention.py ``impl="pallas"``): forward runs the online-softmax
Pallas kernel, backward runs the blockwise dq / dkv Pallas kernels through a
``jax.custom_vjp`` — the (s, t) attention matrix never materializes in HBM
in either direction. bf16 inputs accumulate in fp32 inside every kernel
(PR-1 conventions); causal, sliding-window, *bidirectional* and key-padding
masks are supported.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (NEG_INF, flash_bwd_bh,
                                                  flash_fwd_bh)


def default_interpret() -> bool:
    """Pallas interpret-mode auto-detection: the compiled kernel on
    accelerators, the interpreted body on CPU (where Mosaic cannot
    compile) — same convention as the contrastive-loss kernels."""
    return jax.default_backend() == "cpu"


def pick_block(n: int, want: int) -> int:
    """Largest block size <= ``want`` that divides ``n``, preferring
    sublane-aligned (multiple-of-8) blocks so compiled Mosaic can tile
    them; unaligned divisors are the interpret-mode fallback (callers
    pass e.g. want=128)."""
    for b in range(min(want, n), 0, -1):
        if n % b == 0 and b % 8 == 0:
            return b
    for b in range(min(want, n), 0, -1):
        if n % b == 0:
            return b
    return 1


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash_bh(q, k, v, bias, causal, window, block_q, block_k, interpret):
    out, _ = flash_fwd_bh(q, k, v, bias, causal=causal, window=window,
                          block_q=block_q, block_k=block_k,
                          interpret=interpret)
    return out


def _flash_bh_fwd(q, k, v, bias, causal, window, block_q, block_k,
                  interpret):
    out, lse = flash_fwd_bh(q, k, v, bias, causal=causal, window=window,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)
    return out, (q, k, v, bias, out, lse)


def _flash_bh_bwd(causal, window, block_q, block_k, interpret, res, dout):
    q, k, v, bias, out, lse = res
    dq, dk, dv = flash_bwd_bh(q, k, v, bias, out, lse, dout, causal=causal,
                              window=window, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    return dq, dk, dv, None


_flash_bh.defvjp(_flash_bh_fwd, _flash_bh_bwd)


def flash_attention(q, k, v, *, causal=True, window=None, key_mask=None,
                    block_q=128, block_k=128, interpret=None):
    """q: (b, h, s, d); k/v: (b, kv, t, d) with h % kv == 0. Differentiable
    (custom-VJP into the blockwise backward kernels).

    key_mask: optional (b, t) — bool (True = attend) or additive fp32 bias —
    masking padded key positions per example; every query must keep >= 1
    valid key. The mask/bias is a CONSTANT of the computation (its
    custom-VJP cotangent is None — fine for padding masks, not for a
    learned bias; use naive/chunked to differentiate a bias).
    interpret=None auto-detects the backend (compiled on accelerators,
    interpreted on CPU).

    kv heads are broadcast to q heads (the all-VMEM GQA strategy: k/v tiles
    are small and re-fetched per group member; a production variant would
    reuse the tile across the group — noted in EXPERIMENTS.md §Perf). The
    broadcast happens in XLA, so its VJP sums dk/dv over the group
    automatically."""
    if interpret is None:
        interpret = default_interpret()
    b, h, s, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    g = h // kv
    kb = jnp.repeat(k, g, axis=1).reshape(b * h, t, d)
    vb = jnp.repeat(v, g, axis=1).reshape(b * h, t, d)
    qb = q.reshape(b * h, s, d)
    bias = None
    if key_mask is not None:
        key_mask = jnp.asarray(key_mask)
        if key_mask.dtype == jnp.bool_:
            key_mask = jnp.where(key_mask, 0.0, NEG_INF).astype(jnp.float32)
        bias = jnp.broadcast_to(key_mask.astype(jnp.float32)[:, None, :],
                                (b, h, t)).reshape(b * h, t)
    out = _flash_bh(qb, kb, vb, bias, causal, window,
                    pick_block(s, block_q), pick_block(t, block_k),
                    interpret)
    return out.reshape(b, h, s, d)
