"""Jitted GQA wrapper for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bh


def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_k=128, interpret=False):
    """q: (b, h, s, d); k/v: (b, kv, t, d) with h % kv == 0.

    kv heads are broadcast to q heads (the all-VMEM GQA strategy: k/v tiles
    are small and re-fetched per group member; a production variant would
    reuse the tile across the group — noted in EXPERIMENTS.md §Perf)."""
    b, h, s, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    g = h // kv
    kb = jnp.repeat(k, g, axis=1).reshape(b * h, t, d)
    vb = jnp.repeat(v, g, axis=1).reshape(b * h, t, d)
    qb = q.reshape(b * h, s, d)
    out = flash_attention_bh(qb, kb, vb, causal=causal, window=window,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return out.reshape(b, h, s, d)
