"""Pure-jnp oracle for the flash attention kernel (GQA, causal, SWA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None):
    """q: (b, h, s, d); k/v: (b, kv, t, d). Returns (b, h, s, d)."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, s, d).astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, k.astype(jnp.float32))
    scores = scores * (d ** -0.5)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(k.shape[2])[None, :]
    mask = jnp.zeros((s, k.shape[2]), jnp.float32)
    if causal:
        mask = jnp.where(ki <= qi, mask, NEG_INF)
    if window is not None:
        mask = jnp.where(qi - ki < window, mask, NEG_INF)
    scores = scores + mask
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", w, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)
