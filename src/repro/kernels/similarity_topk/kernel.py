"""Pallas TPU kernel: blockwise similarity→top-k — (b, n) logits never hit HBM.

Open-vocabulary classification at serving time is one matmul against the
class-embedding matrix followed by a top-k (DESIGN.md §6.3). At the label
spaces this repo targets (10⁵ classes, reproducible-scaling-laws regime) the
(b, n_classes) logit matrix is the memory hot-spot — 4·b·n bytes that are
reduced to k numbers per row immediately after being written. This kernel
fuses the two: logits are computed tile-by-tile in VMEM and a RUNNING top-k
per image row is carried in VMEM scratch across the class axis, so HBM
traffic is Θ(b·d + n·d + b·k).

Grid (nI, nJ), j (class blocks) innermost, TPU grids execute sequentially
row-major:

  - per tile: A_ij = X_i · C_jᵀ · inv_tau (MXU, fp32 accumulation; bf16
    inputs stay bf16 on the wires),
  - the (bm, k) running top-k (values + global class indices) lives in VMEM
    scratch, re-initialized at j==0 and merged with each tile via k rounds
    of select-max-then-retire over the (bm, k+bc) candidate pool,
  - at j==nJ−1 the scratch is flushed to the streamed (bm, k) outputs.

Ordering contract (matches ref.py exactly): descending by value, ties broken
by LOWER class index — each select round picks the smallest index among the
columns achieving the row max, then retires that single candidate by index.
Padded class columns (n not divisible by bc) carry value NEG and are never
selected while ≥ k real candidates remain, which ``ops.similarity_topk``
guarantees by requiring k ≤ min(n_classes, bc).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30          # sentinel: below any real similarity (unit-ish inputs)
IDX_PAD = 2 ** 30    # sentinel index: above any real class id


def _tile(x_ref, c_ref, inv_tau):
    """X_i · C_jᵀ tile with fp32 MXU accumulation (bf16 inputs stay bf16)."""
    return jax.lax.dot_general(x_ref[...], c_ref[...], (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32) * inv_tau


def _merge_topk(vals, idx, cand_v, cand_i, k):
    """Top-k of the candidate pool [running top-k | new tile], ties to the
    lower index. k static → the select/retire rounds unroll."""
    cand_v = jnp.concatenate([vals, cand_v], axis=1)
    cand_i = jnp.concatenate([idx, cand_i], axis=1)
    out_v, out_i = [], []
    for _ in range(k):
        m = jnp.max(cand_v, axis=1)                            # (bm,)
        at_max = cand_v == m[:, None]
        sel = jnp.min(jnp.where(at_max, cand_i, IDX_PAD), axis=1)
        out_v.append(m)
        out_i.append(sel)
        cand_v = jnp.where(cand_i == sel[:, None], NEG, cand_v)
    return jnp.stack(out_v, axis=1), jnp.stack(out_i, axis=1)


def _topk_kernel(x_ref, c_ref, inv_tau_ref, n_valid_ref, vals_ref, idx_ref,
                 vscr, iscr, *, bc, k, nj):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vscr[...] = jnp.full_like(vscr, NEG)
        iscr[...] = jnp.full_like(iscr, IDX_PAD)

    a = _tile(x_ref, c_ref, inv_tau_ref[0])                    # (bm, bc)
    col = j * bc + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a = jnp.where(col < n_valid_ref[0], a, NEG)                # mask padding

    vscr[...], iscr[...] = _merge_topk(vscr[...], iscr[...], a, col, k)

    @pl.when(j == nj - 1)
    def _emit():
        vals_ref[...] = vscr[...]
        idx_ref[...] = iscr[...]


def topk_fused(x, c, inv_tau, *, k, bm, bc, n_classes, n_valid=None,
               interpret=False):
    """One grid sweep -> (values (b, k) fp32, indices (b, k) int32).

    x: (b, d) with b % bm == 0; c: (n_pad, d) with n_pad % bc == 0 and
    rows ≥ n_classes zero-padded (masked by index inside the kernel).
    ``n_valid`` optionally overrides the static ``n_classes`` mask with a
    TRACED scalar (the sharded serving path masks each shard's padded tail
    with a value computed from the shard index at run time); columns ≥ the
    mask carry value NEG, so when fewer than k valid columns exist the tail
    of the output is (NEG, <masked col id>) — callers that shard must
    retire those by value (see serving/retrieval/sharded.py).
    """
    b, d = x.shape
    n_pad = c.shape[0]
    assert b % bm == 0 and n_pad % bc == 0, (b, bm, n_pad, bc)
    ni, nj = b // bm, n_pad // bc
    inv_tau = jnp.asarray([inv_tau], jnp.float32)
    n_valid = jnp.asarray(n_classes if n_valid is None else n_valid,
                          jnp.int32).reshape((1,))

    return pl.pallas_call(
        functools.partial(_topk_kernel, bc=bc, k=k, nj=nj),
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, k), jnp.float32),
                   jax.ShapeDtypeStruct((b, k), jnp.int32)],
        scratch_shapes=[
            pltpu.VMEM((bm, k), jnp.float32),   # running top-k values
            pltpu.VMEM((bm, k), jnp.int32),     # running top-k class ids
        ],
        interpret=interpret,
    )(x, c, inv_tau, n_valid)
