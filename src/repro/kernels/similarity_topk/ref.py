"""Pure-jnp oracle for the fused similarity→top-k kernel.

Materializes the full (b, n_classes) logit matrix — the kernel must return
the same top-k without ever forming it. Ordering contract: descending by
logit, ties broken by LOWER class index (stable argsort of the negated
logits preserves ascending index order among equal values).
"""
from __future__ import annotations

import jax.numpy as jnp


def similarity_topk_ref(image_emb, class_emb, k: int, inv_tau=1.0):
    """Top-k of ``image_emb @ class_emb.T * inv_tau``.

    image_emb: (b, d), class_emb: (n, d) — any float dtype (accumulated in
    fp32). Returns (values (b, k) fp32, indices (b, k) int32), sorted
    descending, ties broken by lower class index.
    """
    logits = logits_ref(image_emb, class_emb, inv_tau)
    order = jnp.argsort(-logits, axis=1, stable=True)
    idx = order[:, :k]
    vals = jnp.take_along_axis(logits, idx, axis=1)
    return vals, idx.astype(jnp.int32)


def logits_ref(image_emb, class_emb, inv_tau=1.0):
    """The materializing similarity matrix (b, n) in fp32."""
    return jnp.einsum("bd,nd->bn", image_emb.astype(jnp.float32),
                      class_emb.astype(jnp.float32)) * inv_tau


def classify_ref(image_emb, class_emb, inv_tau=1.0):
    """argmax class id per row (b,) int32 — top-1 of the oracle."""
    _, idx = similarity_topk_ref(image_emb, class_emb, 1, inv_tau)
    return idx[:, 0]
