"""Public op: fused similarity→top-k over a class-embedding matrix.

``similarity_topk(image_emb, class_emb, k)`` returns the top-k
``(values, indices)`` of ``image_emb @ class_emb.T * inv_tau`` per row and
matches ``ref.similarity_topk_ref`` exactly on ordering (descending value,
ties to the lower class index) without ever materializing the (b, n_classes)
logit matrix — peak memory of the kernel path is O(b·k + b·block) beyond the
inputs (DESIGN.md §6.3). Handles arbitrary b (row padding) and n_classes not
divisible by the class block (column masking inside the kernel). bf16 inputs
are fed straight to the MXU with fp32 accumulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.similarity_topk import kernel

_BM_CANDIDATES = (128, 64, 32, 16, 8)
_BC_CANDIDATES = (4096, 2048, 1024, 512, 256, 128)
MAX_K = 64  # the select/retire merge unrolls k rounds; keep it bounded

# Per-step VMEM budget for the compiled kernel's block working set (same
# 8 MiB headroom policy as the contrastive autotuner, DESIGN.md §2.4).
DEFAULT_VMEM_BUDGET = 8 * 2 ** 20

# Interpret mode (the CPU bench/test host) has no VMEM limit and its cost is
# per-grid-step overhead, so the class block grows until the sweep is a
# handful of steps (DESIGN.md §6.3).
INTERPRET_BC = 8192


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pick_bm(b: int) -> int:
    """Largest row block ≤ 128 that keeps padding waste low: the smallest
    sublane-aligned cover of b, capped at 128."""
    cover = _round_up(b, 8)
    for bm in _BM_CANDIDATES:
        if bm <= cover:
            return bm
    return 8


def block_bytes(bm: int, bc: int, d: int, k: int, itemsize: int) -> int:
    """VMEM bytes per grid step: double-buffered class-row stream, the x
    tile, the fp32 logit tile, and the merge's candidate-pool temporaries
    (values + indices over bm×(k+bc))."""
    return (2 * bc * d * itemsize + bm * d * itemsize
            + bm * bc * 4 + 2 * bm * (bc + k) * 4)


def pick_bc(n: int, d: int, k: int, bm: int, itemsize: int, *,
            interpret: bool,
            vmem_budget: int = DEFAULT_VMEM_BUDGET) -> int:
    """Class-axis block. Interpret mode: as large as the class axis needs
    (per-step overhead dominates). Compiled: largest candidate whose working
    set fits the VMEM budget."""
    cover = _round_up(n, 128)
    if interpret:
        return min(INTERPRET_BC, cover)
    for bc in _BC_CANDIDATES:
        if block_bytes(bm, bc, d, k, itemsize) <= vmem_budget:
            return min(bc, cover)
    return 128


def merge_topk(cand_v, cand_i, k: int):
    """Top-k of a (b, m) candidate pool: k unrolled select-max-retire
    rounds, the SAME ordering contract as the kernel's running merge —
    descending by value, ties broken by the LOWER index (each round picks
    the smallest index among the columns achieving the row max, then
    retires that candidate). The sharded serving path feeds it the
    all-gathered per-shard top-k pools (top-k-of-top-k combine,
    serving/retrieval/sharded.py); because the rule is order-independent,
    merging per-shard top-ks is bit-identical to one global sweep.

    cand_v: (b, m) fp32 values; cand_i: (b, m) int32 ids (globally unique
    per row; `kernel.IDX_PAD` marks empty slots, which must carry value
    `kernel.NEG`). Returns (values (b, k) fp32, indices (b, k) int32).
    """
    from repro.kernels.similarity_topk.kernel import IDX_PAD, NEG

    if cand_v.shape[1] < k:
        raise ValueError(f"candidate pool {cand_v.shape} narrower than "
                         f"k={k}")
    out_v, out_i = [], []
    for _ in range(int(k)):
        m = jnp.max(cand_v, axis=1)                            # (b,)
        at_max = cand_v == m[:, None]
        sel = jnp.min(jnp.where(at_max, cand_i, IDX_PAD), axis=1)
        out_v.append(m)
        out_i.append(sel)
        cand_v = jnp.where(cand_i == sel[:, None], NEG, cand_v)
    return (jnp.stack(out_v, axis=1).astype(jnp.float32),
            jnp.stack(out_i, axis=1).astype(jnp.int32))


def similarity_topk(image_emb, class_emb, k: int, *, inv_tau=1.0,
                    bm: int | None = None, bc: int | None = None,
                    n_valid=None,
                    interpret: bool | None = None):
    """Top-k similarities of each image row against every class row.

    image_emb: (b, d); class_emb: (n, d); returns (values (b, k) fp32,
    indices (b, k) int32), rows sorted descending, ties broken by lower
    class index. ``interpret=None`` auto-detects the backend (compiled on
    accelerators, interpreter on CPU). ``n_valid`` optionally narrows the
    valid class prefix with a TRACED scalar (columns ≥ n_valid are masked
    to the NEG sentinel — the shard-local mask of the mesh-sharded path,
    where the last shard's tail padding is only known per shard index).
    """
    b, d = image_emb.shape
    n, d2 = class_emb.shape
    if d != d2:
        raise ValueError(f"embed dims differ: image {d} vs class {d2}")
    k = int(k)
    if not 1 <= k <= n:
        raise ValueError(f"k={k} must be in [1, n_classes={n}]")
    if k > MAX_K:
        raise ValueError(f"k={k} > MAX_K={MAX_K} (the merge unrolls k rounds)")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bm = bm or pick_bm(b)
    bc = bc or pick_bc(n, d, k, bm, image_emb.dtype.itemsize,
                       interpret=interpret)
    if bm % 8 != 0:
        raise ValueError(f"bm={bm} must be a multiple of 8")
    if k > bc:
        raise ValueError(f"k={k} > class block bc={bc}: the running top-k "
                         f"needs ≥ k real candidates per tile")

    bp = _round_up(b, bm)
    n_pad = _round_up(n, bc)
    x = image_emb
    if bp != b:
        x = jnp.pad(x, ((0, bp - b), (0, 0)))
    c = class_emb
    if n_pad != n:
        c = jnp.pad(c, ((0, n_pad - n), (0, 0)))

    vals, idx = kernel.topk_fused(x, c, inv_tau, k=k, bm=bm, bc=bc,
                                  n_classes=n, n_valid=n_valid,
                                  interpret=interpret)
    return vals[:b], idx[:b]


def classify(image_emb, class_emb, *, inv_tau=1.0, bm=None, bc=None,
             interpret=None):
    """Top-1 class id per row (b,) int32 via the fused kernel."""
    _, idx = similarity_topk(image_emb, class_emb, 1, inv_tau=inv_tau,
                             bm=bm, bc=bc, interpret=interpret)
    return idx[:, 0]
