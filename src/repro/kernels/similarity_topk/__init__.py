from repro.kernels.similarity_topk.ops import (  # noqa: F401
    classify,
    similarity_topk,
)
from repro.kernels.similarity_topk.ref import similarity_topk_ref  # noqa: F401
