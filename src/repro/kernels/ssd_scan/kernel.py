"""Pallas TPU kernel: Mamba2 SSD chunked scan.

TPU adaptation of SSD (DESIGN.md §3): the chunk-quadratic term runs on the
MXU as (chunk × chunk) matmuls entirely in VMEM; the inter-chunk recurrence is
carried in a VMEM scratch state across the innermost (chunk) grid axis, so the
only HBM traffic is x/B/C/dt in and y out — the (l × l) semiseparable matrix
of the naive dual form never materializes.

Grid: (batch, heads, n_chunks), chunk innermost. Per step:
  y_c = (C_c B_cᵀ ⊙ L_c) (dt·x)_c  +  exp(cum) C_c stateᵀ  +  D x_c
  state ← exp(cum[-1]) state + ((dt·x)_c ⊙ decay_to_end)ᵀ B_c
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_scr,
                *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)                 # (cl, p)
    dt = dt_ref[0, 0].astype(jnp.float32)               # (cl,)
    A = a_ref[0]                                        # scalar
    Bm = b_ref[0].astype(jnp.float32)                   # (cl, n)
    Cm = c_ref[0].astype(jnp.float32)                   # (cl, n)
    D = d_ref[0]

    da = dt * A                                         # (cl,)
    cum = jnp.cumsum(da)                                # (cl,)
    xdt = x * dt[:, None]                               # (cl, p)

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    seg = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    Lmat = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * Lmat
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    state = state_scr[...]                              # (p, n)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    y += D * x
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update
    decay_to_end = jnp.exp(cum[-1] - cum)               # (cl,)
    new_part = jax.lax.dot_general(
        xdt * decay_to_end[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (p, n)
    state_scr[...] = state * jnp.exp(cum[-1]) + new_part


def ssd_scan_bh(x, dt, A, Bm, Cm, D, *, chunk=128, interpret=False):
    """x: (b, h, l, p); dt: (b, h, l); A/D: (h,); Bm/Cm: (b, l, n).
    Returns y (b, h, l, p) f32."""
    b, h, l, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, l, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm, D.astype(jnp.float32))
