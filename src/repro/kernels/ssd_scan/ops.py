"""Jitted wrapper: layout adaptation (b, l, h, p) -> kernel (b, h, l, p)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_bh


def ssd_scan(x, dt, A, Bm, Cm, D=None, *, chunk=128, interpret=False):
    """Same signature/layout as models.ssm.ssd_chunked plus D.
    x: (b, l, h, p); dt: (b, l, h); A: (h,); Bm/Cm: (b, l, n)."""
    h = x.shape[2]
    xt = jnp.moveaxis(x, 2, 1)            # (b, h, l, p)
    dtt = jnp.moveaxis(dt, 2, 1)          # (b, h, l)
    Dv = D if D is not None else jnp.zeros((h,), jnp.float32)
    y = ssd_scan_bh(xt, dtt, A, Bm, Cm, Dv, chunk=chunk, interpret=interpret)
    return jnp.moveaxis(y, 1, 2)          # (b, l, h, p)
