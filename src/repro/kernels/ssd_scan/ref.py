"""Pure-jnp oracle for the SSD chunked-scan kernel: the sequential recurrence.

h_t = exp(dt_t * A) * h_{t-1} + dt_t * x_t ⊗ B_t ;  y_t = h_t C_t + D x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm, D=None):
    """x: (b, l, h, p); dt: (b, l, h); A: (h,); Bm/Cm: (b, l, n).
    Returns (y (b, l, h, p), final_state (b, h, p, n))."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    f32 = jnp.float32

    def step(state, t):
        xt, dtt, bt, ct = t
        decay = jnp.exp(dtt.astype(f32) * A.astype(f32))          # (b, h)
        dx = dtt.astype(f32)[..., None] * xt.astype(f32)          # (b, h, p)
        state = state * decay[..., None, None] \
            + dx[..., None] * bt.astype(f32)[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(f32))
        return state, y

    xs = (jnp.moveaxis(x, 1, 0).swapaxes(2, 2), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    # x moved to (l, b, h, p)
    state0 = jnp.zeros((b, h, p, n), f32)
    final, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1)                                    # (b, l, h, p)
    if D is not None:
        y = y + D.astype(f32)[None, None, :, None] * x.astype(f32)
    return y, final
