from repro.kernels.contrastive_loss.ops import (  # noqa: F401
    autotune_blocks,
    fused_contrastive_loss,
    fused_contrastive_loss_4pass,
    fused_loss_and_lse,
    fused_loss_and_lse_4pass,
    pick_blocks,
)
