from repro.kernels.contrastive_loss.ops import (  # noqa: F401
    fused_contrastive_loss,
    fused_loss_and_lse,
)
