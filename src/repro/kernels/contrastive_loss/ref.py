"""Pure-jnp oracle for the fused contrastive loss kernel.

Materializes the full B×B similarity matrix (as paper Algorithm 1 line 6
does) — the kernel must match these values without ever forming it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def contrastive_fwd_ref(x, y, log_tau):
    """Returns (loss, row_lse (B,), col_lse (B,), diag (B,))."""
    a = jnp.einsum("id,jd->ij", x.astype(jnp.float32),
                   y.astype(jnp.float32)) * jnp.exp(-log_tau)
    row_lse = jax.nn.logsumexp(a, axis=1)
    col_lse = jax.nn.logsumexp(a, axis=0)
    diag = jnp.diagonal(a)
    loss = 0.5 * (jnp.mean(row_lse - diag) + jnp.mean(col_lse - diag))
    return loss, row_lse, col_lse, diag


def contrastive_grads_ref(x, y, log_tau):
    """(dX, dY, dlog_tau) of the loss above, via the closed form
    dA = (softmax_row + softmax_col - 2I)/(2B)."""
    x32, y32 = x.astype(jnp.float32), y.astype(jnp.float32)
    inv_tau = jnp.exp(-log_tau)
    a = jnp.einsum("id,jd->ij", x32, y32) * inv_tau
    b = a.shape[0]
    p_row = jax.nn.softmax(a, axis=1)
    p_col = jax.nn.softmax(a, axis=0)
    eye = jnp.eye(b, dtype=jnp.float32)
    da = (p_row + p_col - 2 * eye) / (2 * b)
    dx = (da @ y32) * inv_tau
    dy = (da.T @ x32) * inv_tau
    dlog_tau = -jnp.sum(da * a)
    return dx, dy, dlog_tau


def loss_ref(x, y, log_tau):
    return contrastive_fwd_ref(x, y, log_tau)[0]


def loss_and_grads_ref(x, y, log_tau):
    """(loss, dX, dY, dlog_tau) in one call — the materializing baseline
    timed by benchmarks/kernel_bench.py against the fused paths."""
    loss = loss_ref(x, y, log_tau)
    dx, dy, dlog_tau = contrastive_grads_ref(x, y, log_tau)
    return loss, dx, dy, dlog_tau
