"""Pallas TPU kernels: blockwise contrastive loss — B×B never hits HBM.

TPU adaptation of the paper's memory insight (DESIGN.md §2): Algorithm 1
stores the full similarity matrix (Θ(B²) = 16 GB at B=65536); here tiles of
X·Yᵀ live only in VMEM and row/column log-sum-exps are accumulated online
(flash-attention-style running max/sum), so HBM traffic is Θ(B·D).

Single-pass kernels (DESIGN.md §2.3) — the default path, 2 launches total:
  _fused_fwd_kernel : grid (nI, nJ) -> row LSE and col LSE in ONE sweep.
      Row LSE runs the usual online rescale over the inner j axis (row
      running max/sum live in VMEM scratch, finalized at j == nJ-1).
      Col LSE is carried in full-length VMEM scratch across the OUTER i
      axis: each tile updates the (bn,)-slice of the (B,) column running
      max/sum, finalized into the resident output at i == nI-1.
  _fused_bwd_kernel : grid (nI, nJ) -> dX, dY, dlog_tau in ONE sweep.
      Each X·Yᵀ tile is computed once and contracted both ways: dX_i
      accumulates in its streamed output block over the inner j axis; dY
      accumulates slice-wise into a VMEM-resident (B, D) fp32 output
      (constant index map) across the outer i axis; dτ is a resident
      scalar. Versus the legacy 4-pass path this halves X·Yᵀ matmul FLOPs
      and roughly halves HBM reads of X/Y.

Legacy 4-pass kernels (kept for the perf-regression baseline in
benchmarks/kernel_bench.py; each a clean single-reduction grid):
  _row_lse_kernel : grid (nI, nJ) -> row LSE          (J inner, online LSE)
  _col_lse_kernel : grid (nJ, nI) -> col LSE          (I inner, online LSE)
  _dx_kernel      : grid (nI, nJ) -> dX rows + dlog_tau partials
  _dy_kernel      : grid (nJ, nI) -> dY rows

Backward recomputes each tile from (row_lse, col_lse):
  dA_ij = (exp(A_ij - row_lse_i) + exp(A_ij - col_lse_j) - 2·δ_ij) / (2B)

Inputs may be bf16 (fed straight to the MXU with fp32 accumulation via
``preferred_element_type``) or fp32. Block sizes are multiples of (8, 128)
sublane×lane tiling; D is kept whole in VMEM (embedding dims here are
≤ 2048 ⇒ X/Y tiles of bm×D ≤ 1 MB each). The VMEM footprint model behind
block selection is in ops.pick_blocks (DESIGN.md §2.4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _tile(x_ref, y_ref, inv_tau):
    """X_i · Y_jᵀ tile with fp32 MXU accumulation (bf16 inputs stay bf16)."""
    return jax.lax.dot_general(x_ref[...], y_ref[...], (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32) * inv_tau


def _contract(da, v_ref):
    """da · V tile; da is cast to the operand dtype so bf16 uses the MXU."""
    return jax.lax.dot_general(da.astype(v_ref.dtype), v_ref[...],
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _online_update(m, s, a, axis):
    """One online-LSE step: returns updated (max, sum) over ``axis`` of a."""
    m_new = jnp.maximum(m, jnp.max(a, axis=axis))
    exp_a = jnp.exp(a - (m_new[:, None] if axis == 1 else m_new[None, :]))
    s_new = s * jnp.exp(m - m_new) + jnp.sum(exp_a, axis=axis)
    return m_new, s_new


# ---------------------------------------------------------------------------
# single-pass forward: row LSE + col LSE in one sweep
# ---------------------------------------------------------------------------


def _fused_fwd_kernel(x_ref, y_ref, inv_tau_ref, rlse_ref, clse_ref,
                      rm, rs, cm, cs, *, bn, ni, nj):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init_row():
        rm[...] = jnp.full_like(rm, NEG)
        rs[...] = jnp.zeros_like(rs)

    @pl.when((i == 0) & (j == 0))
    def _init_col():
        cm[...] = jnp.full_like(cm, NEG)
        cs[...] = jnp.zeros_like(cs)

    a = _tile(x_ref, y_ref, inv_tau_ref[0])            # (bm, bn)

    rm[...], rs[...] = _online_update(rm[...], rs[...], a, axis=1)

    sl = pl.ds(j * bn, bn)
    cm[sl], cs[sl] = _online_update(cm[sl], cs[sl], a, axis=0)

    @pl.when(j == nj - 1)
    def _finalize_row():
        rlse_ref[...] = rm[...] + jnp.log(rs[...])

    @pl.when(i == ni - 1)
    def _finalize_col():
        clse_ref[sl] = cm[sl] + jnp.log(cs[sl])


def fwd_fused(x, y, inv_tau, *, bm=128, bn=128, interpret=False):
    """Single grid sweep -> (row_lse, col_lse), each (B,) fp32."""
    b, d = x.shape
    assert b % bm == 0 and b % bn == 0, (b, bm, bn)
    ni, nj = b // bm, b // bn
    inv_tau = jnp.asarray([inv_tau], jnp.float32)

    return pl.pallas_call(
        functools.partial(_fused_fwd_kernel, bn=bn, ni=ni, nj=nj),
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((b,), lambda i, j: (0,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b,), jnp.float32)] * 2,
        scratch_shapes=[
            pltpu.VMEM((bm,), jnp.float32),   # row running max
            pltpu.VMEM((bm,), jnp.float32),   # row running sum
            pltpu.VMEM((b,), jnp.float32),    # col running max (full length)
            pltpu.VMEM((b,), jnp.float32),    # col running sum (full length)
        ],
        interpret=interpret,
    )(x, y, inv_tau)


# ---------------------------------------------------------------------------
# single-pass backward: dX, dY, dlog_tau in one sweep
# ---------------------------------------------------------------------------


def _diag_mask(i, j, bm, bn):
    """2·δ_ij contribution for the (i, j) tile (global diagonal)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0) + i * bm
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1) + j * bn
    return (rows == cols).astype(jnp.float32)


def _fused_bwd_kernel(x_ref, y_ref, inv_tau_ref, rlse_ref, clse_ref,
                      dx_ref, dy_ref, dtau_ref, *, bm, bn, b_norm, with_diag):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init_dx():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    @pl.when((i == 0) & (j == 0))
    def _init_dtau():
        dtau_ref[...] = jnp.zeros_like(dtau_ref)

    inv_tau = inv_tau_ref[0]
    a = _tile(x_ref, y_ref, inv_tau)
    p_row = jnp.exp(a - rlse_ref[...][:, None])
    p_col = jnp.exp(a - clse_ref[...][None, :])
    da = p_row + p_col
    if with_diag:
        da = da - 2.0 * _diag_mask(i, j, bm, bn)
    da = da / (2.0 * b_norm)

    dx_ref[...] += _contract(da, y_ref) * inv_tau
    dy_contrib = _contract(da.T, x_ref) * inv_tau
    sl = pl.ds(j * bn, bn)

    @pl.when(i == 0)
    def _dy_first():
        dy_ref[sl, :] = dy_contrib

    @pl.when(i > 0)
    def _dy_accum():
        dy_ref[sl, :] += dy_contrib

    dtau_ref[...] += -jnp.sum(da * a)


def bwd_fused(x, y, inv_tau, row_lse, col_lse, *, bm=128, bn=128,
              interpret=False, b_norm=None, with_diag=True):
    """Single grid sweep -> (dX, dY, dlog_tau), gradients in fp32.

    ``b_norm`` overrides the 1/(2B) normalization batch (the GLOBAL batch
    when this kernel computes one remote-negative chunk of a cross-shard
    loss — core/distributed_loss.py); ``with_diag=False`` drops the
    -2·δ_ij positive-pair term, which only lives in the shard-diagonal
    chunk of the global matrix (DESIGN.md §7.2)."""
    b, d = x.shape
    assert b % bm == 0 and b % bn == 0, (b, bm, bn)
    ni, nj = b // bm, b // bn
    inv_tau = jnp.asarray([inv_tau], jnp.float32)

    dx, dy, dtau = pl.pallas_call(
        functools.partial(_fused_bwd_kernel, bm=bm, bn=bn,
                          b_norm=b if b_norm is None else b_norm,
                          with_diag=with_diag),
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((b, d), lambda i, j: (0, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, d), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.float32)],
        interpret=interpret,
    )(x, y, inv_tau, row_lse, col_lse)
    return dx, dy, dtau[0]


# ---------------------------------------------------------------------------
# legacy 4-pass kernels (perf-regression baseline; see DESIGN.md §2.2)
# ---------------------------------------------------------------------------


def _row_lse_kernel(x_ref, y_ref, inv_tau_ref, m_ref, s_ref, *, nj):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)

    a = _tile(x_ref, y_ref, inv_tau_ref[0])            # (bm, bn)
    m_ref[...], s_ref[...] = _online_update(m_ref[...], s_ref[...], a, axis=1)


def _col_lse_kernel(y_ref, x_ref, inv_tau_ref, m_ref, s_ref, *, ni):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)

    # tile = X_i · Y_j^T transposed -> (bn, bm) scores of columns vs rows
    a = _tile(y_ref, x_ref, inv_tau_ref[0])            # (bn, bm)
    m_ref[...], s_ref[...] = _online_update(m_ref[...], s_ref[...], a, axis=1)


def _dx_kernel(x_ref, y_ref, inv_tau_ref, rlse_ref, clse_ref,
               dx_ref, dtau_ref, *, bm, bn, b_norm, with_diag):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    @pl.when((i == 0) & (j == 0))
    def _init2():
        dtau_ref[...] = jnp.zeros_like(dtau_ref)

    a = _tile(x_ref, y_ref, inv_tau_ref[0])
    p_row = jnp.exp(a - rlse_ref[...][:, None])
    p_col = jnp.exp(a - clse_ref[...][None, :])
    da = p_row + p_col
    if with_diag:
        da = da - 2.0 * _diag_mask(i, j, bm, bn)
    da = da / (2.0 * b_norm)
    dx_ref[...] += _contract(da, y_ref) * inv_tau_ref[0]
    dtau_ref[...] += -jnp.sum(da * a)


def _dy_kernel(y_ref, x_ref, inv_tau_ref, rlse_ref, clse_ref, dy_ref,
               *, bm, bn, b_norm, with_diag):
    j, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dy_ref[...] = jnp.zeros_like(dy_ref)

    a_t = _tile(y_ref, x_ref, inv_tau_ref[0])          # (bn, bm): A_ij^T
    p_row = jnp.exp(a_t - rlse_ref[...][None, :])      # softmax over rows of A
    p_col = jnp.exp(a_t - clse_ref[...][:, None])
    da_t = p_row + p_col
    if with_diag:
        da_t = da_t - 2.0 * _diag_mask(j, i, bn, bm)
    da_t = da_t / (2.0 * b_norm)
    dy_ref[...] += _contract(da_t, x_ref) * inv_tau_ref[0]


def row_col_lse(x, y, inv_tau, *, bm=128, bn=128, interpret=False):
    b, d = x.shape
    assert b % bm == 0 and b % bn == 0, (b, bm, bn)
    ni, nj = b // bm, b // bn
    inv_tau = jnp.asarray([inv_tau], jnp.float32)

    rm, rs = pl.pallas_call(
        functools.partial(_row_lse_kernel, nj=nj),
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b,), jnp.float32)] * 2,
        interpret=interpret,
    )(x, y, inv_tau)
    row_lse = rm + jnp.log(rs)

    cm, cs = pl.pallas_call(
        functools.partial(_col_lse_kernel, ni=ni),
        grid=(nj, ni),
        in_specs=[
            pl.BlockSpec((bn, d), lambda j, i: (j, 0)),
            pl.BlockSpec((bm, d), lambda j, i: (i, 0)),
            pl.BlockSpec((1,), lambda j, i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda j, i: (j,)),
            pl.BlockSpec((bn,), lambda j, i: (j,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b,), jnp.float32)] * 2,
        interpret=interpret,
    )(y, x, inv_tau)
    col_lse = cm + jnp.log(cs)
    return row_lse, col_lse


def grads(x, y, inv_tau, row_lse, col_lse, *, bm=128, bn=128,
          interpret=False, b_norm=None, with_diag=True):
    """Two grid sweeps -> (dX, dY, dlog_tau), gradients in fp32 (legacy
    backward; ``b_norm``/``with_diag`` as in :func:`bwd_fused`)."""
    b, d = x.shape
    ni, nj = b // bm, b // bn
    inv_tau = jnp.asarray([inv_tau], jnp.float32)
    b_norm = b if b_norm is None else b_norm

    dx, dtau = pl.pallas_call(
        functools.partial(_dx_kernel, bm=bm, bn=bn, b_norm=b_norm,
                          with_diag=with_diag),
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, d), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.float32)],
        interpret=interpret,
    )(x, y, inv_tau, row_lse, col_lse)

    dy = pl.pallas_call(
        functools.partial(_dy_kernel, bm=bm, bn=bn, b_norm=b_norm,
                          with_diag=with_diag),
        grid=(nj, ni),
        in_specs=[
            pl.BlockSpec((bn, d), lambda j, i: (j, 0)),
            pl.BlockSpec((bm, d), lambda j, i: (i, 0)),
            pl.BlockSpec((1,), lambda j, i: (0,)),
            pl.BlockSpec((bm,), lambda j, i: (i,)),
            pl.BlockSpec((bn,), lambda j, i: (j,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(y, x, inv_tau, row_lse, col_lse)
    return dx, dy, dtau[0]
