"""Pallas TPU kernel: blockwise contrastive loss — B×B never hits HBM.

TPU adaptation of the paper's memory insight (DESIGN.md §2): Algorithm 1
stores the full similarity matrix (Θ(B²) = 16 GB at B=65536); here tiles of
X·Yᵀ live only in VMEM and row/column log-sum-exps are accumulated online
(flash-attention-style running max/sum), so HBM traffic is Θ(B·D).

Four kernels (each a clean single-reduction grid, innermost axis = reduction):
  _row_lse_kernel : grid (nI, nJ) -> row LSE          (J inner, online LSE)
  _col_lse_kernel : grid (nJ, nI) -> col LSE          (I inner, online LSE)
  _dx_kernel      : grid (nI, nJ) -> dX rows + dlog_tau partials
  _dy_kernel      : grid (nJ, nI) -> dY rows

Backward recomputes each tile from (row_lse, col_lse):
  dA_ij = (exp(A_ij - row_lse_i) + exp(A_ij - col_lse_j) - 2·δ_ij) / (2B)

Block sizes are multiples of (8, 128) sublane×lane tiling; D is kept whole in
VMEM (embedding dims here are ≤ 2048 ⇒ X/Y tiles of bm×D ≤ 1 MB each).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _tile(x_ref, y_ref, inv_tau):
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    return jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32) * inv_tau


def _row_lse_kernel(x_ref, y_ref, inv_tau_ref, m_ref, s_ref, *, nj):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)

    a = _tile(x_ref, y_ref, inv_tau_ref[0])            # (bm, bn)
    m_new = jnp.maximum(m_ref[...], jnp.max(a, axis=1))
    s_ref[...] = s_ref[...] * jnp.exp(m_ref[...] - m_new) \
        + jnp.sum(jnp.exp(a - m_new[:, None]), axis=1)
    m_ref[...] = m_new


def _col_lse_kernel(y_ref, x_ref, inv_tau_ref, m_ref, s_ref, *, ni):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.zeros_like(s_ref)

    # tile = X_i · Y_j^T transposed -> (bn, bm) scores of columns vs rows
    a = _tile(y_ref, x_ref, inv_tau_ref[0])            # (bn, bm)
    m_new = jnp.maximum(m_ref[...], jnp.max(a, axis=1))
    s_ref[...] = s_ref[...] * jnp.exp(m_ref[...] - m_new) \
        + jnp.sum(jnp.exp(a - m_new[:, None]), axis=1)
    m_ref[...] = m_new


def _diag_mask(i, j, bm, bn):
    """2·δ_ij contribution for the (i, j) tile (global diagonal)."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0) + i * bm
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1) + j * bn
    return (rows == cols).astype(jnp.float32)


def _dx_kernel(x_ref, y_ref, inv_tau_ref, rlse_ref, clse_ref,
               dx_ref, dtau_ref, *, bm, bn, b):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dx_ref[...] = jnp.zeros_like(dx_ref)

    @pl.when((i == 0) & (j == 0))
    def _init2():
        dtau_ref[...] = jnp.zeros_like(dtau_ref)

    a = _tile(x_ref, y_ref, inv_tau_ref[0])
    p_row = jnp.exp(a - rlse_ref[...][:, None])
    p_col = jnp.exp(a - clse_ref[...][None, :])
    da = (p_row + p_col - 2.0 * _diag_mask(i, j, bm, bn)) / (2.0 * b)
    dx_ref[...] += jax.lax.dot_general(
        da, y_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * inv_tau_ref[0]
    dtau_ref[...] += -jnp.sum(da * a)


def _dy_kernel(y_ref, x_ref, inv_tau_ref, rlse_ref, clse_ref, dy_ref,
               *, bm, bn, b):
    j, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        dy_ref[...] = jnp.zeros_like(dy_ref)

    a_t = _tile(y_ref, x_ref, inv_tau_ref[0])          # (bn, bm): A_ij^T
    p_row = jnp.exp(a_t - rlse_ref[...][None, :])      # softmax over rows of A
    p_col = jnp.exp(a_t - clse_ref[...][:, None])
    da_t = (p_row + p_col - 2.0 * _diag_mask(j, i, bn, bm)) / (2.0 * b)
    dy_ref[...] += jax.lax.dot_general(
        da_t, x_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * inv_tau_ref[0]


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------


def row_col_lse(x, y, inv_tau, *, bm=128, bn=128, interpret=False):
    b, d = x.shape
    assert b % bm == 0 and b % bn == 0, (b, bm, bn)
    ni, nj = b // bm, b // bn
    inv_tau = jnp.asarray([inv_tau], jnp.float32)

    rm, rs = pl.pallas_call(
        functools.partial(_row_lse_kernel, nj=nj),
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b,), jnp.float32)] * 2,
        interpret=interpret,
    )(x, y, inv_tau)
    row_lse = rm + jnp.log(rs)

    cm, cs = pl.pallas_call(
        functools.partial(_col_lse_kernel, ni=ni),
        grid=(nj, ni),
        in_specs=[
            pl.BlockSpec((bn, d), lambda j, i: (j, 0)),
            pl.BlockSpec((bm, d), lambda j, i: (i, 0)),
            pl.BlockSpec((1,), lambda j, i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda j, i: (j,)),
            pl.BlockSpec((bn,), lambda j, i: (j,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b,), jnp.float32)] * 2,
        interpret=interpret,
    )(y, x, inv_tau)
    col_lse = cm + jnp.log(cs)
    return row_lse, col_lse


def grads(x, y, inv_tau, row_lse, col_lse, *, bm=128, bn=128,
          interpret=False):
    b, d = x.shape
    ni, nj = b // bm, b // bn
    inv_tau = jnp.asarray([inv_tau], jnp.float32)

    dx, dtau = pl.pallas_call(
        functools.partial(_dx_kernel, bm=bm, bn=bn, b=b),
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((b, d), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.float32)],
        interpret=interpret,
    )(x, y, inv_tau, row_lse, col_lse)

    dy = pl.pallas_call(
        functools.partial(_dy_kernel, bm=bm, bn=bn, b=b),
        grid=(nj, ni),
        in_specs=[
            pl.BlockSpec((bn, d), lambda j, i: (j, 0)),
            pl.BlockSpec((bm, d), lambda j, i: (i, 0)),
            pl.BlockSpec((1,), lambda j, i: (0,)),
            pl.BlockSpec((bm,), lambda j, i: (i,)),
            pl.BlockSpec((bn,), lambda j, i: (j,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(y, x, inv_tau, row_lse, col_lse)
    return dx, dy, dtau[0]
