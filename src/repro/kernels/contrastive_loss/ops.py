"""Jitted public op: fused contrastive loss with custom VJP.

``fused_contrastive_loss(x, y, log_tau)`` matches ``ref.loss_ref`` and its
gradients match ``ref.contrastive_grads_ref`` (asserted over shape/dtype
sweeps in tests/test_kernels.py) while keeping the B×B similarity matrix out
of HBM. The forward is ONE Pallas sweep (row+col LSE together) and the
backward is ONE sweep (dX, dY, dτ together) — see DESIGN.md §2.3.

Block sizes are chosen by ``pick_blocks`` — a VMEM-footprint-model autotuner
(DESIGN.md §2.4) preferring (bm, bn) ∈ {128, 256, 512}×{128, 256} — and can
be overridden explicitly via the ``bm``/``bn`` arguments, e.g. with a pair
returned by the optional timed sweep ``autotune_blocks(..., timed=True)``.
bf16 inputs are fed straight to the kernels (fp32 accumulation inside).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.kernels.contrastive_loss import kernel

# Candidate block edges, largest first. {128, 256, 512}×{128, 256} are the
# MXU-friendly preferred pairs; smaller powers of two keep tiny (test-sized)
# batches on the blockwise path.
_BM_CANDIDATES = (512, 256, 128, 64, 32, 16, 8)
_BN_CANDIDATES = (256, 128, 64, 32, 16, 8)

# Per-step VMEM budget for the block-dependent working set. Real TPU cores
# have ~16 MB of VMEM; 8 MiB leaves headroom for the full-kernel residents
# (col accumulators 2·B·4 bytes in fwd, the dY carrier B·D·4 bytes in bwd —
# see DESIGN.md §2.4 for the capacity discussion).
DEFAULT_VMEM_BUDGET = 8 * 2**20

_AUTOTUNE_CACHE: dict = {}

# Approximate compiled-mode VMEM capacity per core, minus slack. The fused
# backward keeps a (B, D) fp32 dY carrier resident for the whole sweep
# (DESIGN.md §2.3); when carrier + block working set can't fit, the compiled
# path falls back to the legacy two-sweep backward (3 launches total).
_VMEM_TOTAL_APPROX = 14 * 2**20


def bwd_fits_fused(b: int, d: int, bm: int, bn: int, itemsize: int) -> bool:
    """True when the single-pass backward's VMEM residency is compilable:
    the (B, D) fp32 dY carrier plus the per-step block working set."""
    return block_bytes(bm, bn, d, itemsize) + b * d * 4 <= _VMEM_TOTAL_APPROX


def block_bytes(bm: int, bn: int, d: int, itemsize: int) -> int:
    """Block-dependent VMEM bytes per grid step (worst pass = backward):
    double-buffered X/Y tiles, ~4 fp32 tile temporaries (A, p_row, p_col,
    dA), the streamed dX block, and the per-block LSE slices."""
    stream = 2 * (bm + bn) * d * itemsize
    tiles = 4 * bm * bn * 4
    dx_out = 2 * bm * d * 4
    lse = (bm + bn) * 4
    return stream + tiles + dx_out + lse


def pick_blocks(b: int, d: int, itemsize: int = 4, *,
                bm: int | None = None, bn: int | None = None,
                vmem_budget: int = DEFAULT_VMEM_BUDGET) -> tuple[int, int]:
    """Pick (bm, bn) by the VMEM footprint model; explicit overrides win.

    Raises ValueError when B is not a multiple of 8 — a 1×1 grid would
    silently defeat the blockwise design (pad the batch instead).
    """
    if b % 8 != 0:
        raise ValueError(
            f"contrastive kernel batch size must be a multiple of 8, got "
            f"B={b}; pad the batch to {-(-b // 8) * 8} (the blockwise grid "
            f"needs sublane-aligned tiles; see DESIGN.md §2.4)")
    if bm is not None and (b % bm != 0 or bm % 8 != 0):
        raise ValueError(f"bm={bm} must divide B={b} and be a multiple of 8")
    if bn is not None and (b % bn != 0 or bn % 8 != 0):
        raise ValueError(f"bn={bn} must divide B={b} and be a multiple of 8")
    if bm is not None and bn is not None:
        return bm, bn

    bms = (bm,) if bm is not None else \
        tuple(c for c in _BM_CANDIDATES if b % c == 0)
    bns = (bn,) if bn is not None else \
        tuple(c for c in _BN_CANDIDATES if b % c == 0)

    best = None
    for cm in bms:
        for cn in bns:
            fits = block_bytes(cm, cn, d, itemsize) <= vmem_budget
            # prefer: fits with the largest tile area (widest lanes as the
            # tie-break); if nothing fits, the smallest footprint wins
            score = (fits, cm * cn if fits else -cm * cn, cn)
            if best is None or score > best[0]:
                best = (score, (cm, cn))
    return best[1]


def autotune_blocks(b: int, d: int, dtype=jnp.float32, *, timed: bool = False,
                    interpret: bool = False, iters: int = 2,
                    vmem_budget: int = DEFAULT_VMEM_BUDGET) -> tuple[int, int]:
    """Return (bm, bn) for the fused kernels at shape (B, D).

    With ``timed=False`` this is just the VMEM model (``pick_blocks``). With
    ``timed=True`` every model-feasible candidate pair is benchmarked
    (jit-compiled fwd+bwd on random data) and the fastest wins; results are
    cached per (B, D, dtype, interpret, backend).
    """
    itemsize = jnp.dtype(dtype).itemsize
    if not timed:
        return pick_blocks(b, d, itemsize, vmem_budget=vmem_budget)

    key = (b, d, jnp.dtype(dtype).name, interpret, jax.default_backend(),
           vmem_budget, iters)
    if key in _AUTOTUNE_CACHE:
        return _AUTOTUNE_CACHE[key]

    model_pick = pick_blocks(b, d, itemsize,
                             vmem_budget=vmem_budget)  # raises on bad B
    cands = [(cm, cn) for cm in _BM_CANDIDATES if b % cm == 0
             for cn in _BN_CANDIDATES if b % cn == 0
             if block_bytes(cm, cn, d, itemsize) <= vmem_budget]
    if not cands:
        cands = [model_pick]
    k1, k2 = jax.random.split(jax.random.key(0))
    x = jax.random.normal(k1, (b, d), jnp.float32).astype(dtype)
    y = jax.random.normal(k2, (b, d), jnp.float32).astype(dtype)
    log_tau = jnp.asarray(-1.0)

    best = None
    for cm, cn in cands:
        fn = jax.jit(jax.grad(
            lambda x, y, t, cm=cm, cn=cn: fused_contrastive_loss(
                x, y, t, interpret, cm, cn)))
        jax.block_until_ready(fn(x, y, log_tau))     # compile + warm
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(x, y, log_tau))
        dt = (time.perf_counter() - t0) / iters
        if best is None or dt < best[0]:
            best = (dt, (cm, cn))
    _AUTOTUNE_CACHE[key] = best[1]
    return best[1]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def fused_contrastive_loss(x, y, log_tau, interpret=False, bm=None, bn=None):
    """Paper Eq. 3 contrastive loss via the single-pass fused kernels.

    x, y: (B, D) fp32/bf16 unit-norm embeddings (B % 8 == 0); log_tau:
    scalar fp32. Returns the scalar fp32 loss; differentiable via a
    custom VJP whose backward is one more Pallas sweep (dX/dY in the
    input dtype, dlog_tau fp32). interpret/bm/bn are static overrides
    (see module docstring)."""
    loss, _ = _fwd(x, y, log_tau, interpret, bm, bn)
    return loss


def _fwd(x, y, log_tau, interpret, bm, bn):
    b, d = x.shape
    bm, bn = pick_blocks(b, d, x.dtype.itemsize, bm=bm, bn=bn)
    inv_tau = jnp.exp(-log_tau)
    row_lse, col_lse = kernel.fwd_fused(x, y, inv_tau, bm=bm, bn=bn,
                                        interpret=interpret)
    diag = jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32),
                   axis=1) * inv_tau
    loss = 0.5 * (jnp.mean(row_lse - diag) + jnp.mean(col_lse - diag))
    return loss, (x, y, log_tau, row_lse, col_lse)


def _bwd(interpret, bm, bn, res, g):
    x, y, log_tau, row_lse, col_lse = res
    b, d = x.shape
    bm, bn = pick_blocks(b, d, x.dtype.itemsize, bm=bm, bn=bn)
    inv_tau = jnp.exp(-log_tau)
    # interpret mode has no VMEM limit; compiled mode needs the resident dY
    # carrier to fit, else the legacy two-sweep backward keeps us correct
    if interpret or bwd_fits_fused(b, d, bm, bn, x.dtype.itemsize):
        dx, dy, dtau = kernel.bwd_fused(x, y, inv_tau, row_lse, col_lse,
                                        bm=bm, bn=bn, interpret=interpret)
    else:
        dx, dy, dtau = kernel.grads(x, y, inv_tau, row_lse, col_lse,
                                    bm=bm, bn=bn, interpret=interpret)
    return ((g * dx).astype(x.dtype), (g * dy).astype(y.dtype), g * dtau)


fused_contrastive_loss.defvjp(_fwd, _bwd)


def fused_loss_and_lse(x, y, log_tau, interpret=False, bm=None, bn=None):
    """Non-VJP entry returning (loss, row_lse, col_lse) for diagnostics.

    x, y: (B, D) fp32/bf16 unit-norm embeddings; log_tau: scalar fp32.
    Returns (scalar fp32 loss, (B,) fp32 row LSE, (B,) fp32 col LSE)."""
    loss, (_, _, _, row_lse, col_lse) = _fwd(x, y, log_tau, interpret, bm, bn)
    return loss, row_lse, col_lse


def chunk_row_col_lse(x, y_chunk, inv_tau, interpret=False, bm=None, bn=None):
    """Blockwise row/col LSE of one square similarity chunk X·Y_chunkᵀ/τ.

    The streaming unit of the cross-shard chunked-negatives loss
    (core/distributed_loss.py, DESIGN.md §7.2): ``x`` is the shard's local
    (B_local, D) block, ``y_chunk`` one remote shard's (B_local, D) block.
    Returns ((B_local,) fp32 partial row LSE over this chunk's columns,
    (B_local,) fp32 partial col LSE over this chunk's rows); the caller
    logaddexp-combines row partials across chunks and psum-combines col
    partials across shards. One Pallas launch, no (B, B) materialization."""
    b, d = x.shape
    bm, bn = pick_blocks(b, d, x.dtype.itemsize, bm=bm, bn=bn)
    return kernel.fwd_fused(x, y_chunk, inv_tau, bm=bm, bn=bn,
                            interpret=interpret)


def chunk_grads(x, y_chunk, inv_tau, row_lse, col_lse_chunk, *, b_norm,
                with_diag=False, interpret=False, bm=None, bn=None):
    """dX/dY/dτ contribution of one square chunk of the cross-shard loss.

    x, y_chunk: (B_local, D); row_lse: (B_local,) GLOBAL row LSE of the
    local rows; col_lse_chunk: (B_local,) GLOBAL col LSE of this chunk's
    columns; b_norm: the GLOBAL batch size (1/(2·B_global) normalization).
    ``with_diag`` is True only for the shard-diagonal chunk, where the
    positive pairs live. Returns ((B_local, D) fp32 dX partial,
    (B_local, D) fp32 dY partial for this chunk's columns, scalar fp32
    dlog_tau partial). Uses the single-pass fused backward when its VMEM
    residency fits, else the legacy two-sweep kernels (same fallback rule
    as the square loss, DESIGN.md §2.3)."""
    b, d = x.shape
    bm, bn = pick_blocks(b, d, x.dtype.itemsize, bm=bm, bn=bn)
    if interpret or bwd_fits_fused(b, d, bm, bn, x.dtype.itemsize):
        return kernel.bwd_fused(x, y_chunk, inv_tau, row_lse, col_lse_chunk,
                                bm=bm, bn=bn, interpret=interpret,
                                b_norm=b_norm, with_diag=with_diag)
    return kernel.grads(x, y_chunk, inv_tau, row_lse, col_lse_chunk,
                        bm=bm, bn=bn, interpret=interpret,
                        b_norm=b_norm, with_diag=with_diag)


def fused_loss_and_lse_4pass(x, y, log_tau, interpret=False, bm=None,
                             bn=None):
    """Legacy 2-launch forward (separate row and col LSE sweeps), kept as
    the comparison baseline for benchmarks/kernel_bench.py. Returns
    (loss, row_lse, col_lse)."""
    b, d = x.shape
    bm, bn = pick_blocks(b, d, x.dtype.itemsize, bm=bm, bn=bn)
    inv_tau = jnp.exp(-log_tau)
    row_lse, col_lse = kernel.row_col_lse(x, y, inv_tau, bm=bm, bn=bn,
                                          interpret=interpret)
    diag = jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32),
                   axis=1) * inv_tau
    loss = 0.5 * (jnp.mean(row_lse - diag) + jnp.mean(col_lse - diag))
    return loss, row_lse, col_lse


def fused_contrastive_loss_4pass(x, y, log_tau, interpret=False,
                                 bm=None, bn=None):
    """Legacy 4-launch path (2 fwd + 2 bwd sweeps), kept as the comparison
    baseline for benchmarks/kernel_bench.py. Not differentiable; returns
    (loss, dx, dy, dtau) directly."""
    b, d = x.shape
    bm, bn = pick_blocks(b, d, x.dtype.itemsize, bm=bm, bn=bn)
    loss, row_lse, col_lse = fused_loss_and_lse_4pass(x, y, log_tau,
                                                      interpret, bm, bn)
    inv_tau = jnp.exp(-log_tau)
    dx, dy, dtau = kernel.grads(x, y, inv_tau, row_lse, col_lse,
                                bm=bm, bn=bn, interpret=interpret)
    return loss, dx, dy, dtau
