"""Jitted public op: fused contrastive loss with custom VJP.

``fused_contrastive_loss(x, y, log_tau)`` matches
``ref.loss_ref`` and its gradients match ``ref.contrastive_grads_ref``
(asserted over shape/dtype sweeps in tests/test_kernels.py) while keeping the
B×B similarity matrix out of HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.contrastive_loss import kernel


def _pick_block(b: int) -> int:
    for cand in (256, 128, 64, 32, 16, 8):
        if b % cand == 0:
            return cand
    return b


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_contrastive_loss(x, y, log_tau, interpret=False):
    loss, _ = _fwd(x, y, log_tau, interpret)
    return loss


def _fwd(x, y, log_tau, interpret):
    b = x.shape[0]
    bm = bn = _pick_block(b)
    inv_tau = jnp.exp(-log_tau)
    row_lse, col_lse = kernel.row_col_lse(x, y, inv_tau, bm=bm, bn=bn,
                                          interpret=interpret)
    diag = jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32),
                   axis=1) * inv_tau
    loss = 0.5 * (jnp.mean(row_lse - diag) + jnp.mean(col_lse - diag))
    return loss, (x, y, log_tau, row_lse, col_lse)


def _bwd(interpret, res, g):
    x, y, log_tau, row_lse, col_lse = res
    b = x.shape[0]
    bm = bn = _pick_block(b)
    inv_tau = jnp.exp(-log_tau)
    dx, dy, dtau = kernel.grads(x, y, inv_tau, row_lse, col_lse,
                                bm=bm, bn=bn, interpret=interpret)
    return (g * dx.astype(x.dtype), g * dy.astype(y.dtype), g * dtau)


fused_contrastive_loss.defvjp(_fwd, _bwd)


def fused_loss_and_lse(x, y, log_tau, interpret=False):
    """Non-VJP entry returning (loss, row_lse, col_lse) for diagnostics."""
    b = x.shape[0]
    bm = bn = _pick_block(b)
    inv_tau = jnp.exp(-log_tau)
    row_lse, col_lse = kernel.row_col_lse(x, y, inv_tau, bm=bm, bn=bn,
                                          interpret=interpret)
    diag = jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32),
                   axis=1) * inv_tau
    loss = 0.5 * (jnp.mean(row_lse - diag) + jnp.mean(col_lse - diag))
    return loss, row_lse, col_lse
