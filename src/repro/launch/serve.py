"""Serving launcher: batched generation with the Engine.

  python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.models import transformer as tf
from repro.serving import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--precision", default=None,
                    choices=["f32", "bf16", "bf16_pure"],
                    help="mixed-precision policy for prefill+decode "
                         "(models.precision; default f32, the engine's "
                         "historical dtype)")
    ap.add_argument("--attn", default=None,
                    choices=["naive", "chunked", "pallas", "auto"],
                    help="attention backend: prefill resolves it through "
                         "the models.attention registry, decode through "
                         "resolve_decode_backend ('pallas' = the "
                         "kernels/decode_attention cache sweep)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params = tf.init_params(cfg, jax.random.key(args.seed))
    moe_args = {"dispatch": "dense"} if args.smoke else None
    eng = Engine(cfg, params, cache_len=args.cache_len, moe_args=moe_args,
                 precision=args.precision, attn=args.attn)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(4, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    t0 = time.time()
    out = eng.generate(prompts, args.max_new, temperature=args.temperature,
                       seed=args.seed)
    dt = time.time() - t0
    toks = out.size
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    for row in out[:4]:
        print(" ", row[:16].tolist(), "...")


if __name__ == "__main__":
    main()
