"""Serving launcher: batched generation with the Engine, or a request-queue
driver over the continuous-batching engine.

  # lockstep batch (legacy):
  python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --prompt-len 16 --max-new 32

  # continuous batching: synthetic request queue with staggered arrivals
  python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --engine continuous --slots 4 --requests 16 --arrival 0.05 \
      --prompt-len 16 --max-new 32

The continuous driver submits ``--requests`` requests with Poisson-ish
inter-arrival gaps (``--arrival`` mean seconds; 0 = all up front), ragged
prompt lengths around ``--prompt-len``, and reports tokens/s, slot
occupancy, and admission-wait quantiles from the engine's obs registry.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.models import transformer as tf
from repro.serving import ContinuousEngine, Engine


def _run_legacy(cfg, params, moe_args, args):
    eng = Engine(cfg, params, cache_len=args.cache_len, moe_args=moe_args,
                 precision=args.precision, attn=args.attn)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(4, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    t0 = time.time()
    out = eng.generate(prompts, args.max_new, temperature=args.temperature,
                       seed=args.seed)
    dt = time.time() - t0
    toks = out.size
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    for row in out[:4]:
        print(" ", row[:16].tolist(), "...")


def _run_continuous(cfg, params, moe_args, args):
    slo_s = args.slo_ms / 1e3 if getattr(args, "slo_ms", None) else None
    eng = ContinuousEngine(cfg, params, cache_len=args.cache_len,
                           num_slots=args.slots, moe_args=moe_args,
                           precision=args.precision, attn=args.attn,
                           temperature=args.temperature, seed=args.seed,
                           latency_slo_s=slo_s)
    server = None
    if getattr(args, "metrics_port", None) is not None:
        server = eng.serve_metrics(port=args.metrics_port)
        print(f"obs: serving /metrics /healthz /snapshot.json on "
              f"{server.url}")
    rng = np.random.default_rng(args.seed)
    # ragged prompts around --prompt-len so admission sees mixed shapes
    # (bucketed to 4 lengths: prefill compiles once per bucket)
    lens = np.clip(args.prompt_len + rng.choice([-4, 0, 4, 8], args.requests),
                   1, None)
    arrivals = (np.zeros(args.requests) if args.arrival <= 0
                else rng.exponential(args.arrival, args.requests))
    reqs = [(rng.integers(4, cfg.vocab, (int(pl),), dtype=np.int32),
             args.max_new) for pl in lens]

    t0 = time.time()
    done, submitted = {}, 0
    while submitted < len(reqs) or eng.pending:
        now = time.time() - t0
        while submitted < len(reqs) and arrivals[:submitted + 1].sum() <= now:
            eng.submit(*reqs[submitted])
            submitted += 1
        for fin in eng.step():
            done[fin.request_id] = fin.tokens
        if not eng.pending and submitted < len(reqs):
            time.sleep(min(0.005, args.arrival or 0.005))
    dt = time.time() - t0

    snap = eng.stats()
    toks = eng.registry.counter("decode/tokens").value
    admit = eng.registry.histogram("decode/admission_wait_s").summary()
    occ = eng.registry.histogram("decode/slot_occupancy_ratio").summary()
    occ_mean = occ["sum"] / occ["count"] if occ["count"] else 0.0
    admit_mean = admit["sum"] / admit["count"] if admit["count"] else 0.0
    print(f"served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({snap['derived']['tokens_per_sec']:.1f} tok/s incl. compile)")
    print(f"slot occupancy: mean {occ_mean:.2f} over {occ['count']} ticks; "
          f"admission wait: mean {admit_mean*1e3:.1f}ms "
          f"p99~{admit['p99']*1e3:.1f}ms over {admit['count']} admissions")
    if "slo" in snap:
        s = snap["slo"]
        print(f"slo: p99 {s['p99_s']*1e3:.1f}ms vs target "
              f"{s['target_s']*1e3:.1f}ms  burn {s['error_budget_burn']:.2f}  "
              f"{'READY' if s['healthy'] else 'NOT READY'}")
    for rid in sorted(done)[:4]:
        print(f"  req {rid}:", done[rid][:16].tolist(), "...")
    if server is not None:
        server.stop()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="legacy",
                    choices=["legacy", "continuous"],
                    help="'legacy' = lockstep fixed batch; 'continuous' = "
                         "slot-based admission queue (serving.continuous)")
    ap.add_argument("--batch", type=int, default=4,
                    help="[legacy] fixed batch size")
    ap.add_argument("--slots", type=int, default=4,
                    help="[continuous] cache slot capacity")
    ap.add_argument("--requests", type=int, default=16,
                    help="[continuous] number of synthetic requests")
    ap.add_argument("--arrival", type=float, default=0.0,
                    help="[continuous] mean inter-arrival gap in seconds "
                         "(0 = all requests queued up front)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--precision", default=None,
                    choices=["f32", "bf16", "bf16_pure"],
                    help="mixed-precision policy for prefill+decode "
                         "(models.precision; default f32, the engine's "
                         "historical dtype)")
    ap.add_argument("--attn", default=None,
                    choices=["naive", "chunked", "pallas", "auto"],
                    help="attention backend: prefill resolves it through "
                         "the models.attention registry, decode through "
                         "resolve_decode_backend ('pallas' = the "
                         "kernels/decode_attention cache sweep)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="[continuous] end-to-end request latency SLO "
                         "target in ms (submit→finish, queue wait "
                         "included): windowed p99 + error-budget burn "
                         "under decode/slo_* (DESIGN.md §14.3)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="[continuous] serve live /metrics /healthz "
                         "/snapshot.json on 127.0.0.1:PORT "
                         "(0 = ephemeral)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params = tf.init_params(cfg, jax.random.key(args.seed))
    moe_args = {"dispatch": "dense"} if args.smoke else None
    if args.engine == "continuous":
        _run_continuous(cfg, params, moe_args, args)
    else:
        _run_legacy(cfg, params, moe_args, args)


if __name__ == "__main__":
    main()
