import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 host-platform placeholder devices.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k \
      --mesh pod --sharding basic_ws [--remat basic] [--out DIR]
  python -m repro.launch.dryrun --all --mesh pod      # every combo

``--arch``/``--shape`` are required unless ``--all``; dual-encoder archs
(basic-{s,m,l}) compile the paper's contrastive GradAccum step instead of
an LM step. Model/compile knobs — ``--attn {naive,chunked,pallas,auto}``,
``--dispatch {dense,capacity}``, ``--moe-group N``, ``--param-dtype
{bf16,f32}``, ``--batch-over {data,all}``, ``--ssm-chunk N``,
``--unroll N`` — tag the output JSON filename; results land one file per
combo under ``--out`` (default experiments/dryrun, cached by filename).
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs.base import (  # noqa: E402
    INPUT_SHAPES, applicable_shapes, get_arch, list_archs)
from repro.launch import roofline as rf  # noqa: E402
from repro.launch import steps as st  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def run_one(arch: str, shape_name: str, *, multi_pod=False,
            sharding="basic_ws", remat="basic", verbose=True,
            unroll=None, attn="naive", moe_group=4096,
            dispatch=None, param_dtype=None, batch_over="data",
            ssm_chunk=None) -> dict:
    import dataclasses
    cfg = get_arch(arch)
    if not hasattr(cfg, "family"):      # dual-encoder (basic-{s,m,l})
        return run_contrastive_dryrun(cfg, shape_name, multi_pod=multi_pod,
                                      sharding=sharding, remat=remat,
                                      verbose=verbose,
                                      batch_over=batch_over)
    if attn != "naive":
        cfg = dataclasses.replace(cfg, attn_impl=attn)
    if ssm_chunk is not None and cfg.ssm is not None:
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk=ssm_chunk))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    # XLA costs a while-loop body ONCE (not x trip count), so a scanned
    # layer stack under-reports flops/bytes/collectives. We compile at
    # unroll=1 and unroll=2 and linearly extrapolate the homogeneous loop
    # body:  total = F1 + (n_periods - 1) * (F2 - F1).   "--unroll N"
    # overrides with a direct single compile at that unroll.
    from repro.models.transformer import period_of
    n_periods = cfg.n_layers // period_of(cfg)
    extrapolate = unroll is None and n_periods >= 2

    margs = dict(st.DEFAULT_MOE_ARGS, group=moe_group)
    serve_margs = None
    if dispatch is not None:
        margs["dispatch"] = dispatch
        serve_margs = dict(margs, group=min(moe_group,
                                            shape.global_batch))

    def build(u):
        if shape.kind == "train":
            fn, opt = st.make_train_step(cfg, remat=remat, unroll=u,
                                         moe_args=margs)
            oabs = st.abstract_opt_state(cfg, opt, params_abs)
        elif shape.kind == "prefill":
            fn, oabs = st.make_prefill_step(cfg, unroll=u,
                                            moe_args=margs), None
        else:
            fn, oabs = st.make_serve_step(cfg, unroll=u,
                                          moe_args=serve_margs), None
        return fn, oabs

    params_abs = st.abstract_params(cfg)
    if param_dtype is not None:
        import jax.numpy as jnp
        dt = {"bf16": jnp.bfloat16, "f32": jnp.float32}[param_dtype]
        params_abs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, dt)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params_abs)

    def compile_at(u):
        fn, oabs = build(u)
        in_sh, inputs = st.shardings_for(cfg, shape, mesh, sharding,
                                         params_abs, oabs,
                                         batch_over=batch_over)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*inputs)
            return lowered.compile()

    if extrapolate:
        c1 = compile_at(1)
        t_lower = time.time() - t0
        c2 = compile_at(2)
        t_compile = time.time() - t0 - t_lower
        cost1, cost2 = c1.cost_analysis(), c2.cost_analysis()
        coll1 = rf.collective_bytes(c1.as_text())
        coll2 = rf.collective_bytes(c2.as_text())

        def extrap(a, b):
            return {k: float(a.get(k, 0))
                    + (n_periods - 1) * (float(b.get(k, 0))
                                         - float(a.get(k, 0)))
                    for k in set(a) | set(b)
                    if isinstance(a.get(k, b.get(k)), (int, float))}

        cost = extrap(cost1, cost2)
        coll = extrap(coll1, coll2)
        mem = c1.memory_analysis()   # scan IS the real execution structure
        compiled = c1
    else:
        u = unroll if unroll is not None else 1
        compiled = compile_at(u)
        t_lower = time.time() - t0
        t_compile = 0.0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = rf.collective_bytes(compiled.as_text())

    terms = rf.roofline_terms(cost, coll)
    n_active = cfg.param_counts()["active"]
    mflops = rf.model_flops(cfg, shape, n_active)
    chips = mesh.devices.size
    hlo_flops_global = terms["flops_per_device"] * chips

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(chips), "sharding": sharding, "remat": remat,
        "attn": attn, "moe_group": moe_group, "dispatch": dispatch,
        "param_dtype": param_dtype, "batch_over": batch_over,
        "ssm_chunk": ssm_chunk,
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes_per_device": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_gb_per_device": round(
                (getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "temp_size_in_bytes", 0)) / 2**30, 3),
        },
        "collectives": coll,
        "roofline": terms,
        "model_flops_global": mflops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": (mflops / hlo_flops_global
                               if hlo_flops_global else None),
    }
    if verbose:
        print(f"[{arch} × {shape_name} × {result['mesh']} × {sharding}] "
              f"compile={t_compile:.1f}s "
              f"compute={terms['compute_s']*1e3:.2f}ms "
              f"mem={terms['memory_s']*1e3:.2f}ms "
              f"coll={terms['collective_s']*1e3:.2f}ms "
              f"bottleneck={terms['bottleneck']} "
              f"useful={result['useful_flops_ratio'] and round(result['useful_flops_ratio'],3)}")
        print("  memory_analysis:", result["memory"])
    return result


def main():
    ap = argparse.ArgumentParser(
        description="lower + compile (arch × input-shape × mesh) combos on "
                    "512 simulated devices; writes one JSON per combo")
    ap.add_argument("--arch", help="arch name from repro.configs "
                                   "(required unless --all)")
    ap.add_argument("--shape", help="input-shape name from "
                                    "configs.INPUT_SHAPES "
                                    "(required unless --all)")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod",
                    help="16x16 pod, 2x16x16 multipod, or both")
    ap.add_argument("--sharding", default="basic_ws",
                    choices=["basic_ws", "tp", "replicated"],
                    help="weight-sharding mode (core.sharding)")
    ap.add_argument("--remat", default="basic",
                    help="jax.checkpoint policy (core.remat registry)")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch × shape)")
    ap.add_argument("--out", default="experiments/dryrun",
                    help="output dir; existing result files are skipped")
    ap.add_argument("--attn", default="naive",
                    choices=["naive", "chunked", "pallas", "auto"],
                    help="attention backend override (models.attention "
                         "registry; 'pallas' lowers the flash kernels — "
                         "host-platform dry-runs fall back per "
                         "resolve_backend)")
    ap.add_argument("--dispatch", default=None,
                    choices=[None, "dense", "capacity"],
                    help="MoE dispatch override")
    ap.add_argument("--param-dtype", default=None,
                    choices=[None, "bf16", "f32"],
                    help="cast floating params before compile")
    ap.add_argument("--batch-over", default="data", choices=["data", "all"],
                    help="input batch over the data axes only, or over ALL "
                         "cores incl. model (paper §5.1)")
    ap.add_argument("--ssm-chunk", type=int, default=None,
                    help="SSM scan chunk override")
    ap.add_argument("--moe-group", type=int, default=4096,
                    help="MoE dispatch group size")
    ap.add_argument("--unroll", type=int, default=None,
                    help="layer-scan unroll (default: compile at unroll=1 "
                         "and 2, then extrapolate the homogeneous loop "
                         "body for accurate cost analysis)")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in list_archs():
            cfg = get_arch(a)
            if not hasattr(cfg, "family"):  # dual-encoder configs: skip here
                continue
            for s in applicable_shapes(cfg):
                combos.append((a, s.name))
    else:
        combos.append((args.arch, args.shape))

    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[
        args.mesh]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in combos:
        for mp in meshes:
            tag = (f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}_"
                   f"{args.sharding}_{args.remat}"
                   + ("" if args.attn == "naive" else f"_{args.attn}")
                   + ("" if args.moe_group == 4096 else f"_g{args.moe_group}")
                   + ("" if args.dispatch is None else f"_{args.dispatch}")
                   + ("" if args.param_dtype is None else f"_p{args.param_dtype}")
                   + ("" if args.batch_over == "data" else "_ball")
                   + ("" if args.ssm_chunk is None else f"_sc{args.ssm_chunk}"))
            path = os.path.join(args.out, tag.replace("/", "-") + ".json")
            if os.path.exists(path):
                print(f"[skip cached] {tag}")
                continue
            try:
                res = run_one(arch, shape, multi_pod=mp,
                              sharding=args.sharding, remat=args.remat,
                              unroll=args.unroll, attn=args.attn,
                              moe_group=args.moe_group,
                              dispatch=args.dispatch,
                              param_dtype=args.param_dtype,
                              batch_over=args.batch_over,
                              ssm_chunk=args.ssm_chunk)
            except Exception as e:  # noqa: BLE001
                failures += 1
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "sharding": args.sharding, "remat": args.remat,
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)




def run_contrastive_dryrun(dual_cfg, shape_name, *, multi_pod=False,
                           sharding="basic_ws", remat="basic", verbose=True,
                           num_micro=8, batch_over="data") -> dict:
    """Lower+compile the paper's own step: BASIC dual-encoder contrastive
    GradAccum at B=65536 (M=8192). Tower scans run at unroll=1 (no
    extrapolation — this run proves memory/sharding coherence at the paper's
    batch size; roofline precision comes from the LM combos)."""
    import jax.numpy as jnp
    from repro.core import sharding as shd
    shape = INPUT_SHAPES[shape_name]
    assert shape.kind == "contrastive"
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()

    step, opt = st.make_contrastive_step(dual_cfg, num_micro=num_micro,
                                         remat=remat)
    params_abs = jax.eval_shape(
        lambda k: __import__("repro.models.dual_encoder",
                             fromlist=["init_params"]).init_params(
                                 dual_cfg, k), jax.random.key(0))
    opt_abs = jax.eval_shape(opt.init, params_abs)
    ins = st.contrastive_input_specs(dual_cfg, shape)
    baxes = None
    if batch_over == "all":
        baxes = (*shd.data_axes(mesh), shd.MODEL)
    pspecs = shd.to_named(shd.params_specs(params_abs, mesh, sharding), mesh)
    ospecs = shd.to_named(shd.params_specs(opt_abs, mesh, sharding), mesh)
    bspecs = shd.to_named(shd.batch_specs(ins, mesh, batch_axes=baxes), mesh)
    with mesh:
        lowered = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs)).lower(
            params_abs, opt_abs, ins)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = rf.collective_bytes(compiled.as_text())
    terms = rf.roofline_terms(cost, coll)
    result = {
        "arch": dual_cfg.name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(mesh.devices.size), "sharding": sharding,
        "remat": remat, "num_micro": num_micro, "ok": True,
        "extrapolated": False,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": int(getattr(mem, "argument_size_in_bytes", 0)),
            "temp_bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_gb_per_device": round(
                (getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "temp_size_in_bytes", 0)) / 2**30, 3),
        },
        "collectives": coll, "roofline": terms,
    }
    if verbose:
        print(f"[{dual_cfg.name} x {shape_name} x {result['mesh']} x "
              f"{sharding} micro={num_micro}] compile={t_compile:.1f}s "
              f"peak={result['memory']['peak_gb_per_device']}GB "
              f"coll={terms['collective_s']*1e3:.1f}ms")
    return result


if __name__ == "__main__":
    main()
