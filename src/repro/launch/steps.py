"""Step-function factory shared by dryrun/train/serve.

For each (arch, input-shape kind) this builds:
  - the jittable step fn (train_step / prefill_step / serve_step),
  - abstract inputs (ShapeDtypeStruct stand-ins, no allocation),
  - in_shardings matching the fn's positional args for a given mesh + mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.core import remat as remat_lib
from repro.core import sharding as shd
from repro.models import frontends, transformer as tf
from repro.optim.adafactorw import AdaFactorW, apply_updates

DEFAULT_MOE_ARGS = {"dispatch": "capacity", "group": 4096,
                    "capacity_factor": 1.25}


def make_optimizer(weight_decay=0.0025):
    return AdaFactorW(beta1=0.9, beta2=0.99, weight_decay=weight_decay)


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda k: tf.init_params(cfg, k),
                          jax.random.key(0))


def abstract_opt_state(cfg: ArchConfig, opt: AdaFactorW, params_abs):
    return jax.eval_shape(opt.init, params_abs)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, *, remat: str = "basic",
                    moe_args: Optional[dict] = None, lr: float = 1e-3,
                    precision="bf16", unroll: int = 1):
    """LM train step factory; ``precision`` is a models.precision policy
    name/object governing tower compute dtypes (default 'bf16')."""
    opt = make_optimizer()
    policy = remat_lib.get_policy(remat)
    margs = DEFAULT_MOE_ARGS if moe_args is None else moe_args

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = tf.lm_loss(cfg, p, batch, precision=precision,
                                       remat_policy=policy, moe_args=margs,
                                       unroll=unroll)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params)
        updates, opt_state = opt.update(grads, opt_state, params, lr)
        params = apply_updates(params, updates)
        return params, opt_state, loss, metrics

    return train_step, opt


def make_prefill_step(cfg: ArchConfig, *, moe_args: Optional[dict] = None,
                      precision="bf16", unroll: int = 1):
    """Prefill step factory (last-position logits)."""
    margs = DEFAULT_MOE_ARGS if moe_args is None else moe_args

    def prefill_step(params, batch):
        return tf.prefill(cfg, params, batch, precision=precision,
                          moe_args=margs, unroll=unroll)

    return prefill_step


def make_serve_step(cfg: ArchConfig, *, moe_args: Optional[dict] = None,
                    precision="bf16", unroll: int = 1):
    """Single-token decode step factory."""
    if moe_args is None:
        # historical default: dense dispatch for single-token decode. This is
        # EXACT but computes every expert for every token — the arctic-480b
        # hillclimb (EXPERIMENTS.md §Perf) showed capacity dispatch with
        # group=batch cuts decode memory traffic ~top_k/E; pass
        # moe_args={'dispatch': 'capacity', ...} to get the optimized path.
        margs = dict(DEFAULT_MOE_ARGS, dispatch="dense")
    else:
        margs = dict(moe_args)

    def serve_step(params, caches, token, pos):
        logits, caches = tf.decode_step(cfg, params, token, pos, caches,
                                        precision=precision, moe_args=margs,
                                        unroll=unroll)
        return logits, caches

    return serve_step


def make_contrastive_step(dual_cfg, *, num_micro: int = 8,
                          remat: str = "basic", remat_image: str = None,
                          remat_text: str = None, lr: float = 2.5e-4,
                          precision="bf16", attn: Optional[str] = None,
                          unroll: int = 1,
                          mesh=None, loss: str = "local",
                          loss_opts: Optional[dict] = None,
                          skip_nonfinite: bool = False):
    """The paper's own training step: Algorithm-1 GradAccum over num_micro
    microbatches (B=65536, M=B/num_micro=8192 matches App. E) + AdaFactorW.

    ``precision`` is a models.precision policy (name/object): towers run in
    its compute dtype, embeddings + loss always land fp32. ``attn``
    overrides both towers' attention backend (models.attention registry:
    naive | chunked | pallas | auto); None keeps each tower's configured
    ``attn_impl``.

    remat selects the jax.checkpoint policy for both towers;
    remat_image/remat_text override it per tower (core.remat registry).
    ``loss`` selects the embedding-level loss:
      'local'     — materializing reference (core.contrastive, B×B in HBM)
      'fused'     — single-pass fused Pallas kernel, single-device global
      'allgather' / 'chunked' — cross-shard GLOBAL-batch loss over the
        data axes of ``mesh`` (required), via core.distributed_loss; the
        embeddings are pinned batch-sharded so GradAccum × data-parallel ×
        tensor-parallel compose under one jit (DESIGN.md §7).
    ``loss_opts`` forwards kernel overrides (interpret/bm/bn).

    ``skip_nonfinite=True`` arms the in-jit step guard (DESIGN.md §14.2):
    the step also computes the global grad norm and, when loss or grad
    norm is non-finite, keeps the INCOMING params/opt-state via an
    elementwise ``jnp.where`` select — the poisoned update is dropped on
    device (no host round-trip, donation-safe) and ``metrics`` gains
    ``grad_norm`` plus a 0/1 ``skipped`` flag for the health monitor.
    Finite steps take the identical update values, so guarded training is
    bit-exact with unguarded training until the first bad step.

    Returns (train_step, opt); train_step(params, opt_state, batch) ->
    (params, opt_state, loss, metrics)."""
    import dataclasses

    from repro.core import distributed_loss as dist
    from repro.core.contrastive import contrastive_loss, fused_kernel_loss
    from repro.core.gradaccum import contrastive_step as ga_step
    from repro.models import dual_encoder as de
    if attn is not None:
        dual_cfg = dataclasses.replace(
            dual_cfg,
            image_tower=dataclasses.replace(dual_cfg.image_tower,
                                            attn_impl=attn),
            text_tower=dataclasses.replace(dual_cfg.text_tower,
                                           attn_impl=attn))
    opt = make_optimizer()
    policy_i = remat_lib.get_policy(remat if remat_image is None
                                    else remat_image)
    policy_t = remat_lib.get_policy(remat if remat_text is None
                                    else remat_text)

    emb_shd = None
    if loss == "local":
        loss_fn, lopts = contrastive_loss, (loss_opts or {})
    elif loss == "fused":
        loss_fn, lopts = fused_kernel_loss, (loss_opts or {})
    elif loss in dist.METHODS:
        if mesh is None:
            raise ValueError(f"loss={loss!r} needs a mesh")
        loss_fn = dist.make_global_loss_fn(mesh, loss, **(loss_opts or {}))
        lopts = {}
        emb_shd = dist.emb_sharding(mesh)
    else:
        raise ValueError(f"unknown loss {loss!r}")

    def enc_i(p, images):
        return de.encode_image(dual_cfg, p, images, precision=precision,
                               remat_policy=policy_i)

    def enc_t(p, texts):
        return de.encode_text(dual_cfg, p, texts, precision=precision,
                              remat_policy=policy_t)

    def train_step(params, opt_state, batch):
        loss_val, metrics, grads = ga_step(enc_i, enc_t, params, batch,
                                           num_micro, loss_fn=loss_fn,
                                           loss_opts=lopts,
                                           emb_sharding=emb_shd)
        updates, new_opt = opt.update(grads, opt_state, params, lr)
        new_params = apply_updates(params, updates)
        if skip_nonfinite:
            gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                                 for g in jax.tree.leaves(grads)))
            ok = jnp.isfinite(loss_val) & jnp.isfinite(gnorm)
            new_params = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_params, params)
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_opt, opt_state)
            metrics = dict(metrics, grad_norm=gnorm,
                           skipped=(~ok).astype(jnp.int32))
        return new_params, new_opt, loss_val, metrics

    return train_step, opt


def contrastive_input_specs(dual_cfg, shape, *, dtype=jnp.float32):
    """Abstract contrastive batch: raw images for the patchify frontend +
    caption tokens (shapes from the dual config and the InputShape)."""
    SDS = jax.ShapeDtypeStruct
    b = shape.global_batch
    it = dual_cfg.image_tower
    return {
        "images": {"image":
                   SDS((b, it.image_size, it.image_size, it.channels),
                       dtype)},
        "texts": {"tokens": SDS((b, shape.seq_len), jnp.int32)},
    }


# ---------------------------------------------------------------------------
# abstract inputs + shardings per shape kind
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: InputShape, *, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    SDS = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        return frontends.train_inputs_spec(cfg, shape, dtype=dtype)
    caches = jax.eval_shape(
        lambda: tf.init_caches(cfg, shape.global_batch, shape.seq_len,
                               dtype=dtype))
    return {
        "caches": caches,
        "token": SDS((shape.global_batch, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }


def shardings_for(cfg: ArchConfig, shape: InputShape, mesh, mode: str,
                  params_abs, opt_abs=None, *, dtype=jnp.bfloat16,
                  batch_over: str = "data"):
    """Returns (in_shardings tuple matching the step fn args, inputs tuple).

    batch_over: 'data' shards inputs over ('pod','data') only; 'all' adds the
    'model' axis when divisible — the paper's exact §5.1 input distribution
    ("B examples distributed equally to ALL cores regardless of R")."""
    baxes = None
    if batch_over == "all":
        baxes = (*shd.data_axes(mesh), shd.MODEL)
    pspecs = shd.to_named(shd.params_specs(params_abs, mesh, mode), mesh)
    ins = input_specs(cfg, shape, dtype=dtype)
    if shape.kind == "train":
        ospecs = shd.to_named(shd.params_specs(opt_abs, mesh, mode), mesh)
        bspecs = shd.to_named(shd.batch_specs(ins, mesh, batch_axes=baxes),
                              mesh)
        return (pspecs, ospecs, bspecs), (params_abs, opt_abs, ins)
    if shape.kind == "prefill":
        bspecs = shd.to_named(shd.batch_specs(ins, mesh, batch_axes=baxes),
                              mesh)
        return (pspecs, bspecs), (params_abs, ins)
    # decode
    cspecs = shd.to_named(shd.cache_specs(ins["caches"], mesh), mesh)
    tspec = shd.to_named(shd.batch_specs(ins["token"], mesh), mesh)
    posspec = shd.to_named(jax.sharding.PartitionSpec(), mesh)
    return (pspecs, cspecs, tspec, posspec), \
        (params_abs, ins["caches"], ins["token"], ins["pos"])
