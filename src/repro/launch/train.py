"""Trainer: LM pretraining of assigned archs + the BASIC 3-phase recipe.

Modes
-----
lm:           next-token training of any assigned arch (reduced or full size)
              on synthetic tokens — the end-to-end driver for smoke scale.
pretrain:     BASIC §8 phase 1 — softmax classification of the image tower on
              the labeled (JFT-analog) synthetic set.
contrastive:  BASIC §8 phase 2 — freeze image tower, contrastive-train text
              tower with Algorithm-1 GradAccum (exact) at any B/M ratio.
finetune:     BASIC §8 phase 3 — unfreeze both towers, small LR.

Examples:
  python -m repro.launch.train --mode lm --arch llama3.2-1b --smoke \
      --steps 100 --batch 8 --seq 128
  python -m repro.launch.train --mode contrastive --arch basic-s --smoke \
      --steps 200 --batch 64 --micro 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_arch, smoke_variant
from repro.core.contrastive import contrastive_loss
from repro.core.gradaccum import contrastive_step
from repro.data import contrastive_batch, jft_batch, load_tokenizer, \
    world_for_tower
from repro.models import dual_encoder as de
from repro.models import frontends
from repro.models import transformer as tf
from repro.optim import AdaFactorW, apply_updates, warmup_cosine


def _smoke_dual(cfg):
    from repro.configs import smoke_dual_variant
    return smoke_dual_variant(cfg, embed_dim=64)


# ---------------------------------------------------------------------------
# LM mode
# ---------------------------------------------------------------------------


def run_lm(args):
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params = tf.init_params(cfg, jax.random.key(args.seed))
    opt = AdaFactorW(weight_decay=0.0025)
    opt_state = opt.init(params)
    lr_fn = warmup_cosine(args.lr, args.lr / 100, args.steps // 10 or 1,
                          args.steps)
    moe_args = {"dispatch": "dense"} if args.smoke else None

    @jax.jit
    def step_fn(params, opt_state, batch, step):
        def loss_fn(p):
            return tf.lm_loss(cfg, p, batch, moe_args=moe_args)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params)
        updates, opt_state2 = opt.update(grads, opt_state, params,
                                         lr_fn(step))
        return apply_updates(params, updates), opt_state2, loss

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.steps):
        batch = frontends.synthetic_inputs(cfg, args.batch, args.seq, rng)
        params, opt_state, loss = step_fn(params, opt_state, batch,
                                          jnp.asarray(i))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(loss):.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.ckpt_dir:
        print("saved:", ckpt.save(args.ckpt_dir, args.steps, params))
    return params


# ---------------------------------------------------------------------------
# BASIC phases
# ---------------------------------------------------------------------------


def _build_world(args):
    rng = np.random.default_rng(args.seed)
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = _smoke_dual(cfg)
    world = world_for_tower(rng, cfg.image_tower, n_classes=args.classes)
    # the versioned committed artifact — NOT retrained per run, so the text
    # tower's token ids (and hence its checkpoints) are portable
    tok = load_tokenizer(getattr(args, "tokenizer", None) or "v1")
    # clamp token ids to the tower vocab
    assert tok.vocab_size <= cfg.text_tower.vocab or args.smoke
    return cfg, world, tok, rng


def run_pretrain(args):
    """Phase 1: image tower + linear classifier on JFT-analog labels."""
    cfg, world, tok, rng = _build_world(args)
    icfg = cfg.image_tower
    key = jax.random.key(args.seed)
    params = {"tower": tf.init_params(icfg, key),
              "head": jax.random.normal(key, (icfg.d_model, world.n_classes))
              * icfg.d_model ** -0.5}
    opt = AdaFactorW()
    opt_state = opt.init(params)

    @jax.jit
    def step_fn(params, opt_state, images, labels):
        def loss_fn(p):
            h = tf.encode(icfg, p["tower"], {"image": images})
            logits = h @ p["head"]
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = opt.update(grads, opt_state, params, args.lr)
        return apply_updates(params, updates), opt_state, loss

    for i in range(args.steps):
        batch, _ = jft_batch(world, args.batch, rng)
        params, opt_state, loss = step_fn(
            params, opt_state, jnp.asarray(batch["image"]),
            jnp.asarray(batch["labels"]))
        if i % args.log_every == 0:
            print(f"pretrain step {i:5d} xent {float(loss):.4f}")
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, params)
    return params


def run_contrastive(args, image_tower_init=None, train_image=False):
    """Phases 2/3: contrastive training with Algorithm-1 GradAccum."""
    cfg, world, tok, rng = _build_world(args)
    key = jax.random.key(args.seed + 1)
    params = de.init_params(cfg, key)
    if image_tower_init is not None:
        params["image"]["tower"] = image_tower_init

    opt = AdaFactorW(weight_decay=0.0025)
    opt_state = opt.init(params)
    lr_fn = warmup_cosine(args.lr, args.lr / 100, args.steps // 10 or 1,
                          args.steps)

    def enc_i(p, images):
        return de.encode_image(cfg, p, images)

    def enc_t(p, texts):
        return de.encode_text(cfg, p, texts)

    frozen_image = not train_image and image_tower_init is not None

    @jax.jit
    def step_fn(params, opt_state, batch, step):
        loss, metrics, grads = contrastive_step(
            enc_i, enc_t, params, batch, args.micro,
            loss_fn=lambda x, y, tau: contrastive_loss(x, y, tau))
        if frozen_image:
            grads["image"]["tower"] = jax.tree.map(
                jnp.zeros_like, grads["image"]["tower"])
        updates, opt_state = opt.update(grads, opt_state, params,
                                        lr_fn(step))
        return apply_updates(params, updates), opt_state, loss, metrics

    for i in range(args.steps):
        batch, _ = contrastive_batch(world, tok, args.batch, rng)
        batch = jax.tree.map(jnp.asarray, batch)
        params, opt_state, loss, metrics = step_fn(params, opt_state, batch,
                                                   jnp.asarray(i))
        if i % args.log_every == 0:
            print(f"contrastive step {i:5d} loss {float(loss):.4f} "
                  f"i2t@1 {float(metrics['i2t_top1']):.3f}")
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, params)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", required=True,
                    choices=["lm", "pretrain", "contrastive", "finetune"])
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=4)
    ap.add_argument("--classes", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--tokenizer", default="v1",
                    help="tokenizer artifact version "
                         "(artifacts/tokenizer_<v>.json)")
    args = ap.parse_args()

    if args.mode == "lm":
        run_lm(args)
    elif args.mode == "pretrain":
        run_pretrain(args)
    elif args.mode == "contrastive":
        run_contrastive(args)
    else:  # finetune: both towers trainable
        run_contrastive(args, train_image=True)


if __name__ == "__main__":
    main()
