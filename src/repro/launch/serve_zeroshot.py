"""Zero-shot serving launcher: the ZeroShotService under synthetic traffic.

  PYTHONPATH=src python -m repro.launch.serve_zeroshot --smoke \
      --classes 64 --batch 16 --requests 8 --k 5

Builds a BASIC dual encoder, precomputes the class matrix through the
registry (persisted under --registry-dir when given, so a second launch
skips the text tower entirely), then pushes --requests classify batches
through the micro-batcher + fused similarity→top-k path and reports
latency/throughput.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.data import load_tokenizer, world_for_tower
from repro.data.synthetic import render_images
from repro.models import dual_encoder as de
from repro.serving import ZeroShotService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="basic-s")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink towers to test size (CPU interpret mode)")
    ap.add_argument("--classes", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--registry-dir", default=None)
    ap.add_argument("--tokenizer", default="v1",
                    help="tokenizer artifact version "
                         "(artifacts/tokenizer_<v>.json)")
    ap.add_argument("--max-delay-ms", type=float, default=2.0)
    ap.add_argument("--retrieval", default="fused",
                    choices=("fused", "sharded", "twostage"),
                    help="top-k sweep: single-device fused kernel, "
                         "mesh-sharded exact, or coarse→fine two-stage "
                         "(DESIGN.md §13)")
    ap.add_argument("--nprobe", default=None,
                    help="twostage blocks probed per query (int or 'all' "
                         "= exact; default all)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="arm the serving SLO tracker: per-request latency "
                         "target in ms (windowed p99 + error-budget burn "
                         "under serve/slo_*; DESIGN.md §14.3)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve live /metrics (Prometheus), /healthz (SLO "
                         "readiness) and /snapshot.json on 127.0.0.1:PORT "
                         "(0 = ephemeral) for the whole run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    nprobe = None if args.nprobe in (None, "all") else int(args.nprobe)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(
            cfg, image_tower=smoke_variant(cfg.image_tower),
            text_tower=smoke_variant(cfg.text_tower), embed_dim=64)

    rng = np.random.default_rng(args.seed)
    world = world_for_tower(rng, cfg.image_tower, n_classes=args.classes)
    # the committed artifact: its hash rides in the registry fingerprint,
    # so serving and eval key their cached class matrices to THIS vocab
    tok = load_tokenizer(args.tokenizer)
    params = de.init_params(cfg, jax.random.key(args.seed))

    slo_s = args.slo_ms / 1e3 if args.slo_ms else None
    with ZeroShotService(cfg, params, tok,
                         registry_dir=args.registry_dir,
                         max_delay_ms=args.max_delay_ms,
                         retrieval=args.retrieval, nprobe=nprobe,
                         latency_slo_s=slo_s) as svc:
        server = None
        if args.metrics_port is not None:
            server = svc.serve_metrics(port=args.metrics_port)
            print(f"obs: serving /metrics /healthz /snapshot.json on "
                  f"{server.url}")
        t0 = time.time()
        svc.classify(render_images(world, rng.integers(
            0, args.classes, args.batch), rng), world.class_names, k=args.k)
        print(f"first classify (compile + class matrix): {time.time()-t0:.2f}s")

        lat = []
        hits = 0
        for _ in range(args.requests):
            cls = rng.integers(0, args.classes, args.batch)
            imgs = render_images(world, cls, rng)
            t0 = time.time()
            res = svc.classify(imgs, world.class_names, k=args.k)
            lat.append(time.time() - t0)
            hits += int(np.sum(res.indices[:, 0] == cls))
        n = args.requests * args.batch
        print(f"warm: p50 {np.median(lat)*1e3:.1f}ms  "
              f"p max {max(lat)*1e3:.1f}ms  "
              f"{n/sum(lat):.1f} img/s  top1 {hits/n:.3f} "
              f"(untrained chance {1/args.classes:.3f})")
        stats = svc.stats()
        if "slo" in stats:
            s = stats["slo"]
            print(f"slo: p99 {s['p99_s']*1e3:.1f}ms vs target "
                  f"{s['target_s']*1e3:.1f}ms  burn {s['error_budget_burn']:.2f}  "
                  f"{'READY' if s['healthy'] else 'NOT READY'}")
        print("service stats:", stats)
        if server is not None:
            server.stop()


if __name__ == "__main__":
    main()
