"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e target, per chip):
    peak bf16 compute  197 TFLOP/s
    HBM bandwidth      819 GB/s
    ICI per link       ~50 GB/s

Terms (per training/serving step, per chip — XLA compiles the per-device
SPMD program, so ``cost_analysis`` numbers are already per chip):
    compute    = flops / PEAK_FLOPS
    memory     = bytes_accessed / HBM_BW
    collective = collective_bytes / ICI_BW

``collective_bytes`` is parsed from the post-SPMD HLO: the summed output
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (one pass over the wire per op — a lower bound that
ignores multi-hop ring latency; good enough to rank bottlenecks).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_LINE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind from post-SPMD HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for m in _OP_LINE.finditer(hlo_text):
        shape_part, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(shape_part)
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(cost: dict, coll: dict) -> dict:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.get("total", 0))
    terms = {
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collective_bytes_per_device": cbytes,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byts / HBM_BW,
        "collective_s": cbytes / ICI_BW,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    return terms


def model_flops(cfg, shape, n_active: int) -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for inference forward
    (D = tokens processed globally per step)."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult * n_active * tokens)
