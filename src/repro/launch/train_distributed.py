"""Distributed LM trainer: the pjit production loop at any mesh size.

The same code path drives a 1-device dev box and the 16×16 pod: params are
initialized DIRECTLY into their shardings (no host-side full copy), the step
is jitted with donated buffers, data comes from the shard-aware prefetching
pipeline, and checkpoints round-trip with resume.

  python -m repro.launch.train_distributed --arch llama3.2-1b --smoke \
      --steps 50 --batch 8 --seq 128 --model-parallel 1 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_arch, smoke_variant
from repro.core import sharding as shd
from repro.core.remat import get_policy
from repro.data.pipeline import Prefetcher, host_rng
from repro.launch.mesh import make_local_mesh
from repro.models import frontends, transformer as tf
from repro.optim import AdaFactorW, apply_updates, warmup_cosine


def build_state(cfg, mesh, mode, opt, seed):
    """Init params/opt-state directly into their shardings."""
    params_abs = jax.eval_shape(lambda k: tf.init_params(cfg, k),
                                jax.random.key(seed))
    pspecs = shd.to_named(shd.params_specs(params_abs, mesh, mode), mesh)
    params = jax.jit(lambda k: tf.init_params(cfg, k),
                     out_shardings=pspecs)(jax.random.key(seed))
    opt_abs = jax.eval_shape(opt.init, params_abs)
    ospecs = shd.to_named(shd.params_specs(opt_abs, mesh, mode), mesh)
    opt_state = jax.jit(opt.init, out_shardings=ospecs)(params)
    return params, opt_state, pspecs, ospecs


def make_step(cfg, opt, lr_fn, *, remat="basic", moe_args=None):
    policy = get_policy(remat)

    def train_step(params, opt_state, batch, step):
        def loss_fn(p):
            loss, metrics = tf.lm_loss(cfg, p, batch, remat_policy=policy,
                                       moe_args=moe_args)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params)
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        updates, opt_state = opt.update(grads, opt_state, params,
                                        lr_fn(step))
        params = apply_updates(params, updates)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, loss, metrics

    return train_step


def train(args):
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    mesh = make_local_mesh(model=args.model_parallel)
    opt = AdaFactorW(weight_decay=0.0025)
    lr_fn = warmup_cosine(args.lr, args.lr / 100,
                          max(1, args.steps // 10), args.steps)
    moe_args = {"dispatch": "dense"} if args.smoke else None

    with mesh:
        params, opt_state, pspecs, ospecs = build_state(
            cfg, mesh, args.sharding, opt, args.seed)

        start = 0
        if args.ckpt_dir and (latest := ckpt.latest_step(args.ckpt_dir)):
            like = jax.eval_shape(lambda: (params, opt_state))
            params, opt_state = ckpt.restore(args.ckpt_dir, latest, like,
                                             shardings=(pspecs, ospecs))
            start = latest
            print(f"resumed from step {start}")

        step_fn = jax.jit(make_step(cfg, opt, lr_fn, remat=args.remat,
                                    moe_args=moe_args),
                          donate_argnums=(0, 1))

        def make_batch(step):
            rng = host_rng(args.seed, 0, step)
            b = frontends.synthetic_inputs(cfg, args.batch, args.seq, rng)
            return jax.tree.map(jnp.asarray, b)

        stop = getattr(args, "stop_after", None) or args.steps
        stream = Prefetcher(make_batch, depth=2, start=start)
        t0, losses = time.time(), []
        for i in range(start, min(args.steps, stop)):
            batch = next(stream)
            params, opt_state, loss, metrics = step_fn(
                params, opt_state, batch, jnp.asarray(i))
            losses.append(float(loss))
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss {float(loss):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"{(time.time()-t0)/max(1, i-start+1):.2f}s/step")
            if args.ckpt_dir and args.ckpt_every and \
                    (i + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, i + 1, (params, opt_state))
        stream.close()
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, min(args.steps, stop),
                      (params, opt_state))
        return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sharding", default="basic_ws",
                    choices=["basic_ws", "tp", "replicated"])
    ap.add_argument("--remat", default="basic")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--stop-after", type=int, default=None,
                    help="halt early but keep the --steps LR horizon")
    args = ap.parse_args()
    train(args)


if __name__ == "__main__":
    main()
