"""Distributed trainer: the pjit production loop at any mesh size.

The same code path drives a 1-device dev box and the 16×16 pod: params are
initialized DIRECTLY into their shardings (no host-side full copy), the step
is jitted with donated buffers, data comes from the shard-aware prefetching
pipeline, and checkpoints round-trip with resume.

Two objectives share the loop (``--objective`` defaults to ``auto``: picked
by arch family):

  lm           — next-token loss on a single transformer (LM archs)
  contrastive  — the paper's dual-encoder objective: Algorithm-1 GradAccum
                 (``--num-micro``) over the GLOBAL batch, with the
                 cross-shard global-batch loss (``--loss allgather`` or
                 ``--loss chunked``, core/distributed_loss.py) so the
                 contrastive batch does NOT shrink with the data-parallel
                 degree; per-tower remat via ``--remat-image`` /
                 ``--remat-text`` (DESIGN.md §7). Images are RAW pixels
                 through the patchify frontend (DESIGN.md §8).

Both objectives take ``--precision {f32,bf16,bf16_pure}`` (models.precision
policy; fp32 norms/projections/logits stay on under bf16) and ``--attn
{naive,chunked,pallas,auto}`` (models.attention backend registry; 'pallas'
runs the kernels/flash_attention fwd+bwd kernels).

The contrastive input side runs on the multi-host sharded data subsystem
(DESIGN.md §9): versioned tokenizer artifact (``--tokenizer v1``),
per-data-shard block layout assembled with
``jax.make_array_from_process_local_data``, optional ``--augment on``, and
loader state checkpointed alongside params so resume replays the exact
batch sequence.

  python -m repro.launch.train_distributed --arch llama3.2-1b --smoke \\
      --steps 50 --batch 8 --seq 128 --model-parallel 1 --ckpt-dir /tmp/ck

  python -m repro.launch.train_distributed --arch basic-s --smoke \\
      --steps 20 --batch 32 --num-micro 2 --loss chunked

``--memstats`` prints the compiled per-step memory/FLOPs report
(launch/memstats.py) before training starts.

Fault tolerance (DESIGN.md §10): checkpoints are written ASYNCHRONOUSLY
(``checkpoint.AsyncCheckpointManager`` — the step only pays for the host
snapshot; ``--ckpt-sync`` restores the blocking path), carry per-leaf
sha256 integrity records, and are retained per ``--ckpt-keep`` /
``--ckpt-keep-every``. ``--resume auto`` restores params/opt-state/loader
input state from the newest checkpoint that VERIFIES — torn or corrupt
step dirs are skipped, stale ``.tmp_ckpt_*`` dirs GC'd. SIGTERM (the
cluster preemption signal) triggers a final sync checkpoint after the
in-flight step, and persistent async-write failures degrade the run to
sync checkpointing after capped-backoff retries.

Telemetry (DESIGN.md §11): with ``--run-dir`` (default: ``--ckpt-dir``)
the loop streams one schema-versioned JSONL record per step to
``<run-dir>/runlog.jsonl`` — loss, grad-norm, examples/sec, and the
data-wait / device-step / ckpt-stall breakdown — plus checkpoint /
degrade / resume marker records, and exports a Chrome ``trace_event``
JSON (``trace.json``, Perfetto-viewable, per-host pid lanes) on exit.
``--log-every N`` paces the human stdout line, ``--quiet`` silences it;
summarize a run with ``python -m repro.obs.report <run-dir>/runlog.jsonl``.

Health (DESIGN.md §14): ``--health`` arms the anomaly detector suite
(non-finite loss/grad, grad/loss spikes via windowed MAD z-score, loss
plateau, data-wait stall, per-host straggler skew) — anomalies land in
the runlog, as trace instants, and as flight-recorder dumps under
``<run-dir>/flight/`` — and switches the jitted step to non-finite-grad
skipping (the poisoned update is dropped ON DEVICE; finite steps are
bit-exact with the unguarded path). ``--metrics-port P`` serves live
Prometheus ``/metrics``, ``/healthz`` and ``/snapshot.json`` on
127.0.0.1:P for the whole run (0 picks an ephemeral port, written to
``<run-dir>/metrics_port``).
"""
from __future__ import annotations

import argparse
import math
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro import obs
from repro.obs import health as obs_health
from repro.obs import trace as obs_trace
from repro.configs import get_arch, smoke_variant
from repro.core import sharding as shd
from repro.core.remat import get_policy, list_policies
from repro.data.pipeline import Prefetcher, host_rng
from repro.launch.mesh import make_local_mesh
from repro.models import frontends, transformer as tf
from repro.optim import AdaFactorW, apply_updates, warmup_cosine


def build_state(init_fn, mesh, mode, opt, seed):
    """Init params/opt-state directly into their shardings.

    init_fn(key) -> params pytree (LM or dual-encoder). Returns
    (params, opt_state, param shardings, opt-state shardings)."""
    params_abs = jax.eval_shape(init_fn, jax.random.key(seed))
    pspecs = shd.to_named(shd.params_specs(params_abs, mesh, mode), mesh)
    params = jax.jit(init_fn, out_shardings=pspecs)(jax.random.key(seed))
    opt_abs = jax.eval_shape(opt.init, params_abs)
    ospecs = shd.to_named(shd.params_specs(opt_abs, mesh, mode), mesh)
    opt_state = jax.jit(opt.init, out_shardings=ospecs)(params)
    return params, opt_state, pspecs, ospecs


def make_step(cfg, opt, lr_fn, *, remat="basic", moe_args=None,
              precision="f32", skip_nonfinite=False):
    """LM train step: next-token loss + AdaFactorW update, jit-ready.
    ``precision``: models.precision policy name (historical default f32).

    ``skip_nonfinite=True`` arms the in-jit step guard (DESIGN.md §14.2):
    a non-finite loss or grad norm keeps the INCOMING params/opt-state
    via an elementwise ``jnp.where`` select — the poisoned update never
    lands, no host round-trip, donation-safe — and ``metrics`` gains a
    0/1 ``skipped`` flag. Finite steps take the identical update values,
    so guarded training is bit-exact with unguarded training."""
    policy = get_policy(remat)

    def train_step(params, opt_state, batch, step):
        def loss_fn(p):
            loss, metrics = tf.lm_loss(cfg, p, batch, remat_policy=policy,
                                       precision=precision,
                                       moe_args=moe_args)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params)
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        updates, new_opt = opt.update(grads, opt_state, params,
                                      lr_fn(step))
        new_params = apply_updates(params, updates)
        metrics = dict(metrics, grad_norm=gnorm)
        if skip_nonfinite:
            ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
            new_params = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_params, params)
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_opt, opt_state)
            metrics["skipped"] = (~ok).astype(jnp.int32)
        return new_params, new_opt, loss, metrics

    return train_step


def _make_manager(args, registry=None):
    """The run's AsyncCheckpointManager (None without --ckpt-dir):
    ``--ckpt-sync`` degrades to the blocking path, ``--ckpt-keep`` /
    ``--ckpt-keep-every`` set the retention policy (DESIGN.md §10.3).
    ``registry``: the run's obs.Registry, so checkpoint counters and the
    write-latency histogram land in the same snapshot as everything
    else."""
    if not args.ckpt_dir:
        return None
    return ckpt.AsyncCheckpointManager(
        args.ckpt_dir,
        sync=bool(getattr(args, "ckpt_sync", False)),
        keep_last=int(getattr(args, "ckpt_keep", 0) or 0),
        keep_every=int(getattr(args, "ckpt_keep_every", 0) or 0),
        registry=registry)


def _make_obs(args, resumed_from):
    """The run's telemetry bundle (DESIGN.md §11): a metrics Registry
    (always — subsystem counters are cheap), plus a span Tracer and a
    schema-versioned RunLogger when the run has a directory to stream
    into (``--run-dir``, defaulting to ``--ckpt-dir``). A resumed run
    APPENDS to the existing runlog with a ``resumed_from`` marker record
    instead of interleaving a second run_start header."""
    run_dir = getattr(args, "run_dir", None) or args.ckpt_dir
    registry = obs.Registry()
    tracer = runlog = None
    if run_dir:
        os.makedirs(run_dir, exist_ok=True)
        tracer = obs.Tracer()
        meta = {"arch": getattr(args, "arch", None),
                "objective": getattr(args, "objective", "auto"),
                "batch": getattr(args, "batch", None),
                "steps": getattr(args, "steps", None),
                "seed": getattr(args, "seed", None)}
        runlog = obs.RunLogger(os.path.join(run_dir, "runlog.jsonl"),
                               meta=meta,
                               resumed_from=resumed_from or None)
    return registry, tracer, runlog, run_dir


def _make_health(args, registry, tracer, runlog, run_dir):
    """The run's active-monitoring pair (DESIGN.md §14): a
    ``HealthMonitor`` when ``--health`` is set (default detector suite +
    flight recorder into the run dir) and a started ``MetricsServer``
    when ``--metrics-port`` is given (0 = ephemeral; the bound port is
    written to ``<run_dir>/metrics_port``). Either can be on without the
    other; ``/healthz`` reports the monitor's status when both are."""
    monitor = server = None
    if getattr(args, "health", False):
        monitor = obs.HealthMonitor(registry=registry, tracer=tracer,
                                    runlog=runlog, run_dir=run_dir)
    port = getattr(args, "metrics_port", None)
    if port is not None:
        server = obs.MetricsServer(
            registry, health=monitor.status if monitor else None,
            port=int(port), run_dir=run_dir).start()
        if not getattr(args, "quiet", False):
            print(f"obs: serving /metrics /healthz /snapshot.json on "
                  f"{server.url}")
    return monitor, server


def _run_loop(args, step_fn, params, opt_state, make_batch, start, *,
              step_takes_index, ckpt_meta_fn=None, registry=None,
              tracer=None, runlog=None, run_dir=None, monitor=None,
              server=None):
    """Shared prefetch/step/log/checkpoint loop; returns per-step losses.
    ``ckpt_meta_fn(next_step) -> dict``: optional user-meta (e.g. resumable
    loader input state) written into every checkpoint step dir.

    Telemetry (DESIGN.md §11): every step appends one schema-versioned
    JSONL record to ``runlog`` — loss, grad-norm, examples/sec, and the
    data-wait / device-step / ckpt-stall time breakdown — while stdout
    only gets the human line every ``--log-every`` steps (``--quiet``
    silences it entirely). ``tracer`` records the same phases as spans;
    the Chrome trace JSON is exported to ``<run_dir>/trace.json`` when
    the loop ends. All of it is host-side work OUTSIDE the jitted step
    (the ``benchmarks/obs_bench.py`` overhead gate pins it ≤1.05× bare).

    Health (DESIGN.md §14): with a ``monitor`` every step's host-side
    floats feed the anomaly detectors (anomaly runlog records, trace
    instants, ``health/*`` counters, flight-recorder dumps); a ``server``
    keeps ``/metrics`` + ``/healthz`` live for the whole run and is shut
    down on exit. The module-level step fault hook (obs/health.py) is
    applied to every batch right before the device step — the chaos seam
    the NaN-injection acceptance test drives.

    Checkpoints go through the async manager (serialize + rename off the
    step path; DESIGN.md §10). SIGTERM — the preemption signal — is caught:
    the loop finishes the step in flight, writes a final SYNC checkpoint,
    and returns early, so a preempted run resumes from its very last step.
    A persistent async-write failure (after the manager's capped-backoff
    retries) degrades the run to synchronous checkpointing rather than
    training on without durability."""
    stop = getattr(args, "stop_after", None) or args.steps
    stream = Prefetcher(make_batch, depth=2, start=start)
    t0, losses = time.time(), []
    quiet = bool(getattr(args, "quiet", False))
    manager = _make_manager(args, registry)
    preempted = threading.Event()
    prev_handler = None
    if threading.current_thread() is threading.main_thread():
        prev_handler = signal.signal(
            signal.SIGTERM, lambda signum, frame: preempted.set())
    preempt_after = getattr(args, "preempt_after", None)

    def save(step, *, final=False, event="save"):
        """Checkpoint + degrade-on-failure; returns the loop stall in
        seconds (the runlog/step record's ``ckpt_stall_s`` share)."""
        meta = ckpt_meta_fn(step) if ckpt_meta_fn else None
        tree = (params, opt_state)
        t_save = time.perf_counter()
        try:
            if final:
                manager.save_sync(step, tree, meta=meta)
            else:
                manager.save(step, tree, meta=meta)
        except ckpt.CheckpointError as e:
            # a previous async write died after retries — don't keep
            # training without durability: degrade to blocking saves and
            # re-write this step synchronously
            print(f"ckpt: async write failed ({e}); degrading to sync")
            manager.degrade_to_sync()
            if runlog:
                runlog.log("checkpoint", step=step,
                           event="degrade_to_sync", error=str(e))
            manager.save_sync(step, tree, meta=meta)
        stall = time.perf_counter() - t_save
        if runlog:
            runlog.log("checkpoint", step=step, event=event,
                       sync=bool(final or manager.sync), stall_s=stall)
        return stall

    final_saved = False
    try:
        for i in range(start, min(args.steps, stop)):
            t_iter = time.perf_counter()
            with obs_trace.span(tracer, "data_wait", step=i):
                batch = next(stream)
            batch = obs_health.apply_step_fault_hook(i, batch)
            t_data = time.perf_counter()
            with obs_trace.span(tracer, "device_step", step=i):
                if step_takes_index:
                    params, opt_state, loss, metrics = step_fn(
                        params, opt_state, batch, jnp.asarray(i))
                else:
                    params, opt_state, loss, metrics = step_fn(
                        params, opt_state, batch)
                loss_f = float(loss)   # blocks until the device step ends
            t_device = time.perf_counter()
            losses.append(loss_f)
            ckpt_stall, breaking = 0.0, False
            if preempt_after is not None and i - start + 1 == preempt_after:
                # simulated-preemption hook: deliver a REAL SIGTERM to
                # ourselves so tests exercise the exact signal path
                os.kill(os.getpid(), signal.SIGTERM)
            if preempted.is_set():
                if args.ckpt_dir:
                    print(f"SIGTERM: preemption checkpoint at step {i + 1}")
                    with obs_trace.span(tracer, "ckpt_stall", step=i):
                        ckpt_stall += save(i + 1, final=True,
                                           event="preempt_save")
                final_saved = breaking = True
            elif args.ckpt_dir and args.ckpt_every and \
                    (i + 1) % args.ckpt_every == 0:
                with obs_trace.span(tracer, "ckpt_stall", step=i):
                    ckpt_stall += save(i + 1)
            step_s = time.perf_counter() - t_iter
            gnorm_f = (float(metrics["grad_norm"])
                       if metrics.get("grad_norm") is not None else None)
            skipped = bool(float(metrics.get("skipped", 0)))
            step_rec = None
            if runlog:
                extra = {} if gnorm_f is None else {"grad_norm": gnorm_f}
                if skipped:
                    extra["skipped"] = 1
                step_rec = runlog.log_step(
                    i, loss=loss_f, data_wait_s=t_data - t_iter,
                    device_step_s=t_device - t_data,
                    ckpt_stall_s=ckpt_stall, step_s=step_s,
                    examples_per_sec=args.batch / step_s, **extra)
            if monitor is not None:
                monitor.observe_step(obs.StepSample(
                    step=i, loss=loss_f,
                    grad_norm=math.nan if gnorm_f is None else gnorm_f,
                    data_wait_s=t_data - t_iter,
                    device_step_s=t_device - t_data, step_s=step_s,
                    skipped=skipped), record=step_rec)
            if not quiet and (i % args.log_every == 0
                              or i == args.steps - 1):
                gnorm = metrics.get("grad_norm")
                gtxt = f"gnorm {float(gnorm):.2f} " \
                    if gnorm is not None else ""
                print(f"step {i:5d} loss {loss_f:.4f} {gtxt}"
                      f"{(time.time()-t0)/max(1, i-start+1):.2f}s/step")
            if breaking:
                break
    finally:
        stream.close()
        if prev_handler is not None:
            signal.signal(signal.SIGTERM, prev_handler)
    if args.ckpt_dir and not final_saved:
        with obs_trace.span(tracer, "ckpt_stall"):
            save(min(args.steps, stop), final=True, event="final_save")
    if manager is not None:
        manager.close()
    trace_path = None
    if tracer is not None and run_dir:
        trace_path = tracer.export(os.path.join(run_dir, "trace.json"))
    if runlog:
        if trace_path:
            # dropped > 0 means the exported timeline is truncated at the
            # old end — report.py surfaces it as a warning
            runlog.log("event", event="trace_export", path=trace_path,
                       dropped=tracer.dropped)
        if registry is not None:
            runlog.log("metrics", **registry.snapshot())
        runlog.close()
    if trace_path and not quiet:
        print(f"obs: trace -> {trace_path} (open in Perfetto)")
    if server is not None:
        server.stop()
    return losses


def _restore(args, params, opt_state, pspecs, ospecs):
    """Resume per ``--resume``: ``auto`` (default) restores from
    ``latest_verified_step`` — torn/corrupt step dirs are skipped and
    stale ``.tmp_ckpt_*`` dirs GC'd, so a crash mid-save can never wedge
    the relaunch; ``latest`` trusts the newest step dir (the historical
    behavior); ``off`` starts fresh."""
    start = 0
    resume = getattr(args, "resume", None) or "auto"
    if args.ckpt_dir and resume != "off":
        latest = (ckpt.latest_verified_step(args.ckpt_dir)
                  if resume == "auto" else ckpt.latest_step(args.ckpt_dir))
        if latest:
            like = jax.eval_shape(lambda: (params, opt_state))
            params, opt_state = ckpt.restore(args.ckpt_dir, latest, like,
                                             shardings=(pspecs, ospecs))
            start = latest
            print(f"resumed from step {start} (--resume {resume})")
    return params, opt_state, start


def train_lm(args):
    """LM objective at any mesh size; returns the per-step loss list."""
    import dataclasses
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if getattr(args, "attn", None):
        cfg = dataclasses.replace(cfg, attn_impl=args.attn)
    mesh = make_local_mesh(model=args.model_parallel)
    opt = AdaFactorW(weight_decay=0.0025)
    lr_fn = warmup_cosine(args.lr, args.lr / 100,
                          max(1, args.steps // 10), args.steps)
    moe_args = {"dispatch": "dense"} if args.smoke else None
    precision = getattr(args, "precision", None) or "f32"

    with mesh:
        params, opt_state, pspecs, ospecs = build_state(
            lambda k: tf.init_params(cfg, k), mesh, args.sharding, opt,
            args.seed)
        params, opt_state, start = _restore(args, params, opt_state,
                                            pspecs, ospecs)
        registry, tracer, runlog, run_dir = _make_obs(args, start)
        monitor, server = _make_health(args, registry, tracer, runlog,
                                       run_dir)
        step_fn = jax.jit(make_step(cfg, opt, lr_fn, remat=args.remat,
                                    moe_args=moe_args, precision=precision,
                                    skip_nonfinite=bool(
                                        getattr(args, "health", False))),
                          donate_argnums=(0, 1))

        def make_batch(step):
            rng = host_rng(args.seed, 0, step)
            b = frontends.synthetic_inputs(cfg, args.batch, args.seq, rng)
            return jax.tree.map(jnp.asarray, b)

        return _run_loop(args, step_fn, params, opt_state, make_batch, start,
                         step_takes_index=True, registry=registry,
                         tracer=tracer, runlog=runlog, run_dir=run_dir,
                         monitor=monitor, server=server)


def train_contrastive(args):
    """Paper objective: GradAccum × data-parallel × tensor-parallel with the
    cross-shard global-batch contrastive loss, one jit. Returns the
    per-step loss list.

    Input side (DESIGN.md §9): the versioned tokenizer artifact
    (``artifacts/tokenizer_v1.json`` — NOT retrained per run, so text-tower
    checkpoints stay portable), a ``data.sharded.ShardedLoader`` laid out
    with one host block per data shard (global batches assemble to
    globally-sharded jax.Arrays via ``make_array_from_process_local_data``),
    optional ``--augment`` train-time augmentation, and resumable loader
    state persisted as checkpoint user-meta — a resumed run validates the
    tokenizer hash/layout and replays the exact batch sequence."""
    from repro.configs import smoke_dual_variant
    from repro.data import world_for_tower
    from repro.data.sharded import (HostLayout, ShardedLoader,
                                    default_augmentations, device_put_global,
                                    load_tokenizer)
    from repro.data.sharded.loader import LoaderState
    from repro.launch import steps as st
    from repro.models import dual_encoder as de

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_dual_variant(cfg)
    mesh = make_local_mesh(model=args.model_parallel)
    num_micro = getattr(args, "num_micro", 2)
    loss = getattr(args, "loss", "chunked")

    data_size = int(np.prod([mesh.shape[a] for a in shd.data_axes(mesh)
                             if a in mesh.shape]))
    if args.batch % num_micro:
        raise SystemExit(f"--batch {args.batch} must be divisible by "
                         f"--num-micro {num_micro}")
    if loss in ("allgather", "chunked"):
        if args.batch % data_size:
            raise SystemExit(
                f"--loss {loss}: --batch {args.batch} must be divisible by "
                f"the data extent {data_size} (one equal block per shard)")
        if (args.batch // data_size) % 8:
            raise SystemExit(
                f"--loss {loss}: per-shard batch {args.batch}/{data_size} "
                f"must be a multiple of 8 (fused-kernel tiling; see "
                f"kernels.contrastive_loss.ops.pick_blocks)")

    step_core, opt = st.make_contrastive_step(
        cfg, num_micro=num_micro, remat=args.remat,
        remat_image=getattr(args, "remat_image", None),
        remat_text=getattr(args, "remat_text", None),
        precision=getattr(args, "precision", None) or "bf16",
        attn=getattr(args, "attn", None),
        lr=args.lr, mesh=mesh, loss=loss,
        skip_nonfinite=bool(getattr(args, "health", False)))

    with mesh:
        params, opt_state, pspecs, ospecs = build_state(
            lambda k: de.init_params(cfg, k), mesh, args.sharding, opt,
            args.seed)
        params, opt_state, start = _restore(args, params, opt_state,
                                            pspecs, ospecs)
        # pin the state's output shardings to its input shardings: the
        # donated loop then reuses ONE executable (and the --memstats AOT
        # compile below is the same one the loop runs)
        step_fn = jax.jit(step_core, donate_argnums=(0, 1),
                          out_shardings=(pspecs, ospecs, None, None))

        world_rng = np.random.default_rng(args.seed)
        world = world_for_tower(world_rng, cfg.image_tower, n_classes=16,
                                noise=0.2)
        tok = load_tokenizer(getattr(args, "tokenizer", None) or "v1")
        augment = default_augmentations() \
            if getattr(args, "augment", "off") == "on" else ()
        if jax.process_count() > 1:
            # the loader's per-host blocks (HostLayout, local_batch_at) are
            # multi-process-ready, but this trainer still materializes the
            # FULL global batch per process — fail loudly rather than feed
            # make_array_from_process_local_data global-shaped data
            # (ROADMAP: "True multi-process input")
            raise NotImplementedError(
                "train_contrastive simulates multi-host input inside one "
                "process; wiring jax.process_index() into HostLayout is a "
                "ROADMAP item")
        registry, tracer, runlog, run_dir = _make_obs(args, start)
        monitor, server = _make_health(args, registry, tracer, runlog,
                                       run_dir)
        if tracer is not None:
            for h in range(data_size):
                tracer.set_process_name(1 + h, f"host {h}")
        # one host block per data shard: block h of the global batch lands
        # on data shard h, the §5.1 "distributed equally to all cores" layout
        loader = ShardedLoader(world, tok, args.batch,
                               layout=HostLayout(n_hosts=data_size),
                               seed=args.seed, text_len=args.seq,
                               augment=augment, registry=registry,
                               tracer=tracer)
        if start and args.ckpt_dir and \
                (meta := ckpt.load_meta(args.ckpt_dir, start)) \
                and "loader" in meta:
            # validates seed/layout/tokenizer-hash/augment against the
            # checkpointed input state — a retrained tokenizer or changed
            # augmentation policy fails here instead of silently diverging
            loader.restore(LoaderState.from_json(meta["loader"]))

        def make_batch(step):
            return device_put_global(loader.global_batch_at(step), mesh)

        def ckpt_meta_fn(next_step):
            return {"loader": loader.state(step=next_step).to_json()}

        if getattr(args, "memstats", False):
            from repro.launch import memstats
            # AOT-compile once, report, and run the loop on the SAME
            # executable (jit's dispatch cache ignores lower().compile(),
            # so calling step_fn afterwards would compile a second time)
            compiled = step_fn.lower(params, opt_state,
                                     make_batch(start)).compile()
            print(memstats.format_rows([memstats.compiled_stats(
                compiled,
                label=f"{args.arch} B={args.batch} micro={num_micro} "
                      f"loss={loss} remat={args.remat}")]))
            step_fn = compiled

        return _run_loop(args, step_fn, params, opt_state, make_batch, start,
                         step_takes_index=False, ckpt_meta_fn=ckpt_meta_fn,
                         registry=registry, tracer=tracer, runlog=runlog,
                         run_dir=run_dir, monitor=monitor, server=server)


def train(args):
    """Dispatch on objective (``auto``: contrastive for dual-encoder archs,
    i.e. configs without a ``family`` attribute; lm otherwise)."""
    objective = getattr(args, "objective", "auto")
    if objective == "auto":
        objective = ("lm" if hasattr(get_arch(args.arch), "family")
                     else "contrastive")
    if objective == "lm":
        return train_lm(args)
    return train_contrastive(args)


def main():
    ap = argparse.ArgumentParser(
        description=__doc__.split("\n\n")[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", required=True,
                    help="arch name from repro.configs (LM archs train the "
                         "lm objective; basic-{s,m,l} train contrastive)")
    ap.add_argument("--objective", default="auto",
                    choices=["auto", "lm", "contrastive"])
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-sized variant of the arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8,
                    help="GLOBAL batch (split over the data axes)")
    ap.add_argument("--seq", type=int, default=128,
                    help="sequence length (lm) / caption length "
                         "(contrastive)")
    ap.add_argument("--lr", type=float, default=1e-3,
                    help="peak LR (lm: warmup-cosine schedule; "
                         "contrastive: constant)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sharding", default="basic_ws",
                    choices=["basic_ws", "tp", "replicated"])
    remat_names = list_policies() + ["off"]   # 'off': no checkpoint wrapping
    ap.add_argument("--remat", default="basic", choices=remat_names,
                    help="jax.checkpoint policy (core.remat registry; "
                         "'off' applies no checkpoint wrapping at all)")
    ap.add_argument("--remat-image", default=None, choices=remat_names,
                    help="override --remat for the image tower "
                         "(contrastive only)")
    ap.add_argument("--remat-text", default=None, choices=remat_names,
                    help="override --remat for the text tower "
                         "(contrastive only)")
    ap.add_argument("--precision", default=None,
                    choices=["f32", "bf16", "bf16_pure"],
                    help="mixed-precision policy (models.precision; "
                         "default: f32 for lm, bf16 for contrastive — the "
                         "historical dtypes)")
    ap.add_argument("--attn", default=None,
                    choices=["naive", "chunked", "pallas", "auto"],
                    help="attention backend override for every tower "
                         "(models.attention registry)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--num-micro", type=int, default=2,
                    help="GradAccum microbatches (contrastive only)")
    ap.add_argument("--loss", default="chunked",
                    choices=["local", "fused", "allgather", "chunked"],
                    help="contrastive loss impl (core.distributed_loss; "
                         "'local'/'fused' compute on the logical global "
                         "batch without explicit cross-shard collectives)")
    ap.add_argument("--memstats", action="store_true",
                    help="print the compiled per-step memory/FLOPs report "
                         "before training (launch/memstats.py)")
    ap.add_argument("--augment", default="off", choices=["on", "off"],
                    help="train-time image augmentation (crop jitter + "
                         "flip + channel noise; data.sharded.augment, "
                         "contrastive only)")
    ap.add_argument("--tokenizer", default="v1",
                    help="tokenizer artifact version to load "
                         "(artifacts/tokenizer_<v>.json; contrastive only)")
    ap.add_argument("--log-every", type=int, default=10,
                    help="print a human step line every N steps (the "
                         "runlog gets EVERY step regardless)")
    ap.add_argument("--quiet", action="store_true",
                    help="no per-step stdout lines; telemetry still "
                         "streams to the runlog")
    ap.add_argument("--health", action="store_true",
                    help="active monitoring (DESIGN.md §14): anomaly "
                         "detectors on loss/grad/data-wait (anomaly "
                         "runlog records + flight-recorder dumps into "
                         "the run dir) and in-jit non-finite step "
                         "skipping — a NaN loss/grad keeps the incoming "
                         "params instead of poisoning them")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve live /metrics (Prometheus), /healthz and "
                         "/snapshot.json on 127.0.0.1:PORT for the whole "
                         "run (0 = ephemeral; the bound port is written "
                         "to <run-dir>/metrics_port)")
    ap.add_argument("--run-dir", default=None,
                    help="directory for runlog.jsonl + trace.json "
                         "(default: --ckpt-dir; no files when neither "
                         "is set). DESIGN.md §11")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-sync", action="store_true",
                    help="blocking checkpoint writes (default: async — "
                         "snapshot on the step path, serialize + atomic "
                         "rename on a background thread)")
    ap.add_argument("--ckpt-keep", type=int, default=0,
                    help="retention: keep only the newest K checkpoints "
                         "(0 = keep all)")
    ap.add_argument("--ckpt-keep-every", type=int, default=0,
                    help="retention: additionally keep every Nth step "
                         "forever (0 = none)")
    ap.add_argument("--resume", default="auto",
                    choices=["auto", "latest", "off"],
                    help="auto: resume from the newest checkpoint that "
                         "passes integrity verification (torn/corrupt "
                         "steps skipped, stale tmp dirs GC'd); latest: "
                         "trust the newest step dir; off: start fresh")
    ap.add_argument("--preempt-after", type=int, default=None,
                    help="chaos hook: SIGTERM ourselves after N steps — "
                         "exercises the preemption path (final sync "
                         "checkpoint + clean exit) deterministically")
    ap.add_argument("--stop-after", type=int, default=None,
                    help="halt early but keep the --steps LR horizon")
    args = ap.parse_args()
    train(args)


if __name__ == "__main__":
    main()
