"""Per-step memory + FLOPs accounting for the contrastive training step.

The paper's two scaling limits — accelerator memory and the global
contrastive batch — meet in one table: for each remat policy (and loss
implementation) this module AOT-compiles the full train step and reports
XLA's compiled-memory analysis (argument/output/temp bytes per device,
peak GB) next to the HLO FLOPs estimate and the analytic VMEM working
set of the fused loss kernels (XLA's CPU/host compile cannot see TPU
VMEM, so the kernel-side numbers come from the same footprint model that
picks the block sizes — kernels.contrastive_loss.ops). The measured
remat policy table in DESIGN.md §7.4 is generated this way.

CLI (the device count is simulated; run BEFORE any other jax init):

  PYTHONPATH=src python -m repro.launch.memstats --arch basic-s --smoke \\
      --devices 8 --model-parallel 2 --batch 64 --num-micro 2 \\
      --remat basic,none,full,dots --loss chunked

Library: ``step_stats(jitted_fn, example_inputs)`` for one compiled
report row (also surfaced as ``train_distributed --memstats``);
``contrastive_report(...)`` for the policy sweep; ``format_rows`` to
render. All rows are plain dicts, JSON-ready (``--json PATH``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _mem_dict(mem) -> dict:
    """memory_analysis() object -> plain per-device byte counts."""
    arg = int(getattr(mem, "argument_size_in_bytes", 0))
    out = int(getattr(mem, "output_size_in_bytes", 0))
    tmp = int(getattr(mem, "temp_size_in_bytes", 0))
    alias = int(getattr(mem, "alias_size_in_bytes", 0))
    return {
        "argument_bytes_per_device": arg,
        "output_bytes_per_device": out,
        "temp_bytes_per_device": tmp,
        "alias_bytes_per_device": alias,
        "peak_gb_per_device": round((arg + tmp) / 2**30, 4),
    }


def compiled_stats(compiled, *, label: str = "") -> dict:
    """Accounting row for an already-AOT-compiled executable (the result
    of ``jax.jit(fn).lower(...).compile()``): compiled per-device memory
    (HBM), HLO FLOPs/bytes-accessed estimates, and cross-device
    collective traffic. No execution happens."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):      # older jax: one dict per program
        cost = cost[0] if cost else {}
    row = {"label": label, "memory": _mem_dict(compiled.memory_analysis()),
           "flops_per_device": float(cost.get("flops", 0.0)),
           "bytes_accessed_per_device": float(cost.get("bytes accessed",
                                                       0.0))}
    try:
        from repro.launch import roofline as rf
        row["collectives"] = rf.collective_bytes(compiled.as_text())
    except Exception:  # noqa: BLE001 — HLO text dump is best-effort
        row["collectives"] = {}
    return row


def step_stats(jitted_fn, example_inputs, *, label: str = "") -> dict:
    """Compile ``jitted_fn`` on ``example_inputs`` (a tuple of concrete or
    abstract positional args) and return its ``compiled_stats`` row. The
    compiled executable is discarded — callers that will also RUN the step
    should lower/compile themselves and pass the result to
    ``compiled_stats`` (AOT compilation does not populate jit's dispatch
    cache; see train_distributed --memstats)."""
    import jax

    if not hasattr(jitted_fn, "lower"):
        jitted_fn = jax.jit(jitted_fn)
    compiled = jitted_fn.lower(*example_inputs).compile()
    return compiled_stats(compiled, label=label)


def loss_kernel_vmem(b_local: int, d: int, itemsize: int = 4) -> dict:
    """Analytic VMEM working set of the fused contrastive-loss kernels at
    per-shard batch ``b_local`` and embed dim ``d`` (bytes): the picked
    (bm, bn) block pair, the per-grid-step block bytes, and whether the
    single-pass backward's resident dY carrier fits compiled VMEM (else
    the legacy two-sweep backward runs — DESIGN.md §2.3/§2.4)."""
    from repro.kernels.contrastive_loss import ops
    bm, bn = ops.pick_blocks(b_local, d, itemsize)
    return {
        "bm": bm, "bn": bn,
        "block_bytes": ops.block_bytes(bm, bn, d, itemsize),
        "bwd_dy_carrier_bytes": b_local * d * 4,
        "bwd_single_pass_fits": ops.bwd_fits_fused(b_local, d, bm, bn,
                                                   itemsize),
    }


def contrastive_report(arch: str, *, smoke: bool, mesh, sharding: str,
                       batch: int, num_micro: int, seq: int,
                       remats, loss: str = "chunked",
                       precision: str = "bf16",
                       attn=None) -> list[dict]:
    """One accounting row per remat policy for the full contrastive train
    step (GradAccum × data-parallel × tensor-parallel × global-batch
    loss) compiled on ``mesh``. remats: iterable of core.remat registry
    names; ``precision``/``attn`` select the models.precision policy and
    attention backend the step compiles with. Abstract inputs only —
    nothing is allocated or run."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch, smoke_dual_variant
    from repro.core import sharding as shd
    from repro.launch import steps as st
    from repro.models import dual_encoder as de

    cfg = get_arch(arch)
    if smoke:
        cfg = smoke_dual_variant(cfg)

    params_abs = jax.eval_shape(lambda k: de.init_params(cfg, k),
                                jax.random.key(0))
    pspecs = shd.to_named(shd.params_specs(params_abs, mesh, sharding), mesh)
    SDS = jax.ShapeDtypeStruct
    it = cfg.image_tower
    batch_abs = {
        "images": {"image":
                   SDS((batch, it.image_size, it.image_size, it.channels),
                       jnp.float32)},
        "texts": {"tokens": SDS((batch, seq), jnp.int32)},
    }
    bspecs = shd.to_named(shd.batch_specs(batch_abs, mesh), mesh)

    data_size = 1
    for a in shd.data_axes(mesh):
        if a in mesh.shape:
            data_size *= mesh.shape[a]

    rows = []
    for remat in remats:
        step, opt = st.make_contrastive_step(cfg, num_micro=num_micro,
                                             remat=remat, mesh=mesh,
                                             precision=precision, attn=attn,
                                             loss=loss)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        ospecs = shd.to_named(shd.params_specs(opt_abs, mesh, sharding),
                              mesh)
        with mesh:
            row = step_stats(
                jax.jit(step, in_shardings=(pspecs, ospecs, bspecs)),
                (params_abs, opt_abs, batch_abs),
                label=f"{arch} B={batch} micro={num_micro} loss={loss} "
                      f"remat={remat}")
        row["remat"] = remat
        # chunked streams (B_local, B_local) chunks; allgather/local/fused
        # run the kernel on the FULL gathered batch on every shard.
        # Embeddings are fp32 regardless of tower dtype (the dual encoder
        # casts at the projection), hence itemsize 4.
        kernel_b = (max(8, batch // data_size) if loss == "chunked"
                    else batch)
        row["loss_kernel_vmem"] = loss_kernel_vmem(kernel_b, cfg.embed_dim)
        rows.append(row)
    return rows


def format_rows(rows) -> str:
    """Render accounting rows as an aligned text table."""
    head = (f"{'label':<56} {'peak GB/dev':>11} {'temp MB':>9} "
            f"{'args MB':>9} {'GFLOPs/dev':>11} {'coll MB':>9}")
    lines = [head, "-" * len(head)]
    for r in rows:
        m = r["memory"]
        coll = r.get("collectives", {}).get("total", 0) / 2**20
        lines.append(
            f"{r['label']:<56} {m['peak_gb_per_device']:>11.4f} "
            f"{m['temp_bytes_per_device']/2**20:>9.1f} "
            f"{m['argument_bytes_per_device']/2**20:>9.1f} "
            f"{r['flops_per_device']/1e9:>11.3f} {coll:>9.1f}")
        kv = r.get("loss_kernel_vmem")
        if kv:
            lines.append(
                f"    loss kernel VMEM: blocks=({kv['bm']},{kv['bn']}) "
                f"block={kv['block_bytes']/2**10:.0f}KiB "
                f"dY-carrier={kv['bwd_dy_carrier_bytes']/2**10:.0f}KiB "
                f"single-pass-bwd={'yes' if kv['bwd_single_pass_fits'] else 'no (legacy fallback)'}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="compiled per-step memory/FLOPs accounting for the "
                    "contrastive global-batch train step")
    ap.add_argument("--arch", default="basic-s")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=None,
                    help="simulate N host-platform devices (must be the "
                         "first jax init in the process)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--sharding", default="basic_ws",
                    choices=["basic_ws", "tp", "replicated"])
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--num-micro", type=int, default=2)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--loss", default="chunked",
                    choices=["local", "fused", "allgather", "chunked"])
    ap.add_argument("--precision", default="bf16",
                    choices=["f32", "bf16", "bf16_pure"],
                    help="models.precision policy the step compiles with")
    ap.add_argument("--attn", default=None,
                    choices=[None, "naive", "chunked", "pallas", "auto"],
                    help="attention backend override for both towers")
    ap.add_argument("--remat", default="basic,none,full,dots",
                    help="comma-separated core.remat policy names")
    ap.add_argument("--json", default=None, help="also write rows to PATH")
    args = ap.parse_args(argv)

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(model=args.model_parallel)
    rows = contrastive_report(
        args.arch, smoke=args.smoke, mesh=mesh, sharding=args.sharding,
        batch=args.batch, num_micro=args.num_micro, seq=args.seq,
        remats=[r.strip() for r in args.remat.split(",") if r.strip()],
        loss=args.loss, precision=args.precision, attn=args.attn)
    print(format_rows(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
