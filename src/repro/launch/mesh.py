"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Tiny mesh over the actually-present devices (tests / examples)."""
    n = jax.device_count()
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
