from repro.serving.engine import Engine  # noqa: F401
