from repro.serving.engine import Engine, sample_tokens  # noqa: F401
from repro.serving.continuous import (  # noqa: F401
    ContinuousEngine,
    FinishedRequest,
)
from repro.serving.embed import (  # noqa: F401
    ClassEmbeddingRegistry,
    MicroBatcher,
    ZeroShotService,
)
