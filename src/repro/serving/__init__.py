from repro.serving.engine import Engine  # noqa: F401
from repro.serving.embed import (  # noqa: F401
    ClassEmbeddingRegistry,
    MicroBatcher,
    ZeroShotService,
)
