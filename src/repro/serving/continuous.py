"""Continuous-batching decode engine (DESIGN.md §12).

The legacy ``Engine`` decodes a FIXED batch in lockstep: every request
prefills together, every slot steps together, and the whole batch only
retires when its slowest member finishes. This engine decouples the two
phases JetStream/MaxText-style around a slot-based cache of capacity
``num_slots``:

  prefill(prompt)      one b=1 compiled forward per prompt length, emitting
                       the first greedy token and a single cache ROW
  insert(row, slot)    splice that row into the packed (num_slots, ...)
                       KV/SSM cache — ONE compiled program regardless of
                       prompt length, so admission never recompiles
  generate_step()      one jitted donated step advancing ALL slots one
                       token via per-slot positions (models.transformer
                       ``decode_step`` with ``pos: (S,)``) — each row is
                       RoPE'd, cache-written, and length-masked at its own
                       decode depth

Host-side per-slot state (request id, position, emitted tokens, EOS)
retires finished slots and immediately refills them from the FIFO
admission queue, so new requests stream in while others keep decoding.

Parity contract (pinned by tests/test_continuous_engine.py): for greedy
decoding, every request's tokens are identical to ``Engine.generate``
run ALONE on that request — prefill is literally the same b=1 program,
and the packed generate step computes each row independently (stale
cache entries past a slot's position weight exactly 0 under the per-slot
mask, so a reused slot can never leak a retired request's context).

Telemetry: a private ``obs.Registry`` (injectable via ``registry=``)
carries ``decode/slot_occupancy`` (gauge + ratio histogram),
``decode/admission_wait_s`` / ``decode/prefill_s`` / ``decode/step_s``
histograms, and ``decode/tokens`` / ``decode/requests`` counters —
``tokens/s`` falls out of ``decode/tokens`` over the run wall clock
(``stats()`` reports it directly).

SLO (DESIGN.md §14.3): ``latency_slo_s`` arms an ``SLOTracker`` on
end-to-end request latency (submit → finish, queue wait included — the
user-visible number): windowed p99 vs the target, error-budget burn,
and a readiness bit under ``decode/slo_*``; ``serve_metrics()`` exposes
the registry + readiness over live HTTP (obs/export.py).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import precision as prec_lib
from repro.models import transformer as tf
from repro.obs import Registry
from repro.obs.metrics import RATIO_BUCKETS
from repro.serving.engine import sample_tokens


@dataclasses.dataclass
class FinishedRequest:
    """A retired request: its id, prompt length, and every generated token
    (EOS included when hit; never padded — pad tokens from the fixed-shape
    step are masked out host-side before they can reach a result)."""
    request_id: int
    prompt_len: int
    tokens: np.ndarray           # (n_generated,) int32, n <= max_new_tokens


@dataclasses.dataclass
class _Slot:
    """Host-side state of one cache row (the device holds only the packed
    KV/SSM rows; everything the scheduler needs lives here)."""
    request_id: int = -1
    active: bool = False
    pos: int = 0                 # next decode position (= prompt_len + n - 1
    #                              when emitting token n, 1-based)
    next_token: int = 0          # last sampled token, the next step's input
    emitted: Optional[list] = None
    max_new: int = 0
    prompt_len: int = 0
    rng: Optional[np.random.Generator] = None
    t_sub: float = 0.0           # submit wall time, for end-to-end SLO


class ContinuousEngine:
    """Slot-based continuous-batching decode engine.

    ``submit()`` enqueues requests; each ``step()`` admits queued requests
    into free slots (prefill → insert), advances every active slot one
    token with a single jitted program, and retires slots whose request
    hit EOS or its token budget — returning those as ``FinishedRequest``
    records. ``run()`` is the drain loop.

    Greedy (``temperature=0``) outputs are bit-identical per request to
    ``Engine.generate`` run alone (the parity suite's contract); sampled
    decoding draws from a PER-REQUEST rng seeded by ``(seed, request_id)``
    so outputs stay reproducible under any arrival order.
    """

    def __init__(self, cfg: ArchConfig, params, *, cache_len: int,
                 num_slots: int, dtype=None, precision=None,
                 attn: Optional[str] = None,
                 moe_args: Optional[dict] = None,
                 eos_id: int = 3, temperature: float = 0.0, seed: int = 0,
                 registry: Optional[Registry] = None,
                 latency_slo_s: Optional[float] = None,
                 slo_objective: float = 0.99, slo_window: int = 256):
        assert cfg.causal, f"{cfg.name} is encoder-only; no decode step"
        assert num_slots >= 1, num_slots
        if attn is not None:
            from repro.models import attention as attn_lib
            if attn != "auto" and attn not in attn_lib.ATTN_BACKENDS:
                raise KeyError(
                    f"unknown attention impl {attn!r}; have "
                    f"{attn_lib.available_backends()} + 'auto'")
            cfg = dataclasses.replace(cfg, attn_impl=attn)
        self.cfg, self.params = cfg, params
        self.cache_len = int(cache_len)
        self.num_slots = int(num_slots)
        self.precision = prec_lib.resolve(precision, dtype or jnp.float32)
        self.moe_args = moe_args or {}
        self.eos_id = int(eos_id)
        self.temperature = float(temperature)
        self.seed = int(seed)

        self._prefill = jax.jit(self._prefill_impl)      # compiled per plen
        self._insert = jax.jit(self._insert_impl,        # ONE compile: row
                               donate_argnums=(0,))      # shape is plen-free
        self._step = jax.jit(self._step_impl, donate_argnums=(1,))

        self._queue: collections.deque = collections.deque()
        self._slots = [_Slot() for _ in range(self.num_slots)]
        self._caches = None                              # built on 1st insert
        self._next_id = 0
        self._finished: List[FinishedRequest] = []
        self._t0 = None

        self.registry = registry if registry is not None else Registry()
        self._m_occ = self.registry.gauge("decode/slot_occupancy")
        self._m_occ_hist = self.registry.histogram(
            "decode/slot_occupancy_ratio", buckets=RATIO_BUCKETS)
        self._m_queue = self.registry.gauge("decode/queue_depth")
        self._m_admit = self.registry.histogram("decode/admission_wait_s")
        self._m_prefill = self.registry.histogram("decode/prefill_s")
        self._m_step = self.registry.histogram("decode/step_s")
        self._m_tokens = self.registry.counter("decode/tokens")
        self._m_requests = self.registry.counter("decode/requests")
        self._m_admitted = self.registry.counter("decode/admissions")
        self.slo = None
        if latency_slo_s is not None:
            from repro.obs import health as obs_health
            self.slo = obs_health.SLOTracker(
                target_s=float(latency_slo_s), objective=slo_objective,
                window=slo_window, registry=self.registry, name="decode")

    # -- compiled bodies ---------------------------------------------------
    def _prefill_impl(self, params, tokens):
        """b=1 prompt forward -> (last-position logits, one cache row)."""
        logits, caches = tf.prefill(self.cfg, params, {"tokens": tokens},
                                    precision=self.precision,
                                    moe_args=self.moe_args,
                                    collect_cache_len=self.cache_len)
        return logits[:, 0, :], caches

    def _insert_impl(self, caches, row, slot):
        """Splice a b=1 prefill row into the packed cache at ``slot``.

        Every cache leaf is stacked (n_periods, batch, ...), so one
        ``dynamic_update_slice_in_dim`` on axis 1 covers KV and SSM leaves
        alike; the row fully overwrites the slot (prefill zero-pads past
        the prompt), so no bytes of the previous tenant survive."""
        return jax.tree.map(
            lambda big, r: jax.lax.dynamic_update_slice_in_dim(
                big, r.astype(big.dtype), slot, axis=1), caches, row)

    def _step_impl(self, params, caches, tokens, pos):
        """Advance all slots one token: per-slot positions end to end."""
        logits, caches = tf.decode_step(self.cfg, params, tokens, pos,
                                        caches, precision=self.precision,
                                        moe_args=self.moe_args)
        return logits[:, 0, :], caches

    # -- admission ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               request_id: Optional[int] = None) -> int:
        """Enqueue one request. ``prompt``: (plen,) int32. Returns its id
        (auto-assigned unless given). Requests are admitted FIFO as slots
        free up; the queue is unbounded (capacity pressure lives in the
        slot array, not here)."""
        prompt = np.asarray(prompt, np.int32)
        assert prompt.ndim == 1 and prompt.size >= 1, prompt.shape
        assert max_new_tokens >= 1, max_new_tokens
        if not (prompt.size + max_new_tokens <= self.cache_len
                or self.cfg.sliding_window is not None):
            raise ValueError(
                f"prompt_len {prompt.size} + max_new_tokens "
                f"{max_new_tokens} exceeds cache_len {self.cache_len}")
        rid = self._next_id if request_id is None else int(request_id)
        self._next_id = max(self._next_id, rid) + 1
        self._queue.append((rid, prompt, int(max_new_tokens), time.time()))
        self._m_queue.set(len(self._queue))
        self._m_requests.inc()
        return rid

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if not s.active]

    def _admit(self) -> None:
        """Fill free slots from the queue: prefill(prompt) -> insert(slot).

        A request whose FIRST token already finishes it (max_new_tokens=1,
        or an immediate EOS) retires here and never occupies a slot."""
        for slot_idx in self._free_slots():
            if not self._queue:
                break
            rid, prompt, max_new, t_sub = self._queue.popleft()
            t0 = time.time()
            self._m_admit.observe(t0 - t_sub)
            logits, row = self._prefill(self.params,
                                        jnp.asarray(prompt[None, :]))
            rng = np.random.default_rng((self.seed, rid))
            tok = int(sample_tokens(logits, self.temperature, rng)[0])
            self._m_tokens.inc()
            self._m_admitted.inc()
            if tok == self.eos_id or max_new == 1:
                self._finished.append(FinishedRequest(
                    request_id=rid, prompt_len=prompt.size,
                    tokens=np.asarray([tok], np.int32)))
                self._m_prefill.observe(time.time() - t0)
                if self.slo is not None:
                    self.slo.observe(time.time() - t_sub)
                continue
            if self._caches is None:
                # size the packed cache off the first real row: same leaf
                # dtypes/shapes as prefill builds (policy-dependent), with
                # the batch axis widened to num_slots
                self._caches = jax.tree.map(
                    lambda r: jnp.zeros(
                        (r.shape[0], self.num_slots, *r.shape[2:]), r.dtype),
                    row)
            self._caches = self._insert(self._caches, row,
                                        jnp.asarray(slot_idx, jnp.int32))
            s = self._slots[slot_idx]
            s.request_id, s.active = rid, True
            s.pos, s.next_token = prompt.size, tok
            s.emitted, s.max_new = [tok], max_new
            s.prompt_len, s.rng = prompt.size, rng
            s.t_sub = t_sub
            self._m_prefill.observe(time.time() - t0)
        self._m_queue.set(len(self._queue))

    # -- decode ------------------------------------------------------------
    def step(self) -> List[FinishedRequest]:
        """One engine tick: admit, advance every active slot one token,
        retire. Returns the requests that finished during this tick (also
        drained from an internal list — callers own them)."""
        if self._t0 is None:
            self._t0 = time.time()
        self._admit()
        active = [i for i, s in enumerate(self._slots) if s.active]
        self._m_occ.set(len(active) / self.num_slots)
        self._m_occ_hist.observe(len(active) / self.num_slots)
        if active:
            t0 = time.time()
            tokens = np.zeros((self.num_slots, 1), np.int32)
            pos = np.zeros((self.num_slots,), np.int32)
            for i in active:
                tokens[i, 0] = self._slots[i].next_token
                pos[i] = self._slots[i].pos
            logits, self._caches = self._step(
                self.params, self._caches, jnp.asarray(tokens),
                jnp.asarray(pos))
            logits = np.asarray(logits, np.float32)
            for i in active:
                s = self._slots[i]
                tok = int(sample_tokens(logits[i:i + 1], self.temperature,
                                        s.rng)[0])
                s.emitted.append(tok)
                s.pos += 1
                s.next_token = tok
                self._m_tokens.inc()
                if tok == self.eos_id or len(s.emitted) >= s.max_new:
                    self._finished.append(FinishedRequest(
                        request_id=s.request_id, prompt_len=s.prompt_len,
                        tokens=np.asarray(s.emitted, np.int32)))
                    s.active = False
                    s.emitted, s.rng = None, None
                    if self.slo is not None:
                        self.slo.observe(time.time() - s.t_sub)
            self._m_step.observe(time.time() - t0)
        out, self._finished = self._finished, []
        return out

    @property
    def pending(self) -> int:
        """Requests not yet finished: queued + occupying a slot."""
        return len(self._queue) + sum(s.active for s in self._slots)

    def run(self, requests=None, *, max_steps: int = 100_000
            ) -> Dict[int, np.ndarray]:
        """Drain loop: optionally ``submit()`` each ``(prompt, max_new)``
        pair (or ``(prompt, max_new, request_id)`` triple), then ``step()``
        until nothing is pending. Returns {request_id: tokens}."""
        for req in requests or []:
            self.submit(*req)
        done: Dict[int, np.ndarray] = {}
        steps = 0
        while self.pending:
            for fin in self.step():
                done[fin.request_id] = fin.tokens
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"run() exceeded {max_steps} steps with "
                                   f"{self.pending} requests pending")
        return done

    def stats(self) -> dict:
        """Registry snapshot + derived throughput (tokens/s over the wall
        clock since the first ``step()``)."""
        snap = self.registry.snapshot()
        elapsed = (time.time() - self._t0) if self._t0 else 0.0
        snap["derived"] = {
            "tokens_per_sec": (self._m_tokens.value / elapsed
                               if elapsed > 0 else 0.0),
            "elapsed_s": elapsed,
        }
        if self.slo is not None:
            snap["slo"] = self.slo.status()
        return snap

    def serve_metrics(self, *, port: int = 0, host: str = "127.0.0.1"):
        """Start a live HTTP endpoint over the engine's registry:
        ``/metrics`` (Prometheus), ``/healthz`` (SLO readiness when
        ``latency_slo_s`` was set — 503 while the error budget is
        exhausted), ``/snapshot.json``. Localhost-only by default; the
        caller owns the returned ``MetricsServer`` (``stop()`` it)."""
        from repro.obs import export as obs_export
        return obs_export.MetricsServer(
            self.registry,
            health=self.slo.status if self.slo is not None else None,
            host=host, port=port).start()
