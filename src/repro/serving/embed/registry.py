"""Class-embedding registry (DESIGN.md §6.2).

CLIP-style deployment hinges on precomputing the prompt-ensembled class
matrix ONCE per label space and amortizing it over every classify call
(Radford et al. 2021 §3.1.4); at open-vocabulary scales the text-tower cost
of rebuilding it per request dwarfs the image-side matmul. The registry
memoizes unit-normalized class matrices keyed on
``(class_names, templates, checkpoint)`` — the checkpoint fingerprint is in
the key, so loading new weights INVALIDATES every matrix computed under the
old ones by construction. Artifacts persist through ``repro.checkpoint.io``
(atomic step directories), so eval jobs and serving replicas share one
on-disk artifact instead of re-encoding the label space per process.

Versioning: each key directory holds checkpoint steps; ``refresh()`` writes
version+1 (e.g. after a kernel/numerics change), ``get()`` serves the
latest. The version travels with the matrix so responses can cite which
artifact classified them.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from repro.checkpoint import io as ckpt_io


def params_fingerprint(params) -> str:
    """Checkpoint identity: sha256 over every leaf's bytes + the treedef.
    Two parameter sets that classify differently must fingerprint
    differently; serving init pays the one-time hash."""
    h = hashlib.sha256()
    leaves, treedef = jax.tree_util.tree_flatten(params)
    h.update(str(treedef).encode())
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        h.update(str((arr.dtype.str, arr.shape)).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def checkpoint_fingerprint(params, tok=None) -> str:
    """The registry's checkpoint tag: params fingerprint plus the tokenizer
    artifact hash (``Tokenizer.content_hash``, DESIGN.md §9). Class
    matrices are computed from TOKENIZED prompts, so a retrained vocab
    changes them even under identical weights — folding the artifact hash
    into the tag invalidates cached matrices by construction instead of
    silently serving ones built under the old segmentation."""
    tag = params_fingerprint(params)
    if tok is not None and hasattr(tok, "content_hash"):
        tag += f":tok-{getattr(tok, 'version', 'unversioned')}" \
               f"-{tok.content_hash()}"
    return tag


@dataclasses.dataclass(frozen=True)
class ClassMatrix:
    """A registry artifact: one prompt-ensembled class-embedding matrix
    plus its provenance (how ``ClassEmbeddingRegistry.get`` obtained it)."""
    key: str            # full registry key (sha256 hex)
    version: int        # artifact version under this key
    matrix: np.ndarray  # (n_classes, D) unit-norm fp32
    source: str         # "memory" | "disk" | "computed"


class ClassEmbeddingRegistry:
    """Memoized prompt-ensembled class matrices with disk persistence.

    compute_fn(class_names, templates) -> (n, D) array; typically the
    service's batched text encode + ensembling (shared with
    ``eval.zero_shot.class_embeddings``).
    """

    def __init__(self, compute_fn: Optional[Callable] = None, *,
                 cache_dir: Optional[str] = None):
        self._compute = compute_fn
        self.cache_dir = cache_dir
        self._mem: dict = {}
        self._index_mem: dict = {}
        self.stats = {"mem_hits": 0, "disk_hits": 0, "computes": 0,
                      "index_hits": 0, "index_builds": 0}

    @staticmethod
    def key(class_names: Sequence[str], templates: Sequence[str],
            checkpoint_tag: str) -> str:
        h = hashlib.sha256()
        for part in ("classes", *class_names, "templates", *templates,
                     "ckpt", checkpoint_tag):
            h.update(part.encode())
            h.update(b"\x00")
        return h.hexdigest()

    def _key_dir(self, key: str) -> Optional[str]:
        return (os.path.join(self.cache_dir, key[:16])
                if self.cache_dir else None)

    def get(self, class_names: Sequence[str], templates: Sequence[str],
            checkpoint_tag: str, *, embed_dim: int) -> ClassMatrix:
        """Memory → disk → compute, persisting on the compute path."""
        key = self.key(class_names, templates, checkpoint_tag)
        hit = self._mem.get(key)
        if hit is not None:
            self.stats["mem_hits"] += 1
            return dataclasses.replace(hit, source="memory")

        kdir = self._key_dir(key)
        if kdir is not None:
            version = ckpt_io.latest_step(kdir)
            if version is not None:
                like = {"class_emb": jax.ShapeDtypeStruct(
                    (len(class_names), embed_dim), np.float32)}
                tree = ckpt_io.restore(kdir, version, like)
                cm = ClassMatrix(key, version,
                                 np.asarray(tree["class_emb"]), "disk")
                self._mem[key] = cm
                self.stats["disk_hits"] += 1
                return cm
        return self._compute_and_store(key, class_names, templates, 1)

    def refresh(self, class_names: Sequence[str], templates: Sequence[str],
                checkpoint_tag: str) -> ClassMatrix:
        """Force a recompute under the same key, bumping the version."""
        key = self.key(class_names, templates, checkpoint_tag)
        kdir = self._key_dir(key)
        latest = ckpt_io.latest_step(kdir) if kdir else None
        if latest is None:
            latest = self._mem[key].version if key in self._mem else 0
        return self._compute_and_store(key, class_names, templates,
                                       latest + 1)

    def _compute_and_store(self, key, class_names, templates,
                           version) -> ClassMatrix:
        if self._compute is None:
            raise RuntimeError(
                f"registry miss for key {key[:16]} and no compute_fn given")
        matrix = np.asarray(self._compute(class_names, templates), np.float32)
        if matrix.shape[0] != len(class_names):
            raise ValueError(f"compute_fn returned {matrix.shape} for "
                             f"{len(class_names)} classes")
        self.stats["computes"] += 1
        kdir = self._key_dir(key)
        if kdir is not None:
            ckpt_io.save(kdir, version, {"class_emb": matrix})
        cm = ClassMatrix(key, version, matrix, "computed")
        self._mem[key] = cm
        return cm

    def get_centroid_index(self, cm: ClassMatrix, *,
                           n_blocks: Optional[int] = None):
        """The two-stage coarse index for a registry artifact, built once
        per (key, version, n_blocks) and cached next to the class matrix.

        The memo/disk key embeds the ClassMatrix's own key AND version, so
        anything that invalidates the matrix — new checkpoint, retrained
        tokenizer, ``refresh()`` — invalidates the index by construction:
        a refreshed matrix simply never finds a stale index under its new
        version. Persists as ``index_v{version}_p{n_blocks}.npz`` in the
        key directory when the registry has a cache_dir.
        """
        from repro.serving.retrieval import twostage

        ikey = (cm.key, cm.version, n_blocks)
        hit = self._index_mem.get(ikey)
        if hit is not None:
            self.stats["index_hits"] += 1
            return hit
        kdir = self._key_dir(cm.key)
        path = (os.path.join(kdir, f"index_v{cm.version}_p{n_blocks}.npz")
                if kdir else None)
        if path is not None and os.path.exists(path):
            index = twostage.CentroidIndex.load(path)
            self._index_mem[ikey] = index
            self.stats["index_hits"] += 1
            return index
        index = twostage.build_centroid_index(cm.matrix, n_blocks=n_blocks)
        self.stats["index_builds"] += 1
        if path is not None:
            os.makedirs(kdir, exist_ok=True)
            index.save(path)
        self._index_mem[ikey] = index
        return index
