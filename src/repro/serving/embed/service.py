"""ZeroShotService: the public zero-shot inference API (DESIGN.md §6).

Ties the three layers of the embedding subsystem together over a BASIC dual
encoder (paper §3):

  classify(images, class_names)  — image tower via the micro-batcher, class
      matrix via the registry (computed once per label space + checkpoint,
      persisted), fused Pallas similarity→top-k over the class axis with the
      learned temperature — the (b, n_classes) logit matrix never exists.
  embed(tower, ...)              — raw unit-norm embeddings, micro-batched.
  retrieve(queries, gallery)     — text→gallery top-k with the same fused
      kernel (inv_tau=1: retrieval convention, no temperature sharpening).

``eval.zero_shot.evaluate_with_service`` and ``examples/serving_demo.py``
are the first two consumers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dual import DualEncoderConfig
from repro.eval.zero_shot import DEFAULT_TEMPLATES, class_embeddings
from repro.kernels.similarity_topk import ops as topk_ops
from repro.models import dual_encoder as de
from repro.serving.embed.batcher import DEFAULT_BUCKETS, MicroBatcher
from repro.serving.embed.registry import (ClassEmbeddingRegistry,
                                          checkpoint_fingerprint)


@dataclasses.dataclass(frozen=True)
class ClassifyResult:
    """Top-k classification output of ``ZeroShotService.classify``."""
    values: np.ndarray        # (b, k) fp32 similarity/temperature logits
    indices: np.ndarray       # (b, k) int32 class ids, ties to lower id
    class_names: tuple        # the label space, for decoding
    version: int              # registry artifact version that classified

    def top_names(self, row: int):
        """Class-name strings of row ``row``'s top-k, best first."""
        return [self.class_names[i] for i in self.indices[row]]


class ZeroShotService:
    """Zero-shot inference front door (DESIGN.md §6): micro-batched
    embedding (MicroBatcher) + memoized class matrices
    (ClassEmbeddingRegistry) + the fused Pallas similarity→top-k kernel,
    behind ``classify`` / ``embed_images`` / ``embed_texts`` /
    ``retrieve``. Context-manager friendly (stops the batcher on exit)."""

    def __init__(self, cfg: DualEncoderConfig, params, tok, *,
                 templates: Sequence[str] = DEFAULT_TEMPLATES,
                 text_len: int = 16,
                 registry_dir: Optional[str] = None,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_delay_ms: float = 2.0,
                 request_timeout_s: float = 60.0,
                 precision="f32",
                 interpret: Optional[bool] = None,
                 autostart: bool = True):
        self.cfg = cfg
        self.params = params
        self.tok = tok
        self.templates = tuple(templates)
        self.text_len = int(text_len)
        self.interpret = interpret
        # params fingerprint + tokenizer artifact hash: new weights OR a
        # retrained vocab both invalidate cached class matrices (§9)
        self.checkpoint_tag = checkpoint_fingerprint(params, tok)
        # 1/tau from the learned log-temperature (paper §3: A = X·Yᵀ/tau)
        self.inv_tau = float(jnp.exp(-params["log_tau"]))

        enc_i = jax.jit(lambda p, im: de.encode_image(cfg, p, im,
                                                      precision=precision))
        enc_t = jax.jit(lambda p, tx: de.encode_text(cfg, p, tx,
                                                     precision=precision))
        self.batcher = MicroBatcher(
            {"image": lambda im: enc_i(self.params, im),
             "text": lambda tx: enc_t(self.params, tx)},
            buckets=buckets, max_delay_ms=max_delay_ms,
            request_timeout_s=request_timeout_s, autostart=autostart)
        self.registry = ClassEmbeddingRegistry(self._compute_class_matrix,
                                               cache_dir=registry_dir)

    # -- embedding ---------------------------------------------------------
    def embed_images(self, images, *, wait: bool = True):
        """images: raw (b, H, W, C) pixels matching the image tower's
        geometry (or a dict payload, e.g. {'image': ...}) — the serving
        image-preprocessing path feeds the tower's patchify frontend.
        Returns (b, D) unit-norm fp32 — or the future when wait=False."""
        payload = images if isinstance(images, dict) else \
            {"image": np.asarray(images, np.float32)}
        fut = self.batcher.submit_many("image", payload)
        return self._result(fut) if wait else fut

    def embed_texts(self, texts, *, wait: bool = True):
        """texts: list of strings (tokenized here) or a pre-tokenized
        {'tokens', 'attn_mask'} payload. Returns (b, D) — or the future."""
        if not isinstance(texts, dict):
            ids = [self.tok.encode(t, max_len=self.text_len) for t in texts]
            tokens, mask = self.tok.pad_batch(ids, max_len=self.text_len)
            texts = {"tokens": tokens, "attn_mask": mask}
        fut = self.batcher.submit_many("text", texts)
        return self._result(fut) if wait else fut

    def _result(self, fut):
        if not self.batcher.running:
            self.batcher.flush_now()   # thread-free (autostart=False) path
        # the per-request deadline bounds the wait: classify/embed_* can
        # never hang indefinitely on a wedged flush thread
        return np.asarray(fut.result(timeout=self.batcher.request_timeout))

    # -- classification ----------------------------------------------------
    def classify(self, images, class_names: Sequence[str], *,
                 templates: Optional[Sequence[str]] = None,
                 k: int = 5) -> ClassifyResult:
        class_names = tuple(class_names)
        templates = tuple(templates) if templates is not None \
            else self.templates
        iemb_fut = self.embed_images(images, wait=False)
        cm = self.registry.get(class_names, templates, self.checkpoint_tag,
                               embed_dim=self.cfg.embed_dim)
        iemb = self._result(iemb_fut)
        vals, idx = topk_ops.similarity_topk(
            jnp.asarray(iemb), jnp.asarray(cm.matrix),
            min(int(k), len(class_names)),
            inv_tau=self.inv_tau, interpret=self.interpret)
        return ClassifyResult(np.asarray(vals), np.asarray(idx),
                              class_names, cm.version)

    def retrieve(self, queries: Sequence[str], gallery_emb, *, k: int = 5):
        """Text→gallery retrieval: top-k gallery rows per query by cosine
        similarity. gallery_emb: (m, D) unit-norm (e.g. from embed_images).
        Returns (values (q, k), indices (q, k))."""
        qemb = self.embed_texts(list(queries))
        vals, idx = topk_ops.similarity_topk(
            jnp.asarray(qemb), jnp.asarray(gallery_emb),
            min(int(k), int(np.shape(gallery_emb)[0])),
            inv_tau=1.0, interpret=self.interpret)
        return np.asarray(vals), np.asarray(idx)

    # -- internals ---------------------------------------------------------
    def _compute_class_matrix(self, class_names, templates):
        """Registry compute path: batched prompt ensembling through the
        text tower, via the SAME ``eval.zero_shot.class_embeddings`` the
        offline eval uses — one code path, one artifact."""
        def encode(texts):
            fut = self.batcher.submit_many("text", texts)
            if not self.batcher.running:
                self.batcher.flush_now()
            return jnp.asarray(
                fut.result(timeout=self.batcher.request_timeout))
        return class_embeddings(encode, self.tok, class_names, templates,
                                text_len=self.text_len)

    def stats(self) -> dict:
        """Service-wide stats: the batcher's dict-shaped counters + the
        class-embedding registry's hit/miss counts (legacy shape), plus
        ``metrics`` — the full ``obs.metrics.Registry`` snapshot
        (queue-depth gauge, request/flush latency and batch-occupancy
        histograms with p50/p90/p99; DESIGN.md §11)."""
        return {"batcher": dict(self.batcher.stats),
                "compiled_shapes": len(self.batcher.compiled_shapes()),
                "registry": dict(self.registry.stats),
                "metrics": self.batcher.metrics.snapshot()}

    def close(self):
        self.batcher.stop()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
