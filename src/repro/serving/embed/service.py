"""ZeroShotService: the public zero-shot inference API (DESIGN.md §6, §13).

Ties the three layers of the embedding subsystem together over a BASIC dual
encoder (paper §3):

  classify(images, class_names)  — image tower via the micro-batcher, class
      matrix via the registry (computed once per label space + checkpoint,
      persisted), similarity→top-k over the class axis with the learned
      temperature — the (b, n_classes) logit matrix never exists.
  embed(tower, ...)              — raw unit-norm embeddings, micro-batched.
  retrieve(queries, gallery)     — text→gallery top-k with the same path
      (inv_tau=1: retrieval convention, no temperature sharpening).

One flag — ``retrieval`` — selects how the top-k sweep runs (§13):

  "fused"     single-device fused Pallas kernel (the PR-2 path; default),
  "sharded"   exact mesh-sharded sweep: class/gallery rows split over the
              mesh data axes, per-shard kernels + top-k-of-top-k combine —
              bit-identical to "fused" (serving/retrieval/sharded.py),
  "twostage"  coarse centroid prune → exact rerank for the long tail; the
              centroid index is cached through the registry keyed on
              (matrix key, version), so checkpoint/tokenizer refreshes
              invalidate it by construction. ``nprobe`` trades recall for
              latency; ``nprobe="all"`` is exact.

Class matrices and galleries are prepared ONCE per artifact: classify keeps
a device-resident (mode-shaped) copy per registry (key, version); retrieve
accepts a ``GalleryHandle`` from ``prepare_gallery`` (and memoizes raw
arrays as a convenience) so repeated calls pay zero host→device upload.

``eval.zero_shot.evaluate_with_service`` and ``examples/serving_demo.py``
are the first two consumers.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dual import DualEncoderConfig
from repro.eval.zero_shot import DEFAULT_TEMPLATES, class_embeddings
from repro.kernels.similarity_topk import ops as topk_ops
from repro.models import dual_encoder as de
from repro.obs import export as obs_export
from repro.obs import health as obs_health
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving import retrieval as rtv
from repro.serving.embed.batcher import DEFAULT_BUCKETS, MicroBatcher
from repro.serving.embed.registry import (ClassEmbeddingRegistry,
                                          checkpoint_fingerprint)

RETRIEVAL_MODES = ("fused", "sharded", "twostage")


@dataclasses.dataclass(frozen=True)
class ClassifyResult:
    """Top-k classification output of ``ZeroShotService.classify``."""
    values: np.ndarray        # (b, k) fp32 similarity/temperature logits
    indices: np.ndarray       # (b, k) int32 class ids, ties to lower id
    class_names: tuple        # the label space, for decoding
    version: int              # registry artifact version that classified

    def top_names(self, row: int):
        """Class-name strings of row ``row``'s top-k, best first."""
        return [self.class_names[i] for i in self.indices[row]]


@dataclasses.dataclass(frozen=True)
class GalleryHandle:
    """A gallery prepared for the service's retrieval mode: device-resident
    (pre-sharded for "sharded", centroid-indexed for "twostage"), so every
    ``retrieve`` against it pays zero upload and zero index build. Obtain
    via ``ZeroShotService.prepare_gallery``."""
    data: object                       # jax.Array | ShardedMatrix | ndarray
    n: int                             # gallery rows
    mode: str                          # retrieval mode it was prepared for
    index: Optional[rtv.CentroidIndex] = None   # "twostage" only


class ZeroShotService:
    """Zero-shot inference front door (DESIGN.md §6): micro-batched
    embedding (MicroBatcher) + memoized class matrices
    (ClassEmbeddingRegistry) + the similarity→top-k sweep selected by
    ``retrieval``, behind ``classify`` / ``embed_images`` / ``embed_texts``
    / ``retrieve``. Context-manager friendly (stops the batcher on exit).

    retrieval: "fused" | "sharded" | "twostage" (module docstring).
    mesh: the device mesh for "sharded" (default: a 1-D data mesh over all
    local devices). nprobe: "twostage" blocks probed per query (None ≡
    "all" ≡ exact). index_blocks: centroid count (default ≈ √n).
    All three modes share one ``obs`` registry (``self.metrics``, also fed
    by the batcher) and one tracer, so ``stats()``/``obs.report`` show the
    whole serving path.

    SLO (DESIGN.md §14.3): ``latency_slo_s`` arms an ``SLOTracker`` —
    every ``classify``/``retrieve`` call's wall time feeds a windowed p99
    vs the target plus an error-budget burn gauge (``serve/slo_*``
    series), and readiness flips False while the windowed budget is
    exhausted. ``serve_metrics()`` exposes it all live over HTTP.
    """

    def __init__(self, cfg: DualEncoderConfig, params, tok, *,
                 templates: Sequence[str] = DEFAULT_TEMPLATES,
                 text_len: int = 16,
                 registry_dir: Optional[str] = None,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_delay_ms: float = 2.0,
                 request_timeout_s: float = 60.0,
                 precision="f32",
                 interpret: Optional[bool] = None,
                 retrieval: str = "fused",
                 mesh=None,
                 nprobe: Union[int, str, None] = None,
                 index_blocks: Optional[int] = None,
                 tracer: Optional[obs_trace.Tracer] = None,
                 autostart: bool = True,
                 latency_slo_s: Optional[float] = None,
                 slo_objective: float = 0.99,
                 slo_window: int = 256):
        if retrieval not in RETRIEVAL_MODES:
            raise ValueError(f"retrieval={retrieval!r} not in "
                             f"{RETRIEVAL_MODES}")
        self.cfg = cfg
        self.params = params
        self.tok = tok
        self.templates = tuple(templates)
        self.text_len = int(text_len)
        self.interpret = interpret
        self.retrieval = retrieval
        self.mesh = mesh
        self.nprobe = nprobe
        self.index_blocks = index_blocks
        # params fingerprint + tokenizer artifact hash: new weights OR a
        # retrained vocab both invalidate cached class matrices (§9)
        self.checkpoint_tag = checkpoint_fingerprint(params, tok)
        # 1/tau from the learned log-temperature (paper §3: A = X·Yᵀ/tau)
        self.inv_tau = float(jnp.exp(-params["log_tau"]))

        self.metrics = obs_metrics.Registry()
        self.tracer = tracer if tracer is not None else obs_trace.Tracer()
        enc_i = jax.jit(lambda p, im: de.encode_image(cfg, p, im,
                                                      precision=precision))
        enc_t = jax.jit(lambda p, tx: de.encode_text(cfg, p, tx,
                                                     precision=precision))
        self.batcher = MicroBatcher(
            {"image": lambda im: enc_i(self.params, im),
             "text": lambda tx: enc_t(self.params, tx)},
            buckets=buckets, max_delay_ms=max_delay_ms,
            request_timeout_s=request_timeout_s, autostart=autostart,
            registry=self.metrics)
        self.registry = ClassEmbeddingRegistry(self._compute_class_matrix,
                                               cache_dir=registry_dir)
        self._cm_device: dict = {}       # (key, version, mode) -> prepared
        self._gallery_memo = collections.OrderedDict()  # id -> (ref, handle)
        self._gallery_memo_cap = 4
        self.slo = None
        if latency_slo_s is not None:
            self.slo = obs_health.SLOTracker(
                target_s=float(latency_slo_s), objective=slo_objective,
                window=slo_window, registry=self.metrics, name="serve")

    # -- embedding ---------------------------------------------------------
    def embed_images(self, images, *, wait: bool = True):
        """images: raw (b, H, W, C) pixels matching the image tower's
        geometry (or a dict payload, e.g. {'image': ...}) — the serving
        image-preprocessing path feeds the tower's patchify frontend.
        Returns (b, D) unit-norm fp32 — or the future when wait=False."""
        payload = images if isinstance(images, dict) else \
            {"image": np.asarray(images, np.float32)}
        fut = self.batcher.submit_many("image", payload)
        return self._result(fut) if wait else fut

    def embed_texts(self, texts, *, wait: bool = True):
        """texts: list of strings (tokenized here) or a pre-tokenized
        {'tokens', 'attn_mask'} payload. Returns (b, D) — or the future."""
        if not isinstance(texts, dict):
            ids = [self.tok.encode(t, max_len=self.text_len) for t in texts]
            tokens, mask = self.tok.pad_batch(ids, max_len=self.text_len)
            texts = {"tokens": tokens, "attn_mask": mask}
        fut = self.batcher.submit_many("text", texts)
        return self._result(fut) if wait else fut

    def _result(self, fut):
        if not self.batcher.running:
            self.batcher.flush_now()   # thread-free (autostart=False) path
        # the per-request deadline bounds the wait: classify/embed_* can
        # never hang indefinitely on a wedged flush thread
        return np.asarray(fut.result(timeout=self.batcher.request_timeout))

    # -- classification ----------------------------------------------------
    def classify(self, images, class_names: Sequence[str], *,
                 templates: Optional[Sequence[str]] = None,
                 k: int = 5) -> ClassifyResult:
        k = int(k)
        if k < 1:
            raise ValueError(f"k={k} must be >= 1")
        class_names = tuple(class_names)
        templates = tuple(templates) if templates is not None \
            else self.templates
        t_req = time.perf_counter()
        try:
            with obs_trace.span(self.tracer, "serve/classify",
                                n_classes=len(class_names), k=k,
                                mode=self.retrieval):
                iemb_fut = self.embed_images(images, wait=False)
                cm = self.registry.get(class_names, templates,
                                       self.checkpoint_tag,
                                       embed_dim=self.cfg.embed_dim)
                data = self._class_data(cm)
                index = self.registry.get_centroid_index(
                    cm, n_blocks=self.index_blocks) \
                    if self.retrieval == "twostage" else None
                iemb = self._result(iemb_fut)
                vals, idx = self._topk(iemb, data, len(class_names),
                                       min(k, len(class_names)),
                                       inv_tau=self.inv_tau, index=index)
        finally:
            if self.slo is not None:
                self.slo.observe(time.perf_counter() - t_req)
        return ClassifyResult(vals, idx, class_names, cm.version)

    # -- retrieval ---------------------------------------------------------
    def prepare_gallery(self, gallery_emb) -> GalleryHandle:
        """Upload + shape ``gallery_emb`` (m, D) for the service's
        retrieval mode ONCE (device put / mesh shard / centroid index).
        Repeated ``retrieve`` calls against the returned handle do no
        host→device transfer and no index build — the fix for the old
        per-call ``jnp.asarray(gallery_emb)`` upload."""
        n = int(np.shape(gallery_emb)[0])
        mode = self.retrieval
        self.metrics.counter("serve/gallery_uploads").inc()
        with obs_trace.span(self.tracer, "serve/prepare_gallery",
                            n=n, mode=mode):
            index = None
            if mode == "sharded":
                data = rtv.shard_matrix(gallery_emb, self.mesh)
            elif mode == "twostage":
                data = np.asarray(gallery_emb, np.float32)
                index = rtv.build_centroid_index(
                    data, n_blocks=self.index_blocks)
            else:
                data = jnp.asarray(gallery_emb)
        return GalleryHandle(data, n, mode, index)

    def retrieve(self, queries: Sequence[str], gallery, *, k: int = 5,
                 nprobe: Union[int, str, None] = None):
        """Text→gallery retrieval: top-k gallery rows per query by cosine
        similarity. gallery: a ``GalleryHandle`` from ``prepare_gallery``
        (preferred — upload-once), or a raw (m, D) unit-norm array
        (prepared on first sight, memoized by object identity so repeated
        calls with the same array also upload once). Returns
        (values (q, k), indices (q, k)); k is clamped to the gallery size.
        nprobe overrides the service default for this call ("twostage")."""
        k = int(k)
        if k < 1:
            raise ValueError(f"k={k} must be >= 1")
        handle = gallery if isinstance(gallery, GalleryHandle) \
            else self._memo_gallery(gallery)
        if handle.mode != self.retrieval:
            raise ValueError(f"gallery prepared for mode {handle.mode!r}; "
                             f"service runs {self.retrieval!r} — call "
                             f"prepare_gallery again")
        t_req = time.perf_counter()
        try:
            with obs_trace.span(self.tracer, "serve/retrieve",
                                n=handle.n, k=k, mode=self.retrieval):
                qemb = self.embed_texts(list(queries))
                return self._topk(qemb, handle.data, handle.n,
                                  min(k, handle.n), inv_tau=1.0,
                                  index=handle.index, nprobe=nprobe)
        finally:
            if self.slo is not None:
                self.slo.observe(time.perf_counter() - t_req)

    def _memo_gallery(self, gallery_emb) -> GalleryHandle:
        """Bounded identity-keyed memo for raw-array galleries (the memo
        holds the reference, so the id stays valid while cached)."""
        key = id(gallery_emb)
        hit = self._gallery_memo.get(key)
        if hit is not None and hit[0] is gallery_emb:
            self._gallery_memo.move_to_end(key)
            self.metrics.counter("serve/gallery_memo_hits").inc()
            return hit[1]
        handle = self.prepare_gallery(gallery_emb)
        self._gallery_memo[key] = (gallery_emb, handle)
        while len(self._gallery_memo) > self._gallery_memo_cap:
            self._gallery_memo.popitem(last=False)
        return handle

    # -- the top-k sweep ---------------------------------------------------
    def _topk(self, q, data, n: int, k: int, *, inv_tau, index=None,
              nprobe=None):
        """Dispatch the (b, k) sweep per the retrieval mode, recording the
        §13 serving telemetry: per-stage ``serve/retrieval_latency_s``,
        ``serve/retrieval_prune_ratio`` (twostage: candidates/n) and
        ``serve/retrieval_shard_share`` (sharded: max per-shard share of
        the winners — 1/S ≈ balanced, →1 ≈ one hot shard)."""
        mode = self.retrieval
        t0 = time.perf_counter()
        with obs_trace.span(self.tracer, f"serve/topk_{mode}", n=n, k=k):
            if mode == "sharded":
                vals, idx = rtv.sharded_similarity_topk(
                    jnp.asarray(q), data, k, inv_tau=inv_tau,
                    interpret=self.interpret)
                shares = rtv.shard_winner_shares(idx, data)
                self.metrics.histogram(
                    "serve/retrieval_shard_share",
                    buckets=obs_metrics.RATIO_BUCKETS,
                    mode=mode).observe(float(shares.max()))
            elif mode == "twostage":
                vals, idx, info = rtv.two_stage_topk(
                    np.asarray(q), data, index, k,
                    nprobe=self.nprobe if nprobe is None else nprobe,
                    inv_tau=inv_tau, interpret=self.interpret)
                self.metrics.histogram(
                    "serve/retrieval_prune_ratio",
                    buckets=obs_metrics.RATIO_BUCKETS,
                    mode=mode).observe(info["prune_ratio"])
                for stage in ("coarse", "gather", "rerank"):
                    self.metrics.histogram(
                        "serve/retrieval_latency_s", mode=mode,
                        stage=stage).observe(info[f"{stage}_s"])
                if self.tracer is not None:
                    self.tracer.instant("serve/twostage_info", **info)
            else:
                vals, idx = topk_ops.similarity_topk(
                    jnp.asarray(q), data, k, inv_tau=inv_tau,
                    interpret=self.interpret)
        self.metrics.histogram("serve/retrieval_latency_s", mode=mode,
                               stage="total").observe(
            time.perf_counter() - t0)
        return np.asarray(vals), np.asarray(idx)

    def _class_data(self, cm):
        """The mode-shaped, device-resident copy of a registry artifact,
        prepared once per (key, version): refreshes re-prepare by
        construction (new version → new cache key)."""
        ck = (cm.key, cm.version, self.retrieval)
        hit = self._cm_device.get(ck)
        if hit is None:
            if self.retrieval == "sharded":
                hit = rtv.shard_matrix(cm.matrix, self.mesh)
            elif self.retrieval == "twostage":
                hit = np.asarray(cm.matrix, np.float32)
            else:
                hit = jnp.asarray(cm.matrix)
            self._cm_device[ck] = hit
        return hit

    # -- internals ---------------------------------------------------------
    def _compute_class_matrix(self, class_names, templates):
        """Registry compute path: batched prompt ensembling through the
        text tower, via the SAME ``eval.zero_shot.class_embeddings`` the
        offline eval uses — one code path, one artifact."""
        def encode(texts):
            fut = self.batcher.submit_many("text", texts)
            if not self.batcher.running:
                self.batcher.flush_now()
            return jnp.asarray(
                fut.result(timeout=self.batcher.request_timeout))
        return class_embeddings(encode, self.tok, class_names, templates,
                                text_len=self.text_len)

    def stats(self) -> dict:
        """Service-wide stats: the batcher's dict-shaped counters + the
        class-embedding registry's hit/miss counts (legacy shape), plus
        ``metrics`` — the shared ``obs.metrics.Registry`` snapshot (batcher
        latency/occupancy AND the serve/retrieval_* series; DESIGN.md §11,
        §13.4)."""
        out = {"batcher": dict(self.batcher.stats),
               "compiled_shapes": len(self.batcher.compiled_shapes()),
               "registry": dict(self.registry.stats),
               "retrieval_mode": self.retrieval,
               "metrics": self.metrics.snapshot()}
        if self.slo is not None:
            out["slo"] = self.slo.status()
        return out

    def serve_metrics(self, *, port: int = 0,
                      host: str = "127.0.0.1") -> obs_export.MetricsServer:
        """Start a live HTTP endpoint over this service's registry:
        ``/metrics`` (Prometheus), ``/healthz`` (SLO readiness when a
        ``latency_slo_s`` was set — 503 while the error budget is
        exhausted), ``/snapshot.json``. Localhost-only by default; the
        caller owns the returned server (``stop()`` it)."""
        return obs_export.MetricsServer(
            self.metrics,
            health=self.slo.status if self.slo is not None else None,
            host=host, port=port).start()

    def close(self):
        self.batcher.stop()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
