from repro.serving.embed.batcher import MicroBatcher  # noqa: F401
from repro.serving.embed.registry import (  # noqa: F401
    ClassEmbeddingRegistry,
    ClassMatrix,
    params_fingerprint,
)
from repro.serving.embed.service import (  # noqa: F401
    ClassifyResult,
    ZeroShotService,
)
