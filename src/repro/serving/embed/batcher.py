"""Async micro-batching embedding engine (DESIGN.md §6.1).

Encoders have no decode loop, so the continuous-batching trick of the decode
engine does not apply; its fixed-shape analog is MICRO-BATCHING: concurrent
encode requests are queued per tower, coalesced into one of a small set of
padded batch shapes (the bucket ladder), and flushed either when the largest
bucket fills (size trigger) or when the oldest request has waited
``max_delay_ms`` (deadline trigger). Callers get futures immediately; the
flush path pads the coalesced batch up to the bucket size so every shape the
towers ever compile is one of ``len(buckets)`` shapes per tower — the
compiled-shape cache is keyed on ``(tower, bucket, example shape/dtype)``.

Padding replicates the last real example (never zeros: an all-pad attention
mask would produce NaN rows that, while sliced off, make debugging
miserable); padded rows are dropped before futures resolve.

The engine is model-agnostic: it batches any pytree-of-arrays payload and
calls the per-tower ``encode_fns`` you hand it. ``ZeroShotService`` wires it
to the dual encoder's towers.

Failure semantics: an encode-fn exception fails that cohort's futures; any
OTHER exception inside the flush thread fails EVERY pending future (a
stranded future is a caller blocked forever) and the worker keeps serving.
Every future carries a per-request deadline (``request_timeout_s``) — a
bare ``result()`` can never hang indefinitely, even when the flush thread
is wedged inside a blocked encode fn.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Callable, Dict, Sequence

import jax
import numpy as np

from repro.obs import metrics as obs_metrics

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64)

_STAT_KEYS = ("requests", "size_flushes", "deadline_flushes",
              "manual_flushes", "encoded_examples", "padded_examples",
              "batches", "worker_errors")


class DeadlineFuture(Future):
    """A Future whose bare ``result()``/``exception()`` wait at most until
    the request deadline instead of forever. Every future the batcher hands
    out is one of these: even when the flush thread is wedged inside a
    blocked encode fn (where no amount of exception plumbing can help), a
    caller that did not pass its own timeout gets ``TimeoutError`` at the
    deadline rather than hanging indefinitely."""

    _deadline = None  # monotonic seconds; set by the batcher at submit

    def _cap(self, timeout):
        if timeout is None and self._deadline is not None:
            return max(0.0, self._deadline - time.monotonic())
        return timeout

    def result(self, timeout=None):
        """``Future.result`` defaulting ``timeout`` to the request
        deadline."""
        return super().result(self._cap(timeout))

    def exception(self, timeout=None):
        """``Future.exception`` defaulting ``timeout`` to the request
        deadline."""
        return super().exception(self._cap(timeout))


class _Group:
    """One submit_many() call: a batched payload awaiting one future."""

    __slots__ = ("payload", "n", "future", "t_submit")

    def __init__(self, payload, n: int, t_submit: float,
                 deadline: float | None = None):
        self.payload = payload
        self.n = n
        self.future: DeadlineFuture = DeadlineFuture()
        self.future._deadline = deadline
        self.t_submit = t_submit


def _leading(payload) -> int:
    leaves = jax.tree_util.tree_leaves(payload)
    if not leaves:
        raise ValueError("empty payload")
    n = leaves[0].shape[0]
    if any(leaf.shape[0] != n for leaf in leaves):
        raise ValueError("payload leaves disagree on the batch axis")
    return n


def _shape_sig(payload):
    return tuple((tuple(leaf.shape[1:]), np.dtype(leaf.dtype).name)
                 for leaf in jax.tree_util.tree_leaves(payload))


class MicroBatcher:
    """Queue → bucket → flush-on-size-or-deadline → futures.

    encode_fns: tower name -> fn(batch pytree) -> (b, D) embeddings. Fns are
    called as-is — jit them yourself with whatever argument discipline keeps
    your params cache-friendly (the service passes closures over a jitted
    (params, batch) fn, so params stay a real jit argument rather than
    trace-time constants). The bucket ladder bounds how many batch shapes a
    fn ever sees.
    """

    def __init__(self, encode_fns: Dict[str, Callable], *,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_delay_ms: float = 2.0, request_timeout_s: float = 60.0,
                 autostart: bool = True, registry=None):
        if not buckets or any(b <= 0 for b in buckets):
            raise ValueError(f"bad bucket ladder {buckets}")
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self.max_delay = float(max_delay_ms) / 1e3
        self.request_timeout = float(request_timeout_s)
        self._fns = dict(encode_fns)
        self._pending: Dict[str, list] = {t: [] for t in self._fns}
        self._cv = threading.Condition()
        self._compiled: Dict[tuple, int] = {}   # shape-cache key -> hit count
        self._stop = False
        self._thread = None
        # telemetry (DESIGN.md §11): counters + queue-depth gauge +
        # latency/occupancy histograms on an obs registry (pass
        # ``registry=`` to share one; default is private so concurrent
        # batcher instances never mix series)
        self.metrics = registry if registry is not None \
            else obs_metrics.Registry()
        self._c = {k: self.metrics.counter(f"serve/{k}")
                   for k in _STAT_KEYS}
        self._g_queue = self.metrics.gauge("serve/queue_depth")
        self._h_request = self.metrics.histogram("serve/request_latency_s")
        self._h_flush = self.metrics.histogram("serve/flush_latency_s")
        self._h_occupancy = self.metrics.histogram(
            "serve/batch_occupancy", buckets=obs_metrics.RATIO_BUCKETS)
        if autostart:
            self.start()

    @property
    def stats(self) -> dict:
        """Dict-shaped counter view (the pre-§11 ad-hoc ``stats`` dict
        shape, now backed by the shared registry — back-compat tested)."""
        return {k: int(c.value) for k, c in self._c.items()}

    # -- lifecycle ---------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self):
        if self.running:
            return
        self._stop = False
        self._thread = threading.Thread(target=self._worker,
                                        name="microbatcher", daemon=True)
        self._thread.start()

    def stop(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        self.flush_now()  # drain anything left behind

    # -- submission --------------------------------------------------------
    def submit(self, tower: str, example) -> Future:
        """One example (pytree WITHOUT batch axis) -> Future of (D,) emb."""
        batched = jax.tree_util.tree_map(lambda a: np.asarray(a)[None],
                                         example)
        group = self._enqueue(tower, batched, 1)
        out = DeadlineFuture()
        out._deadline = group.future._deadline
        group.future.add_done_callback(
            lambda f: out.set_exception(f.exception()) if f.exception()
            else out.set_result(f.result()[0]))
        return out

    def submit_many(self, tower: str, payload) -> Future:
        """A batched payload (pytree WITH batch axis) -> Future of (n, D).
        The group is kept contiguous but batches with other pending work."""
        payload = jax.tree_util.tree_map(np.asarray, payload)
        return self._enqueue(tower, payload, _leading(payload)).future

    def _enqueue(self, tower: str, payload, n: int) -> _Group:
        if tower not in self._fns:
            raise KeyError(f"unknown tower {tower!r}; "
                           f"have {sorted(self._fns)}")
        now = time.monotonic()
        group = _Group(payload, n, now, deadline=now + self.request_timeout)
        with self._cv:
            self._pending[tower].append(group)
            self._g_queue.set(sum(g.n for gs in self._pending.values()
                                  for g in gs))
            self._cv.notify_all()
        self._c["requests"].inc(n)
        return group

    # -- flushing ----------------------------------------------------------
    def flush_now(self) -> int:
        """Synchronously encode everything pending (manual trigger; also the
        path tests use for deterministic, thread-free stepping). Returns the
        number of examples encoded."""
        return sum(self._flush_tower(t, "manual_flushes")
                   for t in list(self._pending))

    def _worker(self):
        while True:
            try:
                with self._cv:
                    if self._stop:
                        return
                    deadline = self._earliest_deadline_locked()
                    if deadline is None:
                        self._cv.wait()
                    else:
                        now = time.monotonic()
                        if deadline > now and not self._size_due_locked():
                            self._cv.wait(timeout=deadline - now)
                    if self._stop:
                        return
                    due = [(t, "size_flushes" if self._size_due_locked(t)
                            else "deadline_flushes")
                           for t in self._pending if self._due_locked(t)]
                for tower, reason in due:
                    self._flush_tower(tower, reason)
            except Exception as e:  # noqa: BLE001 — flush-thread bug: a
                # stranded future is a caller blocked forever, so EVERY
                # pending request fails with the exception and the worker
                # keeps serving future submissions
                self._c["worker_errors"].inc()
                self._fail_all_pending(e)

    def _fail_all_pending(self, exc: Exception) -> int:
        """Fail every queued (unflushed) request with ``exc``; returns how
        many futures were failed. The flush thread calls this when it hits
        an exception outside the per-cohort encode path — nothing may be
        left waiting on a worker that just lost its state."""
        with self._cv:
            groups = [g for gs in self._pending.values() for g in gs]
            for tower in self._pending:
                self._pending[tower] = []
            self._g_queue.set(0)
        failed = 0
        for g in groups:
            if g.future.set_running_or_notify_cancel():
                g.future.set_exception(exc)
                failed += 1
        return failed

    def _earliest_deadline_locked(self):
        oldest = [g.t_submit for gs in self._pending.values() for g in gs]
        return min(oldest) + self.max_delay if oldest else None

    def _size_due_locked(self, tower=None) -> bool:
        towers = [tower] if tower else list(self._pending)
        return any(sum(g.n for g in self._pending[t]) >= self.buckets[-1]
                   for t in towers)

    def _due_locked(self, tower) -> bool:
        groups = self._pending[tower]
        if not groups:
            return False
        if sum(g.n for g in groups) >= self.buckets[-1]:
            return True
        return time.monotonic() - groups[0].t_submit >= self.max_delay

    def _flush_tower(self, tower: str, reason: str) -> int:
        with self._cv:
            groups, self._pending[tower] = self._pending[tower], []
            self._g_queue.set(sum(g.n for gs in self._pending.values()
                                  for g in gs))
        if not groups:
            return 0
        self._c[reason].inc()
        t_flush = time.monotonic()
        try:
            # only structurally identical payloads may coalesce: mixing
            # treedefs or per-example shapes would mispair leaves under one
            # treedef and silently scramble results, so each cohort encodes
            # separately
            cohorts: dict = {}
            for g in groups:
                key = (jax.tree_util.tree_structure(g.payload),
                       _shape_sig(g.payload))
                cohorts.setdefault(key, []).append(g)
            for cohort in cohorts.values():
                self._encode_chunk(tower, cohort)
        except Exception as e:
            # groups are already popped — fail them before propagating, or
            # their callers would block until the deadline for nothing
            for g in groups:
                if not g.future.done():
                    g.future.set_exception(e)
            raise
        self._h_flush.observe(time.monotonic() - t_flush)
        return sum(g.n for g in groups)

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def _encode_chunk(self, tower: str, groups: list):
        n = sum(g.n for g in groups)
        try:
            leaves = [jax.tree_util.tree_leaves(g.payload) for g in groups]
            treedef = jax.tree_util.tree_structure(groups[0].payload)
            cat = [np.concatenate(parts) for parts in zip(*leaves)]
            outs = []
            # slice through the ladder so every encode is a bucket shape
            # (one oversized submit_many group must not compile its own)
            for s in range(0, n, self.buckets[-1]):
                part = [a[s:s + self.buckets[-1]] for a in cat]
                m = part[0].shape[0]
                bucket = self._bucket_for(m)
                if bucket > m:  # replicate the last row up to the bucket
                    part = [np.concatenate(
                        [a, np.repeat(a[-1:], bucket - m, axis=0)])
                        for a in part]
                batch = jax.tree_util.tree_unflatten(treedef, part)
                key = (tower, bucket, _shape_sig(batch))
                self._compiled[key] = self._compiled.get(key, 0) + 1
                outs.append(np.asarray(self._fns[tower](batch))[:m])
                self._c["padded_examples"].inc(bucket - m)
                self._c["batches"].inc()
                self._h_occupancy.observe(m / bucket)
            emb = np.concatenate(outs) if len(outs) > 1 else outs[0]
        except Exception as e:  # noqa: BLE001 — deliver, don't kill worker
            for g in groups:
                g.future.set_exception(e)
            return
        self._c["encoded_examples"].inc(n)
        off = 0
        done = time.monotonic()
        for g in groups:
            g.future.set_result(emb[off:off + g.n])
            self._h_request.observe(done - g.t_submit)
            off += g.n

    # -- observability -----------------------------------------------------
    def compiled_shapes(self):
        """{(tower, bucket, example-shape-sig): batches run} — its length is
        the number of distinct compiled encoder shapes."""
        return dict(self._compiled)
