"""Batched serving engine: prefill + decode loop over fixed batch slots.

The engine compiles two programs per (batch, cache_len):
  - ``prefill``: full forward over the (right-padded) prompt batch, building
    per-layer KV/SSM caches,
  - ``decode``: one token for every slot, cache updated in place (donated).

Both run under one ``models.precision`` policy (``precision='bf16'`` etc.;
the legacy ``dtype=`` maps onto a policy) — compute in the policy's dtype,
norms/logits in fp32 islands — and one attention backend: ``attn`` selects
the full-sequence backend for prefill (``models.attention`` registry) AND
the decode backend (``resolve_decode_backend``; 'pallas' sweeps the KV
cache with the kernels/decode_attention GQA kernel).

Sampling: greedy or temperature (module-level ``sample_tokens``, shared
with the continuous engine). Per-slot EOS stops are tracked host-side;
finished slots keep decoding pad tokens (masked out of the result) — the
fixed-shape analog of continuous batching (``serving.continuous`` lifts
the fixed-batch restriction with slot-level admission; this engine stays
the lockstep baseline and the parity oracle its tests pin against).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import precision as prec_lib
from repro.models import transformer as tf


def sample_tokens(logits, temperature, rng) -> np.ndarray:
    """Sample one token per row from (b, vocab) logits. Greedy for
    ``temperature <= 0`` (fp32 host-side argmax — the tie-break every
    engine must share for token-level parity), else softmax sampling
    drawn from ``rng``."""
    logits = np.asarray(logits, np.float32)
    if temperature <= 0:
        return np.argmax(logits, axis=-1).astype(np.int32)
    p = jax.nn.softmax(jnp.asarray(logits / temperature), axis=-1)
    p = np.asarray(p)
    return np.array([rng.choice(p.shape[-1], p=pi / pi.sum())
                     for pi in p], np.int32)


class Engine:
    """Lockstep fixed-batch decode engine: one prefill + one donated
    decode program per (batch, cache_len); every slot advances together
    and the batch retires when its slowest request finishes. The
    continuous engine's parity oracle (DESIGN.md §12.3)."""

    def __init__(self, cfg: ArchConfig, params, *, cache_len: int,
                 dtype=None, precision=None,
                 attn: Optional[str] = None,
                 moe_args: Optional[dict] = None,
                 eos_id: int = 3):
        assert cfg.causal, f"{cfg.name} is encoder-only; no decode step"
        if attn is not None:
            from repro.models import attention as attn_lib
            if attn != "auto" and attn not in attn_lib.ATTN_BACKENDS:
                raise KeyError(
                    f"unknown attention impl {attn!r}; have "
                    f"{attn_lib.available_backends()} + 'auto'")
            cfg = dataclasses.replace(cfg, attn_impl=attn)
        self.cfg, self.params = cfg, params
        self.cache_len = cache_len
        # policy resolution order matches the tower runtime: an explicit
        # policy wins, a legacy bare dtype maps onto one, default f32 (the
        # engine's historical dtype)
        self.precision = prec_lib.resolve(precision, dtype or jnp.float32)
        self.moe_args = moe_args or {}
        self.eos_id = eos_id

        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))

    # -- compiled bodies ---------------------------------------------------
    def _prefill_impl(self, params, tokens):
        batch = {"tokens": tokens}
        logits, caches = tf.prefill(self.cfg, params, batch,
                                    precision=self.precision,
                                    moe_args=self.moe_args,
                                    collect_cache_len=self.cache_len)
        return logits[:, 0, :], caches

    def _decode_impl(self, params, caches, token, pos):
        logits, caches = tf.decode_step(self.cfg, params, token, pos, caches,
                                        precision=self.precision,
                                        moe_args=self.moe_args)
        return logits[:, 0, :], caches

    # -- public API ----------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new_tokens: int, *,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """prompts: (b, prompt_len) int32 (right-aligned, no padding support
        inside the prompt for simplicity). Returns (b, max_new_tokens)."""
        b, plen = prompts.shape
        assert plen + max_new_tokens <= self.cache_len or \
            self.cfg.sliding_window is not None, "cache too small"
        logits, caches = self._prefill(self.params, jnp.asarray(prompts))
        rng = np.random.default_rng(seed)
        out = np.zeros((b, max_new_tokens), np.int32)
        done = np.zeros((b,), bool)
        tok = self._sample(logits, temperature, rng)
        for i in range(max_new_tokens):
            out[:, i] = np.where(done, 0, tok)
            done |= (tok == self.eos_id)
            if done.all():
                break
            pos = jnp.asarray(plen + i, jnp.int32)
            logits, caches = self._decode(self.params, caches,
                                          jnp.asarray(tok)[:, None], pos)
            tok = self._sample(logits, temperature, rng)
        return out

    # kept as a staticmethod alias so existing callers/tests that reach
    # for Engine._sample keep working; the one implementation lives at
    # module level so both engines share its tie-breaking exactly
    _sample = staticmethod(sample_tokens)
