"""Planet-scale retrieval: mesh-sharded exact top-k (stage A) and the
coarse→fine two-stage path (stage B). DESIGN.md §13."""
from repro.serving.retrieval.sharded import (  # noqa: F401
    ShardedMatrix,
    default_data_mesh,
    shard_matrix,
    shard_winner_shares,
    sharded_similarity_topk,
)
from repro.serving.retrieval.twostage import (  # noqa: F401
    CentroidIndex,
    build_centroid_index,
    two_stage_topk,
)
