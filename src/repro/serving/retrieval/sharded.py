"""Stage A: mesh-sharded similarity→top-k (DESIGN.md §13.1).

The PR-2 fused kernel never materializes the (b, n) logit matrix but is
single-device: at planet scale (10M+ gallery/class rows) one device can
neither hold the class matrix in HBM nor sweep it at interactive latency.
This module shards the class axis over the mesh's data axes (reusing the
``core/sharding`` axis conventions) and runs the fused kernel PER SHARD
inside ``shard_map``, each shard sweeping only its n/S rows:

  1. per shard: ``ops.similarity_topk`` over the local (n_local, d) block
     with a TRACED ``n_valid`` mask (the last shard's zero-padded tail is
     only known from the shard index), emitting (b, k) local winners whose
     indices are lifted to GLOBAL ids by the shard's row offset;
  2. combine: all-gather of the (b, k) per-shard candidates along the data
     axes — a psum-free top-k-of-top-k — then one ``ops.merge_topk``
     select-max-retire pass over the (b, S·k) pool.

Exactness argument (pinned by tests/distributed_checks.py ``retrieval``
against the stable-argsort oracle): every logit is a single fp32-accumulated
dot of one query row with one class row — identical arithmetic whichever
shard computes it — and a global top-k winner is necessarily inside its own
shard's top-k (at most k-1 better rows exist anywhere). The merge rule
(descending value, ties to the LOWER global id, retire-by-id) is the
kernel's own and is order-independent, so merging per-shard top-ks is
bit-identical to the single-device sweep, duplicates and ties included.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import sharding as shd
from repro.kernels.similarity_topk import ops as topk_ops
from repro.kernels.similarity_topk.kernel import IDX_PAD, NEG


def default_data_mesh(n_devices: Optional[int] = None):
    """A 1-D ('data',) mesh over the first ``n_devices`` local devices
    (all of them by default) — the serving-side default when no training
    mesh is passed in."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    return jax.make_mesh((n,), (shd.DATA,), devices=devs[:n])


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _linear_index(axes):
    """Row-major linear shard index over the (possibly multi-) data axes —
    the same composition ``jax.lax.all_gather`` uses for a tuple axis, so
    gathered blocks land at this index."""
    if isinstance(axes, str):
        return jax.lax.axis_index(axes)
    idx = jnp.int32(0)
    for name in axes:
        idx = idx * jax.lax.psum(1, name) + jax.lax.axis_index(name)
    return idx


@dataclasses.dataclass(frozen=True)
class ShardedMatrix:
    """A device-resident class/gallery matrix, row-sharded over the mesh's
    data axes and padded so every shard holds ``n_local`` rows (the tail
    shard's padding is masked at query time via the kernel's ``n_valid``).
    Build once via ``shard_matrix``; every ``sharded_similarity_topk`` call
    against it then pays zero host→device transfer and zero resharding."""
    array: jax.Array     # (S * n_local, d), sharded P(axes) on dim 0
    n: int               # real (unpadded) row count
    n_local: int         # rows per shard (>= MAX_K)
    mesh: object
    axes: tuple          # data axis names the rows are split over

    @property
    def n_shards(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.axes]))


def shard_matrix(matrix, mesh=None, *, data_axes=None) -> ShardedMatrix:
    """Pad ``matrix`` (n, d) to S·n_local rows and lay it over ``mesh``'s
    data axes (``n_local >= MAX_K`` so any legal k fits inside one shard).
    The zero padding is never scored: query-time masking via ``n_valid``
    keeps it at the NEG sentinel."""
    if mesh is None:
        mesh = default_data_mesh()
    if data_axes is None:
        data_axes = tuple(a for a in shd.data_axes(mesh) if a in mesh.shape)
    s = int(np.prod([mesh.shape[a] for a in data_axes]))
    n, d = np.shape(matrix)
    n_local = max(-(-n // s), topk_ops.MAX_K)
    n_pad = s * n_local
    m = jnp.asarray(matrix)
    if n_pad != n:
        m = jnp.pad(m, ((0, n_pad - n), (0, 0)))
    sharding = NamedSharding(mesh, P(data_axes))
    return ShardedMatrix(jax.device_put(m, sharding), int(n), int(n_local),
                         mesh, tuple(data_axes))


def sharded_similarity_topk(query_emb, class_emb, k: int, *, mesh=None,
                            inv_tau=1.0, data_axes=None,
                            bm: Optional[int] = None,
                            bc: Optional[int] = None,
                            interpret: Optional[bool] = None):
    """Mesh-sharded drop-in for ``ops.similarity_topk`` (bit-identical
    output, tests pin it): per-shard fused sweeps + the psum-free
    top-k-of-top-k combine.

    query_emb: (b, d) host or device array (replicated to every shard);
    class_emb: a ``ShardedMatrix`` (the no-per-call-upload path) or a raw
    (n, d) array (sharded here on the fly). Returns (values (b, k) fp32,
    indices (b, k) int32). A 1-extent data mesh degenerates to the
    single-device kernel.
    """
    if not isinstance(class_emb, ShardedMatrix):
        class_emb = shard_matrix(class_emb, mesh, data_axes=data_axes)
    sm = class_emb
    n, d = sm.n, sm.array.shape[1]
    b, dq = np.shape(query_emb)
    if dq != d:
        raise ValueError(f"embed dims differ: query {dq} vs class {d}")
    k = int(k)
    if not 1 <= k <= n:
        raise ValueError(f"k={k} must be in [1, n={n}]")
    if k > topk_ops.MAX_K:
        raise ValueError(f"k={k} > MAX_K={topk_ops.MAX_K}")
    s = sm.n_shards
    if s == 1:
        return topk_ops.similarity_topk(
            jnp.asarray(query_emb), sm.array[:n], k, inv_tau=inv_tau,
            bm=bm, bc=bc, interpret=interpret)

    axis = sm.axes if len(sm.axes) > 1 else sm.axes[0]
    n_local = sm.n_local

    def local_fn(x, c_l):
        r = _linear_index(axis)
        offset = r * n_local
        n_valid = jnp.clip(n - offset, 0, n_local)
        v, i = topk_ops.similarity_topk(x, c_l, k, inv_tau=inv_tau,
                                        bm=bm, bc=bc, n_valid=n_valid,
                                        interpret=interpret)
        # lift to global ids; a shard with < k valid rows emits NEG-valued
        # tail entries whose ids must not alias real rows in the combine
        gi = i + offset
        dead = v <= NEG / 2
        gi = jnp.where(dead, IDX_PAD, gi)
        v = jnp.where(dead, NEG, v)
        # psum-free combine: gather everyone's (b, k) winners, one
        # select-max-retire pass over the (b, S*k) pool on every shard
        vg = jax.lax.all_gather(v, axis, tiled=False)       # (S, b, k)
        ig = jax.lax.all_gather(gi, axis, tiled=False)
        pool_v = jnp.moveaxis(vg, 0, 1).reshape(v.shape[0], -1)
        pool_i = jnp.moveaxis(ig, 0, 1).reshape(v.shape[0], -1)
        return topk_ops.merge_topk(pool_v, pool_i, k)

    mapped = shard_map(local_fn, mesh=sm.mesh,
                       in_specs=(P(), P(axis)), out_specs=(P(), P()),
                       check_rep=False)
    x = jnp.asarray(query_emb)
    with sm.mesh:
        vals, idx = jax.jit(mapped)(x, sm.array)
    return vals, idx


def shard_winner_shares(indices, sm: ShardedMatrix) -> np.ndarray:
    """Per-shard share of the final top-k winners — the load-skew signal
    the serving telemetry histograms (`serve/retrieval_shard_share`).
    Returns (S,) fp32 summing to 1 (uniform ≈ balanced shards)."""
    idx = np.asarray(indices).reshape(-1)
    shard_of = np.clip(idx // sm.n_local, 0, sm.n_shards - 1)
    counts = np.bincount(shard_of, minlength=sm.n_shards).astype(np.float64)
    total = max(counts.sum(), 1.0)
    return (counts / total).astype(np.float32)
