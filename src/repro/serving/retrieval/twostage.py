"""Stage B: two-stage coarse→fine retrieval for the long tail (§13.2).

Even sharded, an exact sweep touches every one of N rows; at N=10M+ the
interactive-latency budget only covers a PRUNED sweep. This is the
IVF-style trade: group the class/gallery rows into blocks around k-means
centroids (built ONCE per registry artifact version — the index is cached
alongside the class matrix, so checkpoint/tokenizer refreshes invalidate
it by construction, registry.py), then per batch

  1. coarse: score the (b, P) query×centroid matrix (P ≈ √N blocks — tiny
     next to N) and take each query's top-``nprobe`` blocks;
  2. prune:  the batch's surviving blocks are the UNION of the per-query
     probes; candidate ids are their members, sorted ASCENDING so the
     fused kernel's lower-local-index tie-break maps to lower GLOBAL id;
  3. rerank: one exact fused ``similarity_topk`` sweep over only the
     candidate rows, local winners mapped back through the id table.

Exactness escape hatch: at ``nprobe >= n_blocks`` every block survives,
the candidate table is the identity, and the rerank IS the stage-A sweep —
recall@k = 1.0 by construction, not by tuning (pinned in tests). At
pruned settings recall is a measured trade against latency
(``benchmarks/serving_bench.py`` ``topk_twostage/*`` entries).

Rows are fetched through a ``gather`` callback so galleries larger than
host memory can stream blocks from wherever they live (the N=10M bench
regenerates blocks from seeds); a materialized (n, d) matrix is the
common case and short-circuits the full-survival gather.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Union

import jax.numpy as jnp
import numpy as np

from repro.kernels.similarity_topk import ops as topk_ops


@dataclasses.dataclass(frozen=True)
class CentroidIndex:
    """The coarse index: unit-norm centroids plus the block membership
    table (a partition of [0, n))."""
    centroids: np.ndarray    # (P, d) fp32 unit-norm
    members: np.ndarray      # (P, m_max) int32 global ids, -1 padded
    counts: np.ndarray       # (P,) int32 real member count per block
    n: int                   # total rows indexed

    @property
    def n_blocks(self) -> int:
        return int(self.centroids.shape[0])

    def block_members(self, block: int) -> np.ndarray:
        """The global ids of ``block`` (ascending, unpadded)."""
        return self.members[block, :self.counts[block]]

    def save(self, path: str) -> None:
        """Persist as an .npz (atomic: tmp + rename)."""
        import os
        tmp = f"{path}.tmp{os.getpid()}"
        with open(tmp, "wb") as f:
            np.savez(f, centroids=self.centroids, members=self.members,
                     counts=self.counts, n=np.int64(self.n))
        os.replace(tmp, path)

    @staticmethod
    def load(path: str) -> "CentroidIndex":
        """Inverse of ``save``."""
        with np.load(path) as z:
            return CentroidIndex(z["centroids"], z["members"], z["counts"],
                                 int(z["n"]))


def build_centroid_index(matrix, *, n_blocks: Optional[int] = None,
                         iters: int = 4, seed: int = 0) -> CentroidIndex:
    """Spherical k-means over the (n, d) unit-norm ``matrix``.

    Deterministic for a given (matrix, n_blocks, iters, seed): init takes
    ``n_blocks`` evenly spaced rows (seed rotates the offset), each Lloyd
    iteration assigns rows to their max-cosine centroid and re-normalizes
    the member mean; empty blocks keep their previous centroid. Defaults
    to P = ceil(sqrt(n)) blocks — coarse cost O(b·√n), balanced against
    per-block rerank cost O(b·√n) per probed block.
    """
    m = np.asarray(matrix, np.float32)
    n, d = m.shape
    if n == 0:
        raise ValueError("cannot index an empty matrix")
    p = int(n_blocks) if n_blocks else int(np.ceil(np.sqrt(n)))
    p = max(1, min(p, n))
    start = seed % max(n // p, 1)
    cent = m[(start + (np.arange(p) * n) // p) % n].copy()
    assign = None
    for _ in range(max(int(iters), 1)):
        assign = np.argmax(m @ cent.T, axis=1)                  # (n,)
        for b in range(p):
            rows = m[assign == b]
            if len(rows):
                c = rows.sum(axis=0)
                norm = np.linalg.norm(c)
                if norm > 0:
                    cent[b] = c / norm
    counts = np.bincount(assign, minlength=p).astype(np.int32)
    m_max = max(int(counts.max()), 1)
    members = np.full((p, m_max), -1, np.int32)
    order = np.argsort(assign, kind="stable")     # ascending ids per block
    offs = np.zeros(p, np.int32)
    for gid in order:
        b = assign[gid]
        members[b, offs[b]] = gid
        offs[b] += 1
    return CentroidIndex(cent, members, counts, n)


def _survivor_blocks(index: CentroidIndex, scores: np.ndarray,
                     nprobe: int, min_candidates: int) -> np.ndarray:
    """Union of each query's top-``nprobe`` blocks, grown (best coarse
    score first) until it holds at least ``min_candidates`` rows — so a
    tiny nprobe can never starve the rerank below k candidates."""
    p = index.n_blocks
    nprobe = min(int(nprobe), p)
    top = np.argpartition(-scores, nprobe - 1, axis=1)[:, :nprobe] \
        if nprobe < p else np.tile(np.arange(p), (scores.shape[0], 1))
    survivors = np.unique(top)
    have = int(index.counts[survivors].sum())
    if have < min_candidates:
        rest = np.setdiff1d(np.arange(p), survivors, assume_unique=True)
        rest = rest[np.argsort(-scores.max(axis=0)[rest], kind="stable")]
        for b in rest:
            survivors = np.append(survivors, b)
            have += int(index.counts[b])
            if have >= min_candidates:
                break
        survivors = np.sort(survivors)
    return survivors


def two_stage_topk(query_emb, matrix_or_gather, index: CentroidIndex,
                   k: int, *, nprobe: Union[int, str, None] = None,
                   inv_tau=1.0, interpret: Optional[bool] = None,
                   bm: Optional[int] = None, bc: Optional[int] = None):
    """Coarse-prune + exact-rerank top-k.

    query_emb: (b, d). matrix_or_gather: the materialized (n, d) matrix,
    or a ``gather(ids) -> (len(ids), d)`` callback for galleries that
    stream blocks. nprobe: blocks probed per query; ``None``/``"all"``/
    ``>= n_blocks`` is the exactness escape hatch (≡ the stage-A answer).
    Returns (values (b, k) fp32, indices (b, k) int32 GLOBAL ids, info)
    where info carries the prune telemetry: ``n_candidates``,
    ``n_blocks_probed``, ``prune_ratio`` (candidates/n, 1.0 = no prune),
    and per-stage seconds (``coarse_s``, ``gather_s``, ``rerank_s``).
    """
    q = np.asarray(query_emb, np.float32)
    n = index.n
    k = int(k)
    if not 1 <= k <= n:
        raise ValueError(f"k={k} must be in [1, n={n}]")
    if nprobe is None or nprobe == "all":
        nprobe = index.n_blocks
    nprobe = int(nprobe)
    if nprobe < 1:
        raise ValueError(f"nprobe={nprobe} must be >= 1 (or 'all')")

    t0 = time.perf_counter()
    scores = q @ index.centroids.T                       # (b, P) — coarse
    survivors = _survivor_blocks(index, scores, nprobe, k)
    coarse_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    if len(survivors) == index.n_blocks:
        cand_ids = np.arange(n, dtype=np.int32)          # identity table
    else:
        cand_ids = np.sort(np.concatenate(
            [index.block_members(b) for b in survivors]))
    if callable(matrix_or_gather):
        rows = matrix_or_gather(cand_ids)
    elif len(cand_ids) == n:
        rows = matrix_or_gather                          # full survival
    else:
        rows = np.asarray(matrix_or_gather)[cand_ids]
    gather_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    vals, loc = topk_ops.similarity_topk(
        jnp.asarray(q), jnp.asarray(rows), min(k, len(cand_ids)),
        inv_tau=inv_tau, bm=bm, bc=bc, interpret=interpret)
    gidx = cand_ids[np.asarray(loc)].astype(np.int32)
    rerank_s = time.perf_counter() - t0

    info = {"n_candidates": int(len(cand_ids)),
            "n_blocks_probed": int(len(survivors)),
            "prune_ratio": float(len(cand_ids) / n),
            "coarse_s": coarse_s, "gather_s": gather_s,
            "rerank_s": rerank_s}
    return np.asarray(vals), gidx, info
