"""LR schedules (paper Table 6: linear warmup -> cosine or linear decay)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(max_lr, min_lr, warmup_steps, total_steps):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = max_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = min_lr + 0.5 * (max_lr - min_lr) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def warmup_linear(max_lr, min_lr, warmup_steps, total_steps):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = max_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        lin = max_lr + (min_lr - max_lr) * t
        return jnp.where(step < warmup_steps, warm, lin)
    return lr
