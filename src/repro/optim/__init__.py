from repro.optim.adafactorw import AdaFactorW, apply_updates  # noqa: F401
from repro.optim.schedules import warmup_cosine, warmup_linear  # noqa: F401
