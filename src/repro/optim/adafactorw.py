"""AdaFactorW (paper App. B): AdaFactor's factored second moment +
AdamW's decoupled weight decay + bf16-stored / f32-used first moment.

Pure-JAX optimizer in the (init, update) style:

    state = init(params)
    updates, state = update(grads, state, params, lr)
    params = apply_updates(params, updates)

Second moments of matrices (ndim >= 2, both trailing dims >= factored_threshold)
are stored as row/col running means (AdaFactor); smaller tensors keep a full
second moment. The first moment is stored in bfloat16 and cast to f32 before
use (paper: "we can *store* these moments in bfloat16, [but] convert them into
float32 prior to computing our weight updates").

``update_from_microbatches`` wires in core/moment_accum.py: the microbatch
gradient stream is folded straight into the moment slots (paper §4.2) without
ever allocating the averaged gradient ḡ. (Factored v2 rows/cols are linear in
g², so the E[c²] accumulation is exact for them.)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import moment_accum as ma


class AdaFactorWState(NamedTuple):
    step: jax.Array
    m: dict        # first moment, bf16 leaves
    v_row: dict    # factored second-moment rows (or full v for small leaves)
    v_col: dict    # factored cols (zeros placeholder for unfactored leaves)


def _factored(x, threshold):
    return x.ndim >= 2 and x.shape[-1] >= threshold and x.shape[-2] >= threshold


class AdaFactorW:
    def __init__(self, beta1=0.9, beta2=0.99, eps=1e-30, weight_decay=0.0,
                 clip_threshold=1.0, factored_threshold=128,
                 store_m_bf16=True):
        self.beta1, self.beta2, self.eps = beta1, beta2, eps
        self.weight_decay = weight_decay
        self.clip_threshold = clip_threshold
        self.factored_threshold = factored_threshold
        self.store_m_bf16 = store_m_bf16

    # -- state ------------------------------------------------------------
    def init(self, params):
        mdt = jnp.bfloat16 if self.store_m_bf16 else jnp.float32
        m = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params)

        def vrow(p):
            if _factored(p, self.factored_threshold):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros_like(p, dtype=jnp.float32)

        def vcol(p):
            if _factored(p, self.factored_threshold):
                return jnp.zeros((*p.shape[:-2], p.shape[-1]), jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdaFactorWState(step=jnp.zeros((), jnp.int32),
                               m=jax.tree.map(lambda x: x, m),
                               v_row=jax.tree.map(vrow, params),
                               v_col=jax.tree.map(vcol, params))

    # -- core update ------------------------------------------------------
    def _precondition(self, g, vr, vc, p):
        if _factored(p, self.factored_threshold):
            r = vr[..., None]                                # (..., rows, 1)
            c = vc[..., None, :]                             # (..., 1, cols)
            denom = jnp.sqrt(r * c / jnp.maximum(
                jnp.mean(vr, axis=-1, keepdims=True)[..., None], self.eps))
            return g / jnp.maximum(denom, jnp.sqrt(self.eps))
        return g / jnp.sqrt(vr + self.eps)

    def _new_v(self, g, vr, vc, p):
        g2 = g.astype(jnp.float32) ** 2 + self.eps
        if _factored(p, self.factored_threshold):
            nvr = self.beta2 * vr + (1 - self.beta2) * jnp.mean(g2, axis=-1)
            nvc = self.beta2 * vc + (1 - self.beta2) * jnp.mean(g2, axis=-2)
            return nvr, nvc
        return self.beta2 * vr + (1 - self.beta2) * g2, vc

    def update(self, grads, state: AdaFactorWState, params, lr):
        step = state.step + 1

        def upd(g, m, vr, vc, p):
            g = g.astype(jnp.float32)
            nvr, nvc = self._new_v(g, vr, vc, p)
            # f32 math on the bf16-stored first moment (paper App. B)
            nm = self.beta1 * m.astype(jnp.float32) + (1 - self.beta1) * g
            u = self._precondition(nm, nvr, nvc, p)
            # RMS update clipping (AdaFactor)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), nm.astype(m.dtype), nvr, nvc

        flat = jax.tree.map(upd, grads, state.m, state.v_row, state.v_col,
                            params)
        updates = jax.tree.map(lambda t: t[0], flat,
                               is_leaf=lambda t: isinstance(t, tuple))
        nm = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
        nvr = jax.tree.map(lambda t: t[2], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
        nvc = jax.tree.map(lambda t: t[3], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
        return updates, AdaFactorWState(step=step, m=nm, v_row=nvr, v_col=nvc)

    # -- paper §4.2: fold a microbatch gradient stream into the slots ------
    def update_from_microbatches(self, c_stream, state: AdaFactorWState,
                                 params, lr, var_hat=None):
        """c_stream: leaves (K, ...) — the Algorithm-1 'Yields' stream. The
        first moment uses the exact K-step decomposition; the second moment
        uses the E[c²]−VarHat estimator (exact for factored rows/cols up to
        the same variance correction)."""
        step = state.step + 1
        m32 = jax.tree.map(lambda m: m.astype(jnp.float32), state.m)
        nm = ma.accumulate_first_moment(m32, c_stream, self.beta1)

        def v_update(c, vr, vc, p, vh):
            g2 = jnp.mean(c.astype(jnp.float32) ** 2, axis=0) + self.eps
            g2 = jnp.maximum(g2 - vh, self.eps)   # paper Eq. 4 correction
            if _factored(p, self.factored_threshold):
                nvr = self.beta2 * vr + (1 - self.beta2) * jnp.mean(g2, -1)
                nvc = self.beta2 * vc + (1 - self.beta2) * jnp.mean(g2, -2)
                return nvr, nvc
            return self.beta2 * vr + (1 - self.beta2) * g2, vc

        vh_tree = var_hat if var_hat is not None else jax.tree.map(
            lambda _: jnp.zeros((), jnp.float32), params)
        flat = jax.tree.map(v_update, c_stream, state.v_row, state.v_col,
                            params, vh_tree)
        nvr = jax.tree.map(lambda t: t[0], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
        nvc = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))

        def upd(m, vr, vc, p):
            u = self._precondition(m, vr, vc, p)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, nm, nvr, nvc, params)
        mdt = jnp.bfloat16 if self.store_m_bf16 else jnp.float32
        nm = jax.tree.map(lambda x: x.astype(mdt), nm)
        return updates, AdaFactorWState(step=step, m=nm, v_row=nvr, v_col=nvc)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)
