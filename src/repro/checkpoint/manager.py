"""Async checkpoint manager: hide serialization behind the train step.

A blocking ``io.save`` stalls the step for the full host-gather +
serialize + rename; at paper scale (3B params, week-long runs — PAPER.md
§5) that stall repeats every few minutes for the whole run. The manager
splits the save at the only true synchronization point (DESIGN.md §10.1):

  save_async(step, tree)  — ``io.snapshot`` (jax.device_get) runs on the
      calling thread (the train loop must not mutate donated buffers under
      an in-flight read), then serialize + hash + atomic rename happen on a
      background thread. The call returns as soon as the leaves are host
      copies — the measured stall is the BENCH_ckpt.json
      ``save/async_stall`` entry.

Ordering and failure contract:

  * writes are serialized: a new ``save``/``save_async``/``wait`` first
    joins the in-flight write, so step dirs appear in order and at most one
    background writer exists;
  * a failed background write is never silent: its exception is re-raised
    on the NEXT ``wait()``/``save*`` call (callers see the failure at the
    next checkpoint boundary, the train loop's natural recovery point);
  * each write attempt retries transient ``OSError`` with capped
    exponential backoff before giving up (``max_retries``/``backoff_s``);
  * ``sync=True`` degrades to the blocking path (the ``--ckpt-sync`` flag;
    also what the trainer flips to after a persistent async failure);
  * retention runs after every successful write on the same thread:
    ``keep_last`` newest steps survive plus every ``keep_every``-th
    "keep" step (0 disables retention entirely).
"""
from __future__ import annotations

import threading
import time

from repro.checkpoint import io
from repro.obs import metrics as obs_metrics

_STAT_KEYS = ("saves", "async_saves", "sync_saves", "retried_writes",
              "failed_writes", "gc_removed", "degraded")


class AsyncCheckpointManager:
    """Background-writing checkpointer with retry, deferred-error
    surfacing, and retention GC (see module docstring for the contract).
    Use as a context manager or call ``close()`` so the final write is
    joined before process exit.

    Telemetry (DESIGN.md §11): counters, the ``ckpt/write_latency_s``
    histogram (full serialize+hash+rename, observed on whichever thread
    writes) and the ``ckpt/last_stall_s`` gauge (how long the last
    ``save*`` held the CALLER — the step-path cost) live on an
    ``obs.metrics.Registry`` (``metrics`` attribute; pass ``registry=``
    to share the run's). The legacy dict-shaped ``stats`` accessor is a
    read-only view over those counters."""

    def __init__(self, directory: str, *, sync: bool = False,
                 keep_last: int = 0, keep_every: int = 0,
                 max_retries: int = 3, backoff_s: float = 0.05,
                 backoff_max_s: float = 1.0, registry=None):
        self.directory = directory
        self.sync = bool(sync)
        self.keep_last = int(keep_last)
        self.keep_every = int(keep_every)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.backoff_max_s = float(backoff_max_s)
        self._thread = None
        self._error = None
        self._error_step = None
        self.metrics = registry if registry is not None \
            else obs_metrics.Registry()
        self._c = {k: self.metrics.counter(f"ckpt/{k}") for k in _STAT_KEYS}
        self._h_write = self.metrics.histogram("ckpt/write_latency_s")
        self._g_stall = self.metrics.gauge("ckpt/last_stall_s")

    @property
    def stats(self) -> dict:
        """Dict-shaped counter view (the pre-§11 ad-hoc ``stats`` dict
        shape, now backed by the shared registry)."""
        return {k: int(c.value) for k, c in self._c.items()}

    def degrade_to_sync(self) -> None:
        """Flip to blocking saves permanently (the trainer's response to
        a persistent async-write failure) and count the transition."""
        if not self.sync:
            self.sync = True
            self._c["degraded"].inc()

    # -- lifecycle ---------------------------------------------------------
    @property
    def in_flight(self) -> bool:
        """True while a background write is still running."""
        return self._thread is not None and self._thread.is_alive()

    def wait(self) -> None:
        """Join the in-flight write (no-op when idle) and re-raise the
        deferred exception of a write that failed since the last call —
        the single point where background errors surface."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, step = self._error, self._error_step
            self._error = self._error_step = None
            raise io.CheckpointError(
                f"async checkpoint write for step {step} failed after "
                f"{self.max_retries + 1} attempts") from err

    def close(self) -> None:
        """Drain the in-flight write; raises if it failed."""
        self.wait()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        # don't mask an in-body exception with a pending write error
        if exc and exc[0] is not None:
            try:
                self.wait()
            except io.CheckpointError:
                pass
        else:
            self.close()
        return False

    # -- saving ------------------------------------------------------------
    def save(self, step: int, tree, meta=None):
        """Checkpoint ``tree`` at ``step``: asynchronously unless the
        manager is in ``sync`` mode. Joins (and surfaces errors of) any
        previous write first."""
        if self.sync:
            return self.save_sync(step, tree, meta=meta)
        return self.save_async(step, tree, meta=meta)

    def save_sync(self, step: int, tree, meta=None) -> str:
        """Blocking save (the degraded/final-checkpoint path): join any
        in-flight write, then snapshot + serialize + rename on the calling
        thread, with the same retry/backoff. Returns the step-dir path."""
        t0 = time.perf_counter()
        self.wait()
        arrs, treedef = io.snapshot(tree)
        path = self._write_with_retry(step, arrs, treedef, meta)
        self._gc()
        self._c["saves"].inc()
        self._c["sync_saves"].inc()
        self._g_stall.set(time.perf_counter() - t0)
        return path

    def save_async(self, step: int, tree, meta=None) -> None:
        """Snapshot leaves to host now; serialize + atomically rename on a
        background thread. Raises a previous write's deferred failure
        before snapshotting (in which case THIS save does not start —
        callers fall back, e.g. to ``save_sync``)."""
        t0 = time.perf_counter()
        self.wait()
        arrs, treedef = io.snapshot(tree)

        def work():
            try:
                self._write_with_retry(step, arrs, treedef, meta)
                self._gc()
            except BaseException as e:  # noqa: BLE001 — surfaced in wait()
                self._c["failed_writes"].inc()
                self._error, self._error_step = e, step
        self._thread = threading.Thread(target=work, daemon=True,
                                        name=f"ckpt-save-{step}")
        self._thread.start()
        self._c["saves"].inc()
        self._c["async_saves"].inc()
        self._g_stall.set(time.perf_counter() - t0)

    # -- internals ---------------------------------------------------------
    def _write_with_retry(self, step, arrs, treedef, meta):
        delay = self.backoff_s
        for attempt in range(self.max_retries + 1):
            t0 = time.perf_counter()
            try:
                path = io.write_snapshot(self.directory, step, arrs,
                                         treedef, meta=meta)
                self._h_write.observe(time.perf_counter() - t0)
                return path
            except OSError:
                if attempt == self.max_retries:
                    raise
                self._c["retried_writes"].inc()
                time.sleep(delay)
                delay = min(delay * 2.0, self.backoff_max_s)

    def _gc(self):
        if self.keep_last > 0:
            removed = io.gc_steps(self.directory, keep_last=self.keep_last,
                                  keep_every=self.keep_every)
            self._c["gc_removed"].inc(len(removed))
