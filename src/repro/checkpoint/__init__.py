from repro.checkpoint.io import latest_step, restore, save  # noqa: F401
