from repro.checkpoint.io import (  # noqa: F401
    CheckpointError,
    gc_steps,
    gc_tmp_dirs,
    latest_step,
    latest_verified_step,
    load_meta,
    restore,
    save,
    verify,
)
from repro.checkpoint.manager import AsyncCheckpointManager  # noqa: F401
