"""Deterministic fault injectors for the checkpoint fault-tolerance
harness (DESIGN.md §10.4).

Two families:

  * WRITE-PATH injectors (context managers) ride the ``io`` write fault
    hook and fire on the Nth file-write of a save: ``failing_writes``
    raises ``OSError`` (exercises the manager's retry/backoff + the
    trainer's sync fallback), ``exit_during_write`` calls ``os._exit``
    (a SIGKILL-equivalent: the process dies mid-save leaving a torn
    ``.tmp_ckpt_*`` dir, exactly what host preemption produces).

  * ON-DISK corruptors mutate a COMPLETED step dir the way real storage
    failures do: ``truncate_leaf`` (short read/torn page),
    ``flip_byte`` (bit rot — size unchanged, only the hash catches it),
    ``tamper_index_hash`` (bad metadata), ``leftover_tmp`` (stale
    partial-save dir). ``verify``/``latest_verified_step`` must reject or
    skip every one of them.

All injectors are process-local and deterministic — tests/distributed_checks.py
``ckpt_fault`` uses them to prove a killed-and-resumed training run replays
the uninterrupted run's losses bit-exactly.
"""
from __future__ import annotations

import contextlib
import json
import os

from repro.checkpoint import io


@contextlib.contextmanager
def failing_writes(n: int = 1, *, message: str = "injected I/O failure"):
    """Make the next ``n`` checkpoint file-writes raise ``OSError`` (then
    heal). Yields a one-key dict ``{"fired": count}`` so tests can check
    how many faults actually triggered."""
    state = {"fired": 0}

    def hook(path):
        if state["fired"] < n:
            state["fired"] += 1
            raise OSError(f"{message} (write #{state['fired']}: {path})")
    prev = io.set_write_fault_hook(hook)
    try:
        yield state
    finally:
        io.set_write_fault_hook(prev)


@contextlib.contextmanager
def exit_during_write(after: int = 0, *, code: int = 17):
    """Kill the process (``os._exit`` — no cleanup, no atexit, the closest
    in-process stand-in for SIGKILL/preemption) on the ``after+1``-th
    checkpoint file-write. The save in progress leaves a torn
    ``.tmp_ckpt_*`` dir behind; the parent recognizes the death by exit
    ``code``."""
    state = {"writes": 0}

    def hook(path):
        state["writes"] += 1
        if state["writes"] > after:
            os._exit(code)
    prev = io.set_write_fault_hook(hook)
    try:
        yield state
    finally:
        io.set_write_fault_hook(prev)


def _leaf_path(directory: str, step: int, leaf: int) -> str:
    return os.path.join(directory, f"step_{step:08d}", f"arr_{leaf}.npy")


def truncate_leaf(directory: str, step: int, leaf: int = 0,
                  keep_bytes: int = 8) -> str:
    """Truncate ``arr_<leaf>.npy`` of a completed step to ``keep_bytes``
    bytes (a torn write / short read). Returns the mutated path."""
    path = _leaf_path(directory, step, leaf)
    with open(path, "r+b") as f:
        f.truncate(keep_bytes)
    return path


def flip_byte(directory: str, step: int, leaf: int = 0,
              offset: int = -1) -> str:
    """XOR one byte of ``arr_<leaf>.npy`` (bit rot: the file size stays
    right, only the recorded sha256 can catch it). ``offset`` indexes from
    the end when negative. Returns the mutated path."""
    path = _leaf_path(directory, step, leaf)
    size = os.path.getsize(path)
    pos = offset % size
    with open(path, "r+b") as f:
        f.seek(pos)
        b = f.read(1)
        f.seek(pos)
        f.write(bytes([b[0] ^ 0xFF]))
    return path


def tamper_index_hash(directory: str, step: int, leaf: int = 0) -> str:
    """Rewrite ``index.json`` with a wrong sha256 for ``leaf`` (corrupt
    metadata: the leaf file itself is intact but can no longer be
    trusted). Returns the index path."""
    path = os.path.join(directory, f"step_{step:08d}", "index.json")
    with open(path) as f:
        index = json.load(f)
    index["leaves"][leaf]["sha256"] = "0" * 64
    with open(path, "w") as f:
        json.dump(index, f)
    return path


def leftover_tmp(directory: str, *, n_files: int = 2) -> str:
    """Plant a stale ``.tmp_ckpt_*`` dir with partial leaf files — what a
    crash mid-save leaves behind. ``latest_verified_step`` must GC it.
    Returns the planted path."""
    import tempfile
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=io.TMP_PREFIX)
    for i in range(n_files):
        with open(os.path.join(tmp, f"arr_{i}.npy"), "wb") as f:
            f.write(b"\x93NUMPY torn" * 3)
    return tmp
