"""Checkpointing: pytree save/restore with an index, atomic writes, and
sharded-array support (each leaf gathered to host as numpy; restore re-places
onto the provided shardings).

Layout:  <dir>/step_<N>/
            index.json      — tree structure + leaf dtypes/shapes
            arr_<i>.npy     — one file per leaf
            user_meta.json  — optional JSON sidecar (``save(..., meta=...)``)

``meta`` rides inside the same atomic rename as the arrays, so a step dir
either has its full user metadata (e.g. resumable loader input state,
DESIGN.md §9) or doesn't exist — never a torn pair.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _leaf_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree, meta=None) -> str:
    """Write ``tree`` as ``<directory>/step_<N>/`` atomically. ``meta``:
    optional JSON-serializable dict stored as ``user_meta.json`` in the
    same rename (read back with ``load_meta``)."""
    final = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        leaves, treedef = _leaf_paths(tree)
        index = {"treedef": str(treedef), "n": len(leaves), "step": step,
                 "leaves": []}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            dtype_name = str(arr.dtype)
            if dtype_name == "bfloat16":  # np.save can't store ml_dtypes
                np.save(os.path.join(tmp, f"arr_{i}.npy"),
                        arr.view(np.uint16))
            else:
                np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            index["leaves"].append({"dtype": dtype_name,
                                    "shape": list(arr.shape)})
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        if meta is not None:
            with open(os.path.join(tmp, "user_meta.json"), "w") as f:
                json.dump(meta, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def load_meta(directory: str, step: int):
    """The ``user_meta.json`` sidecar of a step dir, or None when the
    checkpoint was saved without one (pre-meta checkpoints stay loadable)."""
    path = os.path.join(directory, f"step_{step:08d}", "user_meta.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(directory: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings to place leaves onto."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "index.json")) as f:
        meta = json.load(f)
    like_leaves, treedef = _leaf_paths(like)
    assert meta["n"] == len(like_leaves), \
        f"checkpoint has {meta['n']} leaves, target has {len(like_leaves)}"
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(like_leaves))
    for i, (ref, sh) in enumerate(zip(like_leaves, shard_leaves)):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        if meta["leaves"][i]["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        expect = tuple(np.shape(ref))
        assert tuple(arr.shape) == expect, (i, arr.shape, expect)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
