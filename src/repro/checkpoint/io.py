"""Checkpointing: pytree save/restore with an integrity-verified index,
atomic writes, and sharded-array support (each leaf gathered to host as
numpy; restore re-places onto the provided shardings).

Layout:  <dir>/step_<N>/
            index.json      — tree structure + per-leaf dtype/shape and the
                              INTEGRITY record: sha256 + byte size of every
                              ``arr_<i>.npy`` as written (DESIGN.md §10.2)
            arr_<i>.npy     — one file per leaf
            user_meta.json  — optional JSON sidecar (``save(..., meta=...)``)

``meta`` rides inside the same atomic rename as the arrays, so a step dir
either has its full user metadata (e.g. resumable loader input state,
DESIGN.md §9) or doesn't exist — never a torn pair. The async manager
(checkpoint/manager.py) reuses the ``snapshot``/``write_snapshot`` split:
snapshot on the caller's thread, serialize + rename on a background one.

Validation never uses ``assert`` (gone under ``python -O``): every
corrupt/mismatched/missing condition raises ``CheckpointError`` naming the
offending leaf. ``verify`` replays the recorded hashes; ``latest_verified_step``
walks steps newest→oldest to the most recent checkpoint that passes,
garbage-collecting stale ``.tmp_ckpt_*`` dirs a crash mid-save left behind.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile

import jax
import numpy as np

TMP_PREFIX = ".tmp_ckpt_"
INDEX_FORMAT = 2          # 1: no hashes (pre-integrity); 2: sha256 + bytes

# test-only fault hook (checkpoint/faults.py): called with the path of every
# file about to be written and before the final rename — raising simulates a
# transient I/O failure, os._exit a hard kill mid-save
_write_fault_hook = None


class CheckpointError(Exception):
    """A checkpoint is missing, torn, corrupt, or does not match the target
    structure. Raised by ``restore``/``verify`` instead of ``assert`` so
    validation survives ``python -O``; the message names the offending leaf
    index and the expected-vs-found shape/count/hash."""


def set_write_fault_hook(hook):
    """Install (or clear, with None) the test-only write fault hook; returns
    the previous hook. The hook is invoked as ``hook(path)`` before every
    file write and before the atomic rename (path then ends in the final
    step-dir name) — checkpoint/faults.py builds its deterministic
    injectors (fail-Nth-write, die-mid-save) on top of this."""
    global _write_fault_hook
    prev, _write_fault_hook = _write_fault_hook, hook
    return prev


def _fault(path: str) -> None:
    if _write_fault_hook is not None:
        _write_fault_hook(path)


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def snapshot(tree):
    """Gather every leaf of ``tree`` to host memory — the only part of a
    save that must run synchronously with respect to the training loop.
    Returns ``(host numpy leaves, treedef)`` ready for ``write_snapshot``
    on any thread."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return [np.asarray(jax.device_get(leaf)) for leaf in leaves], treedef


def write_snapshot(directory: str, step: int, arrs, treedef,
                   meta=None) -> str:
    """Serialize a host snapshot as ``<directory>/step_<N>/`` atomically:
    every ``arr_<i>.npy`` plus its sha256/byte-size index entry is written
    into a ``.tmp_ckpt_*`` dir which is renamed into place only once
    complete — a crash at any point leaves either the previous state or a
    stale tmp dir (GC'd by ``latest_verified_step``), never a torn step."""
    final = _step_dir(directory, step)
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=TMP_PREFIX)
    try:
        index = {"treedef": str(treedef), "n": len(arrs), "step": step,
                 "format": INDEX_FORMAT, "leaves": []}
        for i, arr in enumerate(arrs):
            arr = np.asarray(arr)
            path = os.path.join(tmp, f"arr_{i}.npy")
            dtype_name = str(arr.dtype)
            _fault(path)
            if dtype_name == "bfloat16":  # np.save can't store ml_dtypes
                np.save(path, arr.view(np.uint16))
            else:
                np.save(path, arr)
            index["leaves"].append({
                "dtype": dtype_name, "shape": list(arr.shape),
                "bytes": os.path.getsize(path),
                "sha256": _sha256_file(path)})
        _fault(os.path.join(tmp, "index.json"))
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        if meta is not None:
            _fault(os.path.join(tmp, "user_meta.json"))
            with open(os.path.join(tmp, "user_meta.json"), "w") as f:
                json.dump(meta, f, indent=1)
        _fault(final)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def save(directory: str, step: int, tree, meta=None) -> str:
    """Write ``tree`` as ``<directory>/step_<N>/`` atomically (blocking:
    snapshot + serialize + rename on the calling thread — the async path is
    ``checkpoint.manager.AsyncCheckpointManager``). ``meta``: optional
    JSON-serializable dict stored as ``user_meta.json`` in the same rename
    (read back with ``load_meta``)."""
    arrs, treedef = snapshot(tree)
    return write_snapshot(directory, step, arrs, treedef, meta=meta)


def load_meta(directory: str, step: int):
    """The ``user_meta.json`` sidecar of a step dir, or None when the
    checkpoint was saved without one (pre-meta checkpoints stay loadable)."""
    path = os.path.join(_step_dir(directory, step), "user_meta.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _list_steps(directory: str):
    return sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                  if d.startswith("step_"))


def latest_step(directory: str):
    """Newest step number present on disk (no integrity check — prefer
    ``latest_verified_step`` for auto-resume), or None."""
    if not os.path.isdir(directory):
        return None
    steps = _list_steps(directory)
    return steps[-1] if steps else None


def verify(directory: str, step: int) -> dict:
    """Replay the integrity record of ``<directory>/step_<N>/``: the index
    must parse, every ``arr_<i>.npy`` must exist with the recorded byte size
    and sha256. Returns the parsed index on success; raises
    ``CheckpointError`` naming the first offending leaf otherwise.
    Format-1 checkpoints (written before hashes existed) verify existence
    and leaf count only."""
    path = _step_dir(directory, step)
    if not os.path.isdir(path):
        raise CheckpointError(f"no checkpoint dir at {path}")
    try:
        with open(os.path.join(path, "index.json")) as f:
            index = json.load(f)
    except FileNotFoundError:
        raise CheckpointError(f"{path}: missing index.json (torn write?)") \
            from None
    except (json.JSONDecodeError, OSError) as e:
        raise CheckpointError(f"{path}: unreadable index.json: {e}") from e
    leaves = index.get("leaves")
    if not isinstance(leaves, list) or index.get("n") != len(leaves):
        raise CheckpointError(
            f"{path}: index.json inconsistent: n={index.get('n')} vs "
            f"{len(leaves) if isinstance(leaves, list) else 'no'} leaf "
            f"records")
    for i, leaf in enumerate(leaves):
        apath = os.path.join(path, f"arr_{i}.npy")
        if not os.path.exists(apath):
            raise CheckpointError(f"{path}: leaf {i} missing ({apath})")
        want_bytes = leaf.get("bytes")
        if want_bytes is not None:
            found = os.path.getsize(apath)
            if found != want_bytes:
                raise CheckpointError(
                    f"{path}: leaf {i} truncated/resized: expected "
                    f"{want_bytes} bytes, found {found}")
        want_sha = leaf.get("sha256")
        if want_sha is not None:
            found_sha = _sha256_file(apath)
            if found_sha != want_sha:
                raise CheckpointError(
                    f"{path}: leaf {i} content hash mismatch: expected "
                    f"{want_sha[:12]}…, found {found_sha[:12]}…")
    return index


def gc_tmp_dirs(directory: str) -> list:
    """Remove stale ``.tmp_ckpt_*`` dirs a crash mid-save left behind;
    returns the removed paths. Only call when no async save is in flight
    (the manager and ``latest_verified_step`` — which runs at resume time,
    before any save starts — respect this)."""
    if not os.path.isdir(directory):
        return []
    removed = []
    for d in os.listdir(directory):
        if d.startswith(TMP_PREFIX):
            path = os.path.join(directory, d)
            shutil.rmtree(path, ignore_errors=True)
            removed.append(path)
    return removed


def latest_verified_step(directory: str, *, gc: bool = True):
    """Newest step whose checkpoint passes ``verify``, walking newest→oldest
    and skipping torn/corrupt steps — the auto-resume entry point: it always
    lands on a checkpoint that will restore. ``gc`` (default) also removes
    stale ``.tmp_ckpt_*`` dirs. Returns None when no step verifies."""
    if not os.path.isdir(directory):
        return None
    if gc:
        gc_tmp_dirs(directory)
    for step in reversed(_list_steps(directory)):
        try:
            verify(directory, step)
            return step
        except CheckpointError:
            continue
    return None


def gc_steps(directory: str, *, keep_last: int, keep_every: int = 0) -> list:
    """Retention policy: delete step dirs beyond the newest ``keep_last``,
    except "keep" steps divisible by ``keep_every`` (0 = no keep steps).
    Returns the deleted step numbers. ``keep_last`` must be >= 1 — the
    newest checkpoint is never collected."""
    if keep_last < 1:
        raise CheckpointError(f"keep_last must be >= 1, got {keep_last}")
    if not os.path.isdir(directory):
        return []
    steps = _list_steps(directory)
    keep = set(steps[-keep_last:])
    if keep_every > 0:
        keep.update(s for s in steps if s % keep_every == 0)
    dropped = [s for s in steps if s not in keep]
    for s in dropped:
        shutil.rmtree(_step_dir(directory, s), ignore_errors=True)
    return dropped


def restore(directory: str, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings to place leaves onto. Raises ``CheckpointError`` (never
    a bare assert/FileNotFoundError) on a missing step, leaf-count
    mismatch, unreadable leaf file, or per-leaf shape mismatch."""
    path = _step_dir(directory, step)
    try:
        with open(os.path.join(path, "index.json")) as f:
            meta = json.load(f)
    except FileNotFoundError:
        raise CheckpointError(f"no checkpoint at {path} (missing "
                              f"index.json)") from None
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if meta["n"] != len(like_leaves):
        raise CheckpointError(
            f"{path}: checkpoint has {meta['n']} leaves, target structure "
            f"has {len(like_leaves)}")
    out = []
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(like_leaves))
    for i, (ref, sh) in enumerate(zip(like_leaves, shard_leaves)):
        apath = os.path.join(path, f"arr_{i}.npy")
        try:
            arr = np.load(apath)
        except (FileNotFoundError, OSError, ValueError) as e:
            raise CheckpointError(
                f"{path}: leaf {i} unreadable ({apath}): "
                f"{type(e).__name__}: {e}") from e
        if meta["leaves"][i]["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        expect = tuple(np.shape(ref))
        if tuple(arr.shape) != expect:
            raise CheckpointError(
                f"{path}: leaf {i} shape mismatch: checkpoint has "
                f"{tuple(arr.shape)}, target expects {expect}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
