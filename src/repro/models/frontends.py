"""Modality frontends + per-(arch, shape) input specs.

Vision is a REAL frontend (DESIGN.md §8): raw ``(b, H, W, C)`` images are
linear-patchified (non-overlapping ``patch_size`` windows — exactly a
stride-``patch_size`` conv — projected to ``d_model``) into the image tower;
``ArchConfig.image_size/patch_size/channels`` pin the geometry and
``frontend_len == (image_size // patch_size) ** 2`` patches come out.
Position information rides on the tower's RoPE over patch index.

Audio (conv feature extractor) remains the one allowed STUB per the task
carve-out: ``input_specs`` provides precomputed frame embeddings.

``train_inputs_spec`` and ``synthetic_inputs`` are kept aligned BY
CONSTRUCTION: both derive every shape from the config (the historical
``P = min(frontend_len, seq // 4)`` drift is gone); a regression test pins
them equal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, InputShape
from repro.models import layers as L


def init_vision_frontend(key, cfg: ArchConfig) -> dict:
    """Patchify-projection parameters for a vision-frontend arch:
    {'patch_proj': (patch_size² · channels, d_model) fp32}."""
    pd = cfg.patch_size * cfg.patch_size * cfg.channels
    return {"patch_proj": L.dense_init(key, pd, cfg.d_model)}


def patchify(images, patch_size: int):
    """(b, H, W, C) -> (b, P, patch_size²·C) non-overlapping patches,
    row-major over the patch grid."""
    b, h, w, c = images.shape
    gh, gw = h // patch_size, w // patch_size
    x = images.reshape(b, gh, patch_size, gw, patch_size, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, gh * gw, patch_size * patch_size * c)


def patch_embed(p: dict, cfg: ArchConfig, images, dtype):
    """Linear patchify frontend: raw (b, H, W, C) images -> (b, frontend_len,
    d_model) patch embeddings in ``dtype`` (the compute dtype; the fp32
    params are cast at use like every other weight)."""
    x = patchify(images, cfg.patch_size).astype(dtype)
    assert x.shape[1] == cfg.frontend_len, \
        (x.shape, cfg.frontend_len, cfg.image_size, cfg.patch_size)
    return L.dense(x, p["patch_proj"])


def train_inputs_spec(cfg: ArchConfig, shape: InputShape, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins for one train/prefill batch of ``shape``.
    Vision archs consume raw images; vlm archs add text filling the rest of
    the sequence (``seq_len - frontend_len`` tokens)."""
    b, s = shape.global_batch, shape.seq_len
    SDS = jax.ShapeDtypeStruct
    if cfg.frontend == "vision":
        img = SDS((b, cfg.image_size, cfg.image_size, cfg.channels), dtype)
        if cfg.vocab > 0:            # vlm: patches + text filling the rest
            return {"image": img,
                    "tokens": SDS((b, s - cfg.frontend_len), jnp.int32)}
        return {"image": img}
    if cfg.family == "encoder":  # hubert: frame embeddings + masked targets
        return {
            "embeddings": SDS((b, s, cfg.d_model), dtype),
            "targets": SDS((b, s), jnp.int32),
            "mask": SDS((b, s), jnp.bool_),
        }
    return {"tokens": SDS((b, s), jnp.int32)}


def synthetic_inputs(cfg: ArchConfig, batch: int, seq: int,
                     rng: np.random.Generator, dtype=jnp.float32):
    """Concrete small batch matching ``train_inputs_spec`` leaf-for-leaf
    (smoke tests/examples): same keys, same shape arithmetic."""
    if cfg.frontend == "vision":
        img = jnp.asarray(rng.standard_normal(
            (batch, cfg.image_size, cfg.image_size, cfg.channels)), dtype)
        if cfg.vocab > 0:
            assert seq > cfg.frontend_len, (seq, cfg.frontend_len)
            return {"image": img, "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (batch, seq - cfg.frontend_len)),
                jnp.int32)}
        return {"image": img}
    if cfg.family == "encoder":
        return {
            "embeddings": jnp.asarray(
                rng.standard_normal((batch, seq, cfg.d_model)), dtype),
            "targets": jnp.asarray(
                rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32),
            "mask": jnp.asarray(rng.random((batch, seq)) < 0.3),
        }
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)}
