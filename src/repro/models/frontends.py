"""Modality frontend STUBS + per-(arch, shape) input specs.

Per the task carve-out, audio (conv feature extractor) and vision (ViT
encoder + projector) frontends are not implemented; ``input_specs`` provides
precomputed frame/patch embeddings of the right shape, and
``synthetic_inputs`` materializes small concrete batches for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, InputShape


def train_inputs_spec(cfg: ArchConfig, shape: InputShape, dtype=jnp.bfloat16):
    b, s = shape.global_batch, shape.seq_len
    SDS = jax.ShapeDtypeStruct
    if cfg.family == "encoder":  # hubert: frame embeddings + masked targets
        return {
            "embeddings": SDS((b, s, cfg.d_model), dtype),
            "targets": SDS((b, s), jnp.int32),
            "mask": SDS((b, s), jnp.bool_),
        }
    if cfg.frontend == "vision":  # vlm: patches + text filling the rest
        s_text = s - cfg.frontend_len
        return {
            "patch_embeddings": SDS((b, cfg.frontend_len, cfg.d_model), dtype),
            "tokens": SDS((b, s_text), jnp.int32),
        }
    return {"tokens": SDS((b, s), jnp.int32)}


def synthetic_inputs(cfg: ArchConfig, batch: int, seq: int, rng: np.random.Generator,
                     dtype=jnp.float32):
    """Concrete small batch matching train_inputs_spec (smoke tests/examples)."""
    if cfg.family == "encoder":
        return {
            "embeddings": jnp.asarray(
                rng.standard_normal((batch, seq, cfg.d_model)), dtype),
            "targets": jnp.asarray(
                rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32),
            "mask": jnp.asarray(rng.random((batch, seq)) < 0.3),
        }
    if cfg.frontend == "vision":
        P = min(cfg.frontend_len, max(1, seq // 4))
        return {
            "patch_embeddings": jnp.asarray(
                rng.standard_normal((batch, P, cfg.d_model)), dtype),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab, (batch, seq - P)), jnp.int32),
        }
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)}
