"""Mixed-precision policy for the tower runtime (DESIGN.md §8).

The paper trains its 3B towers in bfloat16 with fp32 "islands" where range
or accumulation matters. Instead of a single scattered ``dtype=`` argument,
the model stack threads one ``Precision`` object end-to-end:

  param_dtype    — dtype parameters are stored in (fp32 everywhere: the
                   optimizer owns master weights; casting happens at use)
  compute_dtype  — dtype of block matmuls/activations inside the towers
  accum_dtype    — dtype of softmax/log-sum-exp/pooling accumulation
                   (fp32 always; the Pallas kernels accumulate fp32
                   internally regardless)
  fp32_projections — run the lm head / dual-encoder embedding projections
                   (and hence the logits and unit-sphere embeddings) in
                   fp32 even when compute is bf16

Norms always compute in fp32 (layers.rms_norm casts internally) and norm
scales are stored fp32 — the policy object documents that invariant rather
than toggling it.

``resolve`` accepts a registry name ('f32' | 'bf16' | 'bf16_pure'), an
existing Precision, or a bare dtype (legacy ``dtype=`` call sites map to a
policy with that compute dtype and fp32 islands on).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Precision:
    """One mixed-precision policy threaded through the tower runtime."""
    name: str
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32
    fp32_projections: bool = True

    def compute(self, x):
        """Cast an activation into the block compute dtype."""
        return x.astype(self.compute_dtype)

    def accum(self, x):
        """Cast into the accumulation dtype (softmax/pooling/loss)."""
        return x.astype(self.accum_dtype)

    def project(self, x):
        """Cast into the projection dtype: fp32 when the policy keeps
        projections/logits in fp32, else the compute dtype."""
        return x.astype(jnp.float32 if self.fp32_projections
                        else self.compute_dtype)


POLICIES = {
    "f32": Precision("f32"),
    "bf16": Precision("bf16", compute_dtype=jnp.bfloat16),
    # ablation: projections/logits ride in bf16 too (norms stay fp32)
    "bf16_pure": Precision("bf16_pure", compute_dtype=jnp.bfloat16,
                           fp32_projections=False),
}


def list_policies() -> list:
    """Registered precision policy names (sorted)."""
    return sorted(POLICIES)


def resolve(precision: Union[Precision, str, None],
            dtype: Optional[Any] = None) -> Precision:
    """Resolve a policy argument: a Precision passes through; a registry
    name looks up POLICIES; None falls back to ``dtype`` (a legacy bare
    compute dtype → ad-hoc policy with fp32 islands) or 'f32'."""
    if isinstance(precision, Precision):
        return precision
    if isinstance(precision, str):
        try:
            return POLICIES[precision]
        except KeyError:
            raise KeyError(f"unknown precision policy {precision!r}; "
                           f"have {list_policies()}") from None
    if precision is not None:          # a bare dtype passed positionally
        dtype = precision
    if dtype is None:
        return POLICIES["f32"]
    dtype = jnp.dtype(dtype)
    for p in POLICIES.values():
        if jnp.dtype(p.compute_dtype) == dtype and p.fp32_projections:
            return p
    return Precision(f"compute_{dtype.name}", compute_dtype=dtype)
