"""Model assembly: periodic layer stacks scanned over depth.

Layers repeat with a *period* = lcm(attn interleave, MoE interleave) — e.g.
Jamba's period is 8 (7 mamba + 1 attn, MoE on odd positions). Parameters for
each position in the period are stacked on a leading (n_layers // period) axis
and the whole stack is applied with one ``jax.lax.scan``, so HLO size is
depth-independent (required for 80-layer dry-runs to compile quickly).

Entry points:
  init_params(cfg, rng)                  -> params pytree
  lm_loss(cfg, params, batch)            -> (loss, metrics)   [train_4k]
  prefill(cfg, params, batch)            -> (logits, caches)  [prefill_32k]
  decode_step(cfg, params, token, pos, caches) -> (logits, caches) [decode]
  encode(cfg, params, batch)             -> pooled (b, d)     [dual-encoder tower]

Every entry point takes ``precision`` — a models.precision policy (object,
registry name, or None) governing compute/accum/projection dtypes
end-to-end; the legacy ``dtype=`` argument maps to a policy with that
compute dtype (fp32 norms/projections stay on). Vision-frontend archs
consume raw ``batch['image']`` through models.frontends.patch_embed.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import frontends as fe
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import precision as prec_lib
from repro.models import ssm as ssm_lib


def period_of(cfg: ArchConfig) -> int:
    """Layer-stack period: lcm of attention and MoE interleaves (scan unit)."""
    p = cfg.attn_every if cfg.family == "hybrid" else 1
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.every)
    assert cfg.n_layers % p == 0, (cfg.name, cfg.n_layers, p)
    return p


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ArchConfig, kind: str, use_moe: bool, extra):
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    p = {"ln1": jnp.ones((*extra, d), jnp.float32)}
    if kind == "attn":
        p["attn"] = attn_lib.init_attn_params(k1, cfg, extra)
    else:
        p["mamba"] = ssm_lib.init_ssm_params(k1, cfg, extra)
    if cfg.family != "ssm":  # mamba2 blocks have no separate FFN
        p["ln2"] = jnp.ones((*extra, d), jnp.float32)
        if use_moe:
            p["moe"] = moe_lib.init_moe_params(k2, cfg, extra)
        else:
            ka, kb, kc = jax.random.split(k2, 3)
            p["ffn"] = {
                "wi": L.dense_init(ka, d, cfg.d_ff, extra),
                "wg": L.dense_init(kb, d, cfg.d_ff, extra),
                "wo": L.dense_init(kc, cfg.d_ff, d, extra),
            }
    return p


def init_params(cfg: ArchConfig, rng):
    """Full tower/LM params: scanned block stacks, final norm, frontend, embeddings/head."""
    period = period_of(cfg)
    n_periods = cfg.n_layers // period
    kinds = cfg.layer_kinds()[:period]
    moe_mask = cfg.moe_layer_mask()[:period]
    keys = jax.random.split(rng, period + 3)

    blocks = []
    for i in range(period):
        blocks.append(_init_block(keys[i], cfg, kinds[i], moe_mask[i],
                                  extra=(n_periods,)))
    params = {
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.frontend == "vision":
        params["frontend"] = fe.init_vision_frontend(keys[-3], cfg)
    if cfg.vocab > 0 and cfg.frontend != "audio":
        params["embed"] = L.trunc_normal(keys[-1], (cfg.vocab, cfg.d_model),
                                         cfg.d_model ** -0.5)
    if cfg.vocab > 0 and not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[-2], cfg.d_model, cfg.vocab)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _apply_block(cfg, kind, use_moe, p, h, positions, cache, decode, moe_args,
                 collect_cache_len=None, key_mask=None):
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        hn = L.rms_norm(h, p["ln1"], cfg.norm_eps)
        if decode:
            mix, new_cache = attn_lib.decode_attention(
                p["attn"], cfg, hn, cache, positions)
        elif collect_cache_len is not None:
            mix, (k, v) = attn_lib.attention(p["attn"], cfg, hn, positions,
                                             return_kv=True,
                                             key_mask=key_mask)
            new_cache = attn_lib.cache_from_prefill(cfg, k, v,
                                                    collect_cache_len)
        else:
            mix = attn_lib.attention(p["attn"], cfg, hn, positions,
                                     key_mask=key_mask)
            new_cache = None
    else:
        hn = L.rms_norm(h, p["ln1"], cfg.norm_eps)
        if decode:
            mix, new_cache = ssm_lib.mamba_decode(p["mamba"], cfg, hn, cache)
        else:
            mix, new_cache = ssm_lib.mamba_mixer(p["mamba"], cfg, hn, cache)
    h = h + mix
    if cfg.family != "ssm":
        hn = L.rms_norm(h, p["ln2"], cfg.norm_eps)
        if use_moe:
            out, aux = moe_lib.moe_ffn(p["moe"], cfg, hn, **moe_args)
        else:
            out = L.swiglu(hn, p["ffn"]["wi"], p["ffn"]["wg"], p["ffn"]["wo"])
        h = h + out
    return h, new_cache, aux


def forward(cfg: ArchConfig, params, h, positions, caches=None, decode=False,
            remat_policy=None, moe_args=None, collect_cache_len=None,
            unroll: int = 1, key_mask=None):
    """Run the full stack. h: (b, s, d). Returns (h, new_caches, aux_loss).

    caches: list (len=period) of stacked KV/SSM caches or None.
    remat_policy: optional jax.checkpoint policy applied per period-step.
    collect_cache_len: if set (prefill), build decode caches of this length.
    key_mask: optional (b, s) bool padding mask threaded into attention.
    """
    period = period_of(cfg)
    kinds = cfg.layer_kinds()[:period]
    moe_mask = cfg.moe_layer_mask()[:period]
    moe_args = moe_args or {}

    def period_step(h, sliced):
        blocks, caches_in = sliced
        new_caches, aux_total = [], jnp.zeros((), jnp.float32)
        for i in range(period):
            c = None if caches_in is None else caches_in[i]
            h, nc, aux = _apply_block(cfg, kinds[i], moe_mask[i], blocks[i], h,
                                      positions, c, decode, moe_args,
                                      collect_cache_len, key_mask)
            new_caches.append(nc)
            aux_total = aux_total + aux
        return h, (new_caches, aux_total)

    if remat_policy is not None:
        period_step = jax.checkpoint(period_step, policy=remat_policy)

    def scan_body(h, sliced):
        return period_step(h, sliced)

    xs = (params["blocks"], caches)
    if caches is None:
        # replace None with a per-step dummy so scan sees a consistent pytree
        xs = (params["blocks"],
              [jnp.zeros((cfg.n_layers // period,), jnp.float32)] * period)

        def scan_body(h, sliced):  # noqa: F811
            blocks, _ = sliced
            return period_step(h, (blocks, None))

    h, (new_caches, aux) = jax.lax.scan(scan_body, h, xs, unroll=unroll)
    if caches is None and collect_cache_len is None and not decode:
        new_caches = None
    return h, new_caches, jnp.sum(aux)


# ---------------------------------------------------------------------------
# Embedding / heads
# ---------------------------------------------------------------------------


def embed_inputs(cfg: ArchConfig, params, batch, dtype):
    """Returns (h (b, s, d), positions (b, s), text_mask (b, s) or None).

    Vision archs consume raw ``batch['image']`` (b, H, W, C) through the
    linear-patchify frontend (models.frontends); vlm archs append token
    embeddings after the patches (and accept token-only batches, e.g.
    text-only decode). Audio archs consume precomputed frame
    ``batch['embeddings']`` (the one remaining frontend stub)."""
    if cfg.frontend == "audio":
        h = batch["embeddings"].astype(dtype)           # (b, s, d) stub
        b, s, _ = h.shape
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        return h, pos, None
    if cfg.frontend == "vision" and "image" in batch:
        patches = fe.patch_embed(params["frontend"], cfg, batch["image"],
                                 dtype)                 # (b, P, d)
        b = patches.shape[0]
        if cfg.vocab > 0 and "tokens" in batch:         # vlm: patches + text
            tok = batch["tokens"]
            emb = jnp.take(params["embed"], tok, axis=0).astype(dtype)
            h = jnp.concatenate([patches, emb], axis=1)
            s = h.shape[1]
            pos = jnp.broadcast_to(jnp.arange(s), (b, s))
            text_mask = jnp.concatenate(
                [jnp.zeros((b, patches.shape[1]), bool),
                 jnp.ones((b, tok.shape[1]), bool)], axis=1)
            return h, pos, text_mask
        s = patches.shape[1]
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        return patches, pos, None
    tok = batch["tokens"]
    emb = jnp.take(params["embed"], tok, axis=0).astype(dtype)
    b, s = tok.shape
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    return emb, pos, None


def logits_from_h(cfg: ArchConfig, params, h, pol: prec_lib.Precision = None):
    """Vocabulary logits from hidden states; the precision policy decides
    whether the head matmul (and hence the logits) runs in fp32."""
    if pol is not None:
        h = pol.project(h)
    if cfg.tie_embeddings:
        w = params["embed"].astype(h.dtype)
        return jnp.einsum("bsd,vd->bsv", h, w)
    return L.dense(h, params["lm_head"])


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def lm_loss(cfg: ArchConfig, params, batch, *, dtype=jnp.float32,
            precision=None, remat_policy=None, moe_args=None,
            unroll: int = 1):
    """Training loss.

    decoder families: next-token CE over `tokens` (+`labels` if given).
    encoder (hubert): masked-frame CE over `targets` where `mask` is set.
    vlm: next-token CE on the text segment only.

    ``precision`` (policy object/name) governs compute/projection dtypes;
    the legacy ``dtype=`` maps to a policy with that compute dtype. The CE
    itself always accumulates fp32.
    """
    pol = prec_lib.resolve(precision, dtype)
    h, pos, text_mask = embed_inputs(cfg, params, batch, pol.compute_dtype)
    h, _, aux = forward(cfg, params, h, pos, remat_policy=remat_policy,
                        moe_args=moe_args, unroll=unroll)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)

    if cfg.family == "encoder":
        logits = logits_from_h(cfg, params, h, pol).astype(jnp.float32)
        targets = batch["targets"]                       # (b, s)
        mask = batch["mask"].astype(jnp.float32)         # (b, s)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        logits = logits_from_h(cfg, params, h, pol).astype(jnp.float32)
        if text_mask is not None:                        # vlm: text tail only
            logits = logits[:, cfg.frontend_len:, :]
        tokens = batch["tokens"]
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        if mask is not None:
            m = mask[:, 1:].astype(jnp.float32)
            loss = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
        else:
            loss = jnp.mean(nll)
    return loss + aux, {"xent": loss, "aux": aux}


def init_caches(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    """Stacked per-period-position caches for decode."""
    period = period_of(cfg)
    n_periods = cfg.n_layers // period
    kinds = cfg.layer_kinds()[:period]

    def stack(make):
        one = make()
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_periods, *x.shape)).copy(), one)

    caches = []
    for k in kinds:
        if k == "attn":
            caches.append(stack(
                lambda: attn_lib.init_kv_cache(cfg, batch, seq_len, dtype)))
        else:
            caches.append(stack(
                lambda: ssm_lib.init_ssm_cache(cfg, batch, dtype)))
    return caches


def prefill(cfg: ArchConfig, params, batch, *, dtype=jnp.bfloat16,
            precision=None, moe_args=None, collect_cache_len=None,
            unroll: int = 1):
    """Full forward emitting last-position logits; with ``collect_cache_len``
    also builds the decode caches (serving prefill). Returns logits or
    (logits, caches)."""
    pol = prec_lib.resolve(precision, dtype)
    h, pos, _ = embed_inputs(cfg, params, batch, pol.compute_dtype)
    h, caches, _ = forward(cfg, params, h, pos, moe_args=moe_args,
                           collect_cache_len=collect_cache_len, unroll=unroll)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    out = (logits_from_h(cfg, params, h[:, -1:, :], pol) if cfg.vocab > 0
           else h[:, -1:, :])
    if collect_cache_len is not None:
        return out, caches
    return out


def decode_step(cfg: ArchConfig, params, token, pos, caches, *,
                dtype=jnp.bfloat16, precision=None, moe_args=None,
                unroll: int = 1):
    """One decode step. token: (b, 1) int32; pos: scalar int32 (all rows
    at one position, the legacy engine) or (b,) int32 per-slot positions
    (continuous batching: every cache row advances at its own depth)."""
    pol = prec_lib.resolve(precision, dtype)
    h = jnp.take(params["embed"], token, axis=0).astype(pol.compute_dtype)
    h, new_caches, _ = forward(cfg, params, h, pos, caches=caches, decode=True,
                               moe_args=moe_args, unroll=unroll)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return logits_from_h(cfg, params, h, pol), new_caches


def encode(cfg: ArchConfig, params, batch, *, dtype=jnp.float32,
           precision=None, remat_policy=None):
    """Pooled representation for dual-encoder towers. Returns (b, d_model)
    in the policy's projection dtype (fp32 under the default policies).

    ``batch['attn_mask']`` (b, s) masks padded text positions BOTH inside
    attention (threaded to the backend as a key-padding mask) and in the
    mean pooling; pooling always accumulates in fp32."""
    pol = prec_lib.resolve(precision, dtype)
    h, pos, _ = embed_inputs(cfg, params, batch, pol.compute_dtype)
    mask = batch.get("attn_mask")
    h, _, _ = forward(cfg, params, h, pos, remat_policy=remat_policy,
                      key_mask=mask)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    h = pol.accum(h)
    if mask is not None:
        m = mask.astype(h.dtype)[..., None]
        pooled = jnp.sum(h * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)
    else:
        pooled = jnp.mean(h, axis=1)
    return pol.project(pooled)
