"""GQA attention: training/prefill (full-sequence) and single-token decode.

Supports: grouped-query heads, qk-norm (Qwen3), causal / bidirectional /
sliding-window / key-padding masks, RoPE, and two KV-cache layouts:
  - linear cache (full attention):  k/v (batch, kv_heads, S, head_dim) + pos
  - ring cache (sliding window):    same shape with S = window, written mod W

The full-sequence path runs through the ATTENTION BACKEND REGISTRY
(DESIGN.md §8): ``impl`` ∈ {'naive', 'chunked', 'pallas', 'auto'} resolved
per ArchConfig (``cfg.attn_impl``), with auto-detection of the platform and
graceful fallback when a backend cannot serve a shape. The 'pallas' backend
wires ``kernels/flash_attention`` (fwd + custom-VJP bwd kernels,
bf16-in/fp32-accum) into the encoder hot path.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

NEG_INF = -1e30


class KVCache(NamedTuple):
    """k/v: (batch, kv_heads, cache_len, head_dim), RoPE already applied.

    Ring-buffer addressing is *derived*, not stored: the cache is a ring iff
    the arch has a sliding window and cache_len == window (see ``is_ring``) —
    keeping the pytree free of static leaves so it jits cleanly.
    """
    k: jax.Array
    v: jax.Array


def is_ring(cfg: ArchConfig, cache: KVCache) -> bool:
    """True when the cache is ring-addressed: the arch slides a window and cache_len equals it."""
    return (cfg.sliding_window is not None
            and cache.k.shape[2] == cfg.sliding_window)


def init_attn_params(key, cfg: ArchConfig, extra=()):
    """Attention projection (+ optional qk-norm) params for one block."""
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(kq, cfg.d_model, cfg.n_heads * hd, extra),
        "wk": L.dense_init(kk, cfg.d_model, cfg.n_kv_heads * hd, extra),
        "wv": L.dense_init(kv, cfg.d_model, cfg.n_kv_heads * hd, extra),
        "wo": L.dense_init(ko, cfg.n_heads * hd, cfg.d_model, extra),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((*extra, hd), jnp.float32)
        p["k_norm"] = jnp.ones((*extra, hd), jnp.float32)
    return p


def _project_qkv(p, cfg: ArchConfig, x, positions):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.dense(x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = L.dense(x, p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = L.dense(x, p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(cfg: ArchConfig, q_pos, k_pos):
    """(q_len, k_len) additive mask from absolute positions."""
    m = jnp.zeros((q_pos.shape[-1], k_pos.shape[-1]), jnp.float32)
    if cfg.causal:
        m = jnp.where(k_pos[None, :] <= q_pos[:, None], m, NEG_INF)
    if cfg.sliding_window is not None:
        m = jnp.where(q_pos[:, None] - k_pos[None, :] < cfg.sliding_window,
                      m, NEG_INF)
    return m


def _key_bias(key_mask):
    """(b, t) bool / additive key-padding mask -> (b, 1, 1, 1, t) additive."""
    if key_mask.dtype == jnp.bool_:
        key_mask = jnp.where(key_mask, 0.0, NEG_INF)
    return key_mask.astype(jnp.float32)[:, None, None, None, :]


def _sdpa(q, k, v, mask, key_mask=None):
    """q: (b,s,h,hd); k/v: (b,t,kv,hd); mask: (s,t) additive;
    key_mask: optional (b,t) bool/additive padding mask."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    q = q.reshape(b, s, kv, group, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k) * (hd ** -0.5)
    scores = scores.astype(jnp.float32) + mask
    if key_mask is not None:
        scores = scores + _key_bias(key_mask)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, hd)


def _sdpa_chunked(q, k, v, mask, block: int, key_mask=None):
    """Flash-style chunked attention in pure XLA (lowerable on any backend —
    the dry-run stand-in for the Pallas kernel): scan over query blocks,
    scores live only per block, block fn checkpointed so the backward pass
    recomputes them instead of saving O(s²) residuals."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    nb = s // block
    qb = q.reshape(b, nb, block, h, hd).transpose(1, 0, 2, 3, 4)
    mb = mask.reshape(nb, block, mask.shape[-1])
    kb = None if key_mask is None else _key_bias(key_mask)

    @jax.checkpoint
    def blk(args):
        qi, mi = args
        qg = qi.reshape(b, block, kv, group, hd)
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) * (hd ** -0.5)
        scores = scores.astype(jnp.float32) + mi
        if kb is not None:
            scores = scores + kb
        w = jax.nn.softmax(scores, axis=-1).astype(qi.dtype)
        o = jnp.einsum("bkgst,btkd->bskgd", w, v)
        return o.reshape(b, block, h, hd)

    _, out = jax.lax.scan(lambda c, a: (c, blk(a)), None, (qb, mb))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


# ---------------------------------------------------------------------------
# Backend registry (DESIGN.md §8)
# ---------------------------------------------------------------------------


ATTN_BACKENDS = {}


def register_backend(name: str):
    """Decorator registering a full-sequence attention backend under
    ``name``. Backends take (q (b,s,h,hd), k/v (b,s,kv,hd)) plus keyword
    context and return (b,s,h,hd)."""
    def deco(fn):
        ATTN_BACKENDS[name] = fn
        return fn
    return deco


@register_backend("naive")
def _naive_backend(q, k, v, *, cfg, positions, key_mask, block):
    """Materialized-scores baseline (the paper-era implementation)."""
    mask = _mask(cfg, positions[0], positions[0])
    return _sdpa(q, k, v, mask, key_mask)


@register_backend("chunked")
def _chunked_backend(q, k, v, *, cfg, positions, key_mask, block):
    """Flash-style online blocks in pure XLA (any backend; remat'd)."""
    s = q.shape[1]
    mask = _mask(cfg, positions[0], positions[0])
    if s % min(block, s) != 0:          # ragged tail: fall back
        return _sdpa(q, k, v, mask, key_mask)
    return _sdpa_chunked(q, k, v, mask, min(block, s), key_mask)


@register_backend("pallas")
def _pallas_backend(q, k, v, *, cfg, positions, key_mask, block):
    """kernels/flash_attention: Pallas online-softmax fwd + blockwise bwd
    (custom VJP), bf16-in/fp32-accum; interpret mode auto-selected on CPU.
    Assumes positions are the standard arange (true for every train /
    encode / prefill call; decode uses its own path)."""
    from repro.kernels.flash_attention import ops as fa_ops
    out = fa_ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=cfg.causal,
        window=cfg.sliding_window, key_mask=key_mask,
        block_q=block, block_k=block)
    return out.transpose(0, 2, 1, 3)


def available_backends() -> tuple:
    """Registered full-sequence attention backend names."""
    return tuple(sorted(ATTN_BACKENDS))


def resolve_backend(impl: Optional[str], *, seq: int, head_dim: int,
                    platform: Optional[str] = None) -> str:
    """Resolve an ``attn_impl`` request to a registered backend name.

    'auto' (or None) picks 'pallas' on accelerators and 'chunked' on CPU
    hosts (where the Pallas kernel runs interpreted — correct but not the
    fast path for production shapes). An explicit 'pallas' request falls
    back to 'chunked' when the compiled kernel cannot serve the shape
    (head_dim not lane-aligned / seq not sublane-aligned on a real
    accelerator); interpret mode on CPU has no such constraint."""
    platform = platform or jax.default_backend()
    if impl in (None, "auto"):
        impl = "pallas" if platform in ("tpu", "gpu") else "chunked"
    if impl not in ATTN_BACKENDS:
        raise KeyError(f"unknown attention impl {impl!r}; "
                       f"have {available_backends()} + 'auto'")
    if impl == "pallas" and platform in ("tpu", "gpu") and (
            head_dim % 128 != 0 or seq % 8 != 0):
        return "chunked"
    return impl


def attention(p, cfg: ArchConfig, x, positions, return_kv: bool = False,
              impl: Optional[str] = None, block: Optional[int] = None,
              key_mask=None):
    """Full-sequence attention (train / prefill / encode). x: (b, s, d).

    impl: backend registry name ('naive' | 'chunked' | 'pallas' | 'auto');
    None defers to ``cfg.attn_impl``. key_mask: optional (b, s) bool mask
    (True = real token) masking padded key positions — threaded from the
    encoder towers' ``attn_mask``."""
    b, s, _ = x.shape
    impl = resolve_backend(impl if impl is not None else cfg.attn_impl,
                           seq=s, head_dim=cfg.resolved_head_dim)
    block = block if block is not None else cfg.attn_block
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = ATTN_BACKENDS[impl](q, k, v, cfg=cfg, positions=positions,
                              key_mask=key_mask, block=block)
    out = L.dense(out.reshape(b, s, -1), p["wo"])
    if return_kv:
        return out, (k, v)
    return out


def cache_from_prefill(cfg: ArchConfig, k, v, cache_len: int,
                       dtype=None) -> KVCache:
    """Build a decode cache from prefill k/v ((b, s, kv, hd), RoPE applied).

    Linear cache: k/v written at [0, s). Ring cache (SWA, cache_len == window
    <= s is possible): the last ``window`` positions are placed at their
    pos %% window slots so subsequent decode writes continue the ring."""
    import numpy as np
    b, s, kvh, hd = k.shape
    dtype = dtype or k.dtype
    k = k.transpose(0, 2, 1, 3).astype(dtype)   # (b, kv, s, hd)
    v = v.transpose(0, 2, 1, 3).astype(dtype)
    ring = cfg.sliding_window is not None and cache_len == cfg.sliding_window
    if ring and s >= cache_len:
        w = cache_len
        src = np.arange(s - w, s)               # source positions
        dest = src % w                          # their ring slots
        inv = np.argsort(dest)                  # slot i is filled from src[inv[i]]
        ksel = k[:, :, src[inv], :]
        vsel = v[:, :, src[inv], :]
        return KVCache(k=ksel, v=vsel)
    ck = jnp.zeros((b, kvh, cache_len, hd), dtype)
    cv = jnp.zeros((b, kvh, cache_len, hd), dtype)
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k[:, :, :cache_len], 0, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v[:, :, :cache_len], 0, axis=2)
    return KVCache(k=ck, v=cv)


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ArchConfig, batch: int, seq_len: int,
                  dtype=jnp.bfloat16) -> KVCache:
    """Zeroed decode KV cache: ring-sized when the window fits, else seq_len."""
    hd = cfg.resolved_head_dim
    ring = cfg.sliding_window is not None and cfg.sliding_window <= seq_len
    clen = cfg.sliding_window if ring else seq_len
    shape = (batch, cfg.n_kv_heads, clen, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def resolve_decode_backend(impl: Optional[str], *, cache_len: int,
                           head_dim: int,
                           platform: Optional[str] = None) -> str:
    """Resolve an ``attn_impl`` request to a DECODE backend ('einsum' |
    'pallas') — the single-token counterpart of ``resolve_backend``.

    'pallas' routes the cache sweep through ``kernels/decode_attention``
    (the GQA-grouped bandwidth kernel; interpret mode on CPU). 'auto'
    (or None) picks it on accelerators and keeps the fused-einsum path on
    CPU hosts, where the interpreted kernel is correct but not fast.
    'naive'/'chunked' are full-sequence notions — decode maps both to
    'einsum'. An explicit 'pallas' request falls back to 'einsum' when the
    kernel can't tile the cache (cache_len not divisible by a block, or
    head_dim not lane-aligned on a real accelerator)."""
    platform = platform or jax.default_backend()
    if impl in (None, "auto"):
        impl = "pallas" if platform in ("tpu", "gpu") else "einsum"
    if impl in ("naive", "chunked", "einsum"):
        return "einsum"
    if impl != "pallas":
        raise KeyError(f"unknown decode attention impl {impl!r}; "
                       f"have ('einsum', 'pallas') + 'auto'")
    block = min(256, cache_len)
    if cache_len % block != 0:
        return "einsum"
    if platform in ("tpu", "gpu") and (head_dim % 128 != 0
                                       or block % 8 != 0):
        return "einsum"
    return impl


def decode_attention(p, cfg: ArchConfig, x, cache: KVCache, pos,
                     impl: Optional[str] = None):
    """One-token decode. x: (b, 1, d); pos: scalar int32 (every row at the
    same position — the legacy fixed-batch engine), or (b,) int32 PER-SLOT
    positions — the continuous-batching engine, where each cache row is a
    slot at its own decode depth (write, RoPE, and length mask are all
    per row; stale entries past a slot's position carry a retired
    request's keys and weight exactly 0 under the mask).

    Returns (out (b,1,d), new_cache). ``impl``: decode backend override
    ('einsum' | 'pallas' | 'auto'); None defers to ``cfg.attn_impl`` via
    ``resolve_decode_backend`` — an Engine built with attn='pallas' runs
    the kernels/decode_attention cache sweep here.
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    positions = pos[:, None] if per_slot else jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)

    clen = cache.k.shape[2]
    ring = is_ring(cfg, cache)
    idx = jnp.arange(clen)
    if per_slot:
        slot = (pos % clen) if ring else pos              # (b,)
        wmask = (idx[None, :] == slot[:, None])[:, None, :, None]
        k = jnp.where(wmask,
                      k_new.transpose(0, 2, 1, 3).astype(cache.k.dtype),
                      cache.k)
        v = jnp.where(wmask,
                      v_new.transpose(0, 2, 1, 3).astype(cache.v.dtype),
                      cache.v)
        valid = idx[None, :] <= pos[:, None]              # (b, clen)
        if ring:
            valid = jnp.where((pos >= clen)[:, None],
                              jnp.ones_like(valid), valid)
    else:
        slot = (pos % clen) if ring else pos
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.transpose(0, 2, 1, 3).astype(cache.k.dtype),
            slot, axis=2)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.transpose(0, 2, 1, 3).astype(cache.v.dtype),
            slot, axis=2)
        valid = idx <= pos
        if ring:
            # once pos >= clen the ring is full and every slot is in-window
            valid = jnp.where(pos >= clen, jnp.ones_like(valid), valid)

    impl = resolve_decode_backend(impl if impl is not None else cfg.attn_impl,
                                  cache_len=clen, head_dim=hd)
    kv = cfg.n_kv_heads
    if impl == "pallas":
        from repro.kernels.decode_attention import ops as dec_ops
        out = dec_ops.decode_attention(
            q.reshape(b, cfg.n_heads, hd), k, v, valid,
            block_k=min(256, clen),
            interpret=jax.default_backend() == "cpu")
        out = out.astype(q.dtype).reshape(b, 1, cfg.n_heads * hd)
        return L.dense(out, p["wo"]), KVCache(k=k, v=v)

    mask = jnp.where(valid, 0.0, NEG_INF)                 # (clen,) | (b, clen)
    mask = mask[None, None, None, :] if not per_slot \
        else mask[:, None, None, :]
    group = cfg.n_heads // kv
    qh = q.reshape(b, kv, group, hd)
    scores = jnp.einsum("bkgd,bktd->bkgt", qh, k.astype(qh.dtype)) * (hd ** -0.5)
    scores = scores.astype(jnp.float32) + mask
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,bktd->bkgd", w, v.astype(w.dtype))
    out = out.reshape(b, 1, cfg.n_heads * hd)
    return L.dense(out, p["wo"]), KVCache(k=k, v=v)
