"""BASIC dual encoder: image tower F and text tower G mapping into S^D.

Paper §3: F(x), G(y) live on the D-dimensional unit sphere; similarity
A = (X^T Y)/tau with learnable temperature tau (stored as log_tau).
Text pooling is mean-over-positions (paper §7.2, unlike ALIGN's [CLS]).

Both encoders take a ``precision`` policy (models.precision): the towers
run in its compute dtype while the embedding projections and the unit-norm
always land in fp32 under the default policies — the contrastive loss (and
its Pallas kernels) see fp32 embeddings regardless of tower precision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.dual import DualEncoderConfig
from repro.models import layers as L
from repro.models import precision as prec_lib
from repro.models import transformer as tf


def init_params(cfg: DualEncoderConfig, rng):
    """Parameter pytree: per-tower transformer params (incl. the image
    tower's patchify frontend) + embedding projections + log_tau."""
    ki, kt, kpi, kpt = jax.random.split(rng, 4)
    return {
        "image": {
            "tower": tf.init_params(cfg.image_tower, ki),
            "proj": L.dense_init(kpi, cfg.image_tower.d_model, cfg.embed_dim),
        },
        "text": {
            "tower": tf.init_params(cfg.text_tower, kt),
            "proj": L.dense_init(kpt, cfg.text_tower.d_model, cfg.embed_dim),
        },
        "log_tau": jnp.asarray(jnp.log(cfg.init_temperature), jnp.float32),
    }


def _norm(z):
    return z / jnp.linalg.norm(z, axis=-1, keepdims=True).clip(1e-6)


def encode_image(cfg: DualEncoderConfig, params, images, *, precision=None,
                 remat_policy=None):
    """images: dict with 'image' (b, H, W, C) raw pixels (the tower's
    patchify frontend embeds them). Returns (b, D) on S^D, fp32."""
    pol = prec_lib.resolve(precision)
    h = tf.encode(cfg.image_tower, params["image"]["tower"], images,
                  precision=pol, remat_policy=remat_policy)
    return _norm(L.dense(pol.project(h),
                         params["image"]["proj"]).astype(jnp.float32))


def encode_text(cfg: DualEncoderConfig, params, texts, *, precision=None,
                remat_policy=None):
    """texts: dict with 'tokens' (b, s) (+ optional 'attn_mask', which masks
    padding inside attention and pooling)."""
    pol = prec_lib.resolve(precision)
    h = tf.encode(cfg.text_tower, params["text"]["tower"], texts,
                  precision=pol, remat_policy=remat_policy)
    return _norm(L.dense(pol.project(h),
                         params["text"]["proj"]).astype(jnp.float32))


def temperature(params):
    """tau = exp(log_tau) — the learnable similarity temperature (paper §3)."""
    return jnp.exp(params["log_tau"])
