"""Mixture-of-Experts FFN: top-k router, GShard-style capacity dispatch.

Two dispatch modes:

- ``capacity`` (default, TPU-idiomatic): tokens are bucketed per expert up to a
  fixed capacity C = ceil(top_k * group / E * capacity_factor); dispatch and
  combine are one-hot einsums (GShard/Switch). Expert FLOPs scale with top_k,
  not num_experts, and the expert axis is shardable over the 'model' mesh axis
  (expert parallelism); XLA lowers the resharding to an all-to-all.
- ``dense``: every expert computes every token, weighted combine. Exact
  (no token dropping), O(E) FLOPs — only sensible for tiny smoke/parity tests.

Arctic's parallel dense-residual FFN is supported via ``dense_residual``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


def init_moe_params(key, cfg: ArchConfig, extra=()):
    """Router + per-expert SwiGLU (+ optional dense-residual FFN) params."""
    m = cfg.moe
    kr, ki, kg, ko, kd = jax.random.split(key, 5)
    E = m.num_experts
    p = {
        "router": L.dense_init(kr, cfg.d_model, E, extra),
        "wi": L.dense_init(ki, cfg.d_model, cfg.d_ff, (*extra, E)),
        "wg": L.dense_init(kg, cfg.d_model, cfg.d_ff, (*extra, E)),
        "wo": L.dense_init(ko, cfg.d_ff, cfg.d_model, (*extra, E)),
    }
    if m.dense_residual:
        k1, k2, k3 = jax.random.split(kd, 3)
        p["dense_wi"] = L.dense_init(k1, cfg.d_model, cfg.d_ff, extra)
        p["dense_wg"] = L.dense_init(k2, cfg.d_model, cfg.d_ff, extra)
        p["dense_wo"] = L.dense_init(k3, cfg.d_ff, cfg.d_model, extra)
    return p


def _router(p, cfg, x):
    """Returns (top_p, top_idx, aux_loss). x: (..., d)."""
    m = cfg.moe
    logits = jnp.einsum("...d,de->...e", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # load-balance loss: E * sum_e (token fraction to e) * (mean prob of e)
    onehot = jax.nn.one_hot(top_idx, m.num_experts, dtype=probs.dtype)
    f = jnp.mean(jnp.sum(onehot, axis=-2), axis=tuple(range(onehot.ndim - 2)))
    pbar = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    aux = m.load_balance_coef * m.num_experts * jnp.sum(f / m.top_k * pbar)
    return top_p, top_idx, onehot, aux


def _dense_dispatch(p, cfg, x, top_p, onehot):
    combine = jnp.einsum("bsk,bske->bse", top_p.astype(x.dtype),
                         onehot.astype(x.dtype))
    h = jnp.einsum("bsd,edf->ebsf", x, p["wi"].astype(x.dtype))
    g = jnp.einsum("bsd,edf->ebsf", x, p["wg"].astype(x.dtype))
    h = h * jax.nn.silu(g)
    return jnp.einsum("ebsf,efd,bse->bsd", h, p["wo"].astype(x.dtype), combine)


def _capacity_dispatch(p, cfg, x, top_p, top_idx, group: int,
                       capacity_factor: float):
    """GShard one-hot capacity dispatch. x: (b, s, d)."""
    m = cfg.moe
    b, s, d = x.shape
    E, k = m.num_experts, m.top_k
    assert (b * s) % group == 0, (b, s, group)
    n = (b * s) // group
    xg = x.reshape(n, group, d)
    tp = top_p.reshape(n, group, k)
    ti = top_idx.reshape(n, group, k)

    cap = int(max(k, round(k * group / E * capacity_factor)))
    cap = min(cap, group)

    # position of each (token, choice) within its expert bucket
    choice_oh = jax.nn.one_hot(ti, E, dtype=jnp.int32)        # (n, g, k, E)
    flat = choice_oh.reshape(n, group * k, E)                  # choices in order
    pos = jnp.cumsum(flat, axis=1) - 1                         # (n, g*k, E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(n, group, k)    # (n, g, k)
    keep = pos < cap

    disp = (jax.nn.one_hot(ti, E, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(pos, cap, dtype=x.dtype)[..., None, :])  # (n,g,k,E,C)
    disp = disp * keep[..., None, None].astype(x.dtype)
    combine = jnp.einsum("ngk,ngkec->ngec", tp.astype(x.dtype), disp)
    dispatch = jnp.sum(disp, axis=2)                           # (n, g, E, C)

    ein = jnp.einsum("ngec,ngd->necd", dispatch, xg)           # (n, E, C, d)
    h = jnp.einsum("necd,edf->necf", ein, p["wi"].astype(x.dtype))
    g_ = jnp.einsum("necd,edf->necf", ein, p["wg"].astype(x.dtype))
    eout = jnp.einsum("necf,efd->necd", h * jax.nn.silu(g_),
                      p["wo"].astype(x.dtype))
    out = jnp.einsum("ngec,necd->ngd", combine, eout)
    return out.reshape(b, s, d)


def moe_ffn(p, cfg: ArchConfig, x, *, dispatch: str = "capacity",
            group: int = 4096, capacity_factor: float = 1.25):
    """x: (b, s, d) -> (out, aux_loss scalar)."""
    top_p, top_idx, onehot, aux = _router(p, cfg, x)
    if dispatch == "dense":
        out = _dense_dispatch(p, cfg, x, top_p, onehot)
    else:
        g = min(group, x.shape[0] * x.shape[1])
        out = _capacity_dispatch(p, cfg, x, top_p, top_idx, g, capacity_factor)
    out = L.checkpoint_name(out, L.SAVE)
    if cfg.moe.dense_residual:
        out = out + L.swiglu(x, p["dense_wi"], p["dense_wg"], p["dense_wo"])
    return out, aux
