"""Primitive layers: norms, RoPE, SwiGLU, initializers.

All layers are pure functions over explicit param pytrees. Intermediate values
that the BASIC remat policy (core/remat.py) wants to *save* are tagged with
``jax.ad_checkpoint.checkpoint_name`` — everything untagged (norms, activations,
softmax internals) is rematerialized, mirroring paper §5.2 / Figure 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

# Name used to tag outputs of weight-bearing ops (matmuls). The BASIC policy
# saves exactly these.
SAVE = "weight_op"


def dense(x, w, name=SAVE):
    """x @ w with the output tagged as a saveable for the remat policy."""
    y = jnp.einsum("...i,io->...o", x, w.astype(x.dtype))
    return checkpoint_name(y, name)


def rms_norm(x, scale, eps=1e-5):
    """RMSNorm computed in fp32 regardless of input dtype; returns the input dtype."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def swiglu(x, wi, wg, wo):
    """SwiGLU FFN: (x@wi) * silu(x@wg) @ wo."""
    h = dense(x, wi) * jax.nn.silu(dense(x, wg))
    return dense(h, wo)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    """Rotary base frequencies for half the head dim."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                     # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    sin = jnp.sin(angles)[..., None, :]               # (..., seq, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def trunc_normal(key, shape, stddev):
    """Truncated-normal init at +-2 sigma, fp32."""
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                dtype=jnp.float32)


def dense_init(key, d_in, d_out, extra=()):
    """Dense weight init: trunc-normal, stddev d_in**-0.5, optional leading stack dims."""
    return trunc_normal(key, (*extra, d_in, d_out), stddev=d_in ** -0.5)
