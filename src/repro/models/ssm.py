"""Mamba2 (SSD — state-space duality) mixer block [arXiv:2405.21060].

Chunked SSD algorithm (paper Listing 1) adapted to JAX: intra-chunk quadratic
attention-like term + inter-chunk linear recurrence via ``jax.lax.scan``; the
projections are split (z, x, B, C, dt) so each is independently shardable.

Decode is the O(1) recurrent form: state (b, heads, head_dim, N) updated per
token; a depthwise-conv ring state of width conv_width-1 feeds the (x, B, C)
convolution.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L


class SSMCache(NamedTuple):
    """Decode-time SSM state: conv tail (b, d_in, conv_w-1) + SSD state (b, heads, P, N)."""
    ssm: jax.Array        # (b, heads, head_dim, N) f32
    conv: jax.Array       # (b, conv_width-1, d_conv) rolling window of xBC


def dims(cfg: ArchConfig):
    """Derived SSD dimensions (d_inner, n_heads) for the config."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    d_conv = d_in + 2 * s.state_dim
    return d_in, nheads, d_conv


def init_ssm_params(key, cfg: ArchConfig, extra=()):
    """Mamba2 block params: in/out projections, conv, per-head A/D/dt."""
    s = cfg.ssm
    d = cfg.d_model
    d_in, nheads, d_conv = dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "in_z": L.dense_init(ks[0], d, d_in, extra),
        "in_x": L.dense_init(ks[1], d, d_in, extra),
        "in_B": L.dense_init(ks[2], d, s.state_dim, extra),
        "in_C": L.dense_init(ks[3], d, s.state_dim, extra),
        "in_dt": L.dense_init(ks[4], d, nheads, extra),
        "conv_w": L.trunc_normal(ks[5], (*extra, s.conv_width, d_conv),
                                 stddev=s.conv_width ** -0.5),
        "dt_bias": jnp.zeros((*extra, nheads), jnp.float32),
        # A in (-exp range); A_log init ~ U[ln 1, ln 16]
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.linspace(1.0, 16.0, nheads, dtype=jnp.float32)),
            (*extra, nheads)).copy(),
        "D": jnp.ones((*extra, nheads), jnp.float32),
        "out": L.dense_init(ks[6], d_in, d, extra),
    }


def _segsum(a):
    """a: (..., t) -> (..., t, t) lower-triangular pairwise cumulative sums:
    out[..., i, j] = sum_{k=j+1..i} a[..., k] for i >= j, -inf otherwise."""
    t = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    x:  (b, l, h, p)   inputs (per head)
    dt: (b, l, h)      softplus'd step sizes
    A:  (h,)           negative decay rates
    Bm: (b, l, n)      input matrix (single group, broadcast over heads)
    Cm: (b, l, n)      output matrix
    Returns (y (b, l, h, p), final_state (b, h, p, n)).
    """
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    f32 = jnp.float32

    xc = (x.astype(f32) * dt.astype(f32)[..., None]).reshape(b, nc, chunk, h, p)
    da = (dt.astype(f32) * A.astype(f32)).reshape(b, nc, chunk, h)
    da = jnp.moveaxis(da, -1, 1)                        # (b, h, nc, chunk)
    Bc = Bm.astype(f32).reshape(b, nc, chunk, n)
    Cc = Cm.astype(f32).reshape(b, nc, chunk, n)

    # 1) intra-chunk (quadratic, "attention-like") term
    Lmat = jnp.exp(_segsum(da))                         # (b, h, nc, c, c)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, Lmat, xc)

    # 2) per-chunk states (contribution of each chunk to the carried state)
    da_cum = jnp.cumsum(da, axis=-1)                    # (b, h, nc, c)
    decay_to_end = jnp.exp(da_cum[..., -1:] - da_cum)   # (b, h, nc, c)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_to_end, xc)

    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(da_cum[..., -1])              # (b, h, nc)
    init = (jnp.zeros((b, h, p, n), f32) if init_state is None
            else init_state.astype(f32))

    def step(carry, inp):
        s_new, dec = inp                                # (b,h,p,n), (b,h)
        out = carry
        carry = carry * dec[..., None, None] + s_new
        return carry, out

    final, states_in = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, -1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)           # (b, nc, h, p, n)

    # 4) inter-chunk output: decayed initial-state contribution
    state_decay = jnp.exp(da_cum)                       # (b, h, nc, c)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, states_in, state_decay)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final


def _conv1d(xBC, w, state=None):
    """Causal depthwise conv. xBC: (b, l, c); w: (cw, c).
    state: (b, cw-1, c) previous inputs (decode) or None (train: zero-pad)."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((xBC.shape[0], cw - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    full = jnp.concatenate([pad, xBC], axis=1)
    out = sum(full[:, i:i + xBC.shape[1], :] * w[i][None, None, :].astype(xBC.dtype)
              for i in range(cw))
    return out, full[:, -(cw - 1):, :] if cw > 1 else pad


def mamba_mixer(p, cfg: ArchConfig, x, cache: SSMCache = None):
    """Full-sequence Mamba2 mixer. x: (b, l, d) -> (y, new_cache or None)."""
    s = cfg.ssm
    d_in, nheads, _ = dims(cfg)
    b, l, _ = x.shape

    z = L.dense(x, p["in_z"])
    xi = L.dense(x, p["in_x"])
    Bm = L.dense(x, p["in_B"])
    Cm = L.dense(x, p["in_C"])
    dt = jax.nn.softplus(
        L.dense(x, p["in_dt"]).astype(jnp.float32) + p["dt_bias"])

    xBC = jnp.concatenate([xi, Bm, Cm], axis=-1)
    conv_state = None if cache is None else cache.conv
    xBC, new_conv = _conv1d(xBC, p["conv_w"], conv_state)
    xBC = jax.nn.silu(xBC)
    xi, Bm, Cm = jnp.split(xBC, [d_in, d_in + s.state_dim], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xi.reshape(b, l, nheads, s.head_dim)
    init_state = None if cache is None else cache.ssm
    y, final = ssd_chunked(xh, dt, A, Bm, Cm, min(s.chunk, l), init_state)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, l, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = L.dense(y, p["out"])
    new_cache = SSMCache(ssm=final, conv=new_conv)
    return out, new_cache


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> SSMCache:
    """Zeroed decode cache for one SSM block stack."""
    s = cfg.ssm
    d_in, nheads, d_conv = dims(cfg)
    return SSMCache(
        ssm=jnp.zeros((batch, nheads, s.head_dim, s.state_dim), jnp.float32),
        conv=jnp.zeros((batch, s.conv_width - 1, d_conv), dtype),
    )


def mamba_decode(p, cfg: ArchConfig, x, cache: SSMCache):
    """Single-token recurrent step. x: (b, 1, d)."""
    s = cfg.ssm
    d_in, nheads, _ = dims(cfg)
    b = x.shape[0]

    z = L.dense(x, p["in_z"])
    xi = L.dense(x, p["in_x"])
    Bm = L.dense(x, p["in_B"])
    Cm = L.dense(x, p["in_C"])
    dt = jax.nn.softplus(
        L.dense(x, p["in_dt"]).astype(jnp.float32) + p["dt_bias"])  # (b,1,h)

    xBC = jnp.concatenate([xi, Bm, Cm], axis=-1)
    xBC, new_conv = _conv1d(xBC, p["conv_w"], cache.conv)
    xBC = jax.nn.silu(xBC)
    xi, Bm, Cm = jnp.split(xBC, [d_in, d_in + s.state_dim], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (h,)
    dt0 = dt[:, 0, :]                                             # (b, h)
    decay = jnp.exp(dt0 * A)                                      # (b, h)
    xh = xi.reshape(b, nheads, s.head_dim).astype(jnp.float32)
    dx = dt0[..., None] * xh                                      # (b, h, p)
    state = (cache.ssm * decay[..., None, None]
             + dx[..., None] * Bm[:, 0, None, None, :].astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", state, Cm[:, 0].astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = L.dense(y, p["out"])
    return out, SSMCache(ssm=state, conv=new_conv)
