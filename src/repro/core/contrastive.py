"""Image-text contrastive loss (paper §3, Eqs. 1-3).

A = (X^T Y) / tau; loss = (RowLoss + ColumnLoss)/2 where each is softmax CE
against the diagonal. ``contrastive_loss`` is the reference (materializes the
B×B matrix, as paper Algorithm 1 line 6 does); the Pallas fused kernel in
``repro.kernels.contrastive_loss`` computes the same quantity blockwise
without materializing A in HBM (beyond-paper, DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def similarity(x_emb, y_emb, tau):
    """A_{ij} = <F(x_i), G(y_j)> / tau. x_emb/y_emb: (B, D) unit-normalized."""
    return jnp.einsum("id,jd->ij", x_emb, y_emb) / tau


def contrastive_loss(x_emb, y_emb, tau, label_smoothing: float = 0.0):
    """Paper Eq. 3. Returns (loss, metrics)."""
    b = x_emb.shape[0]
    a = similarity(x_emb.astype(jnp.float32), y_emb.astype(jnp.float32), tau)
    labels = jnp.arange(b)
    row_lse = jax.nn.logsumexp(a, axis=1)
    col_lse = jax.nn.logsumexp(a, axis=0)
    diag = jnp.diagonal(a)
    if label_smoothing:
        eps = label_smoothing
        row_tgt = (1 - eps) * diag + eps * jnp.mean(a, axis=1)
        col_tgt = (1 - eps) * diag + eps * jnp.mean(a, axis=0)
    else:
        row_tgt, col_tgt = diag, diag
    row_loss = jnp.mean(row_lse - row_tgt)
    col_loss = jnp.mean(col_lse - col_tgt)
    loss = 0.5 * (row_loss + col_loss)
    acc = jnp.mean((jnp.argmax(a, axis=1) == labels).astype(jnp.float32))
    return loss, {"row_loss": row_loss, "col_loss": col_loss,
                  "i2t_top1": acc}


def fused_kernel_loss(x_emb, y_emb, tau, interpret=None, bm=None, bn=None):
    """Same value/gradients as ``contrastive_loss`` but via the single-pass
    Pallas fused kernels (one fwd sweep, one bwd sweep) — the B×B similarity
    matrix never materializes in HBM (beyond-paper; DESIGN.md §2).

    ``interpret=None`` auto-detects the backend: the compiled kernel on
    accelerators, the interpreted kernel body when ``jax.default_backend()``
    is "cpu" (where Mosaic cannot compile). bf16 embeddings are passed
    through unconverted (fp32 accumulation happens inside the kernel);
    ``bm``/``bn`` override the VMEM-model block autotuner (DESIGN.md §2.4).

    Drop-in ``loss_fn`` for core.gradaccum (metrics limited to the loss —
    the argmax-accuracy metric would need the full matrix)."""
    from repro.kernels.contrastive_loss.ops import fused_contrastive_loss
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    log_tau = jnp.log(tau)
    loss = fused_contrastive_loss(x_emb, y_emb, log_tau, interpret, bm, bn)
    zero = jnp.zeros((), jnp.float32)
    return loss, {"row_loss": zero, "col_loss": zero, "i2t_top1": zero}


def normalized_train_loss(x_emb, y_emb):
    """Paper §6 normalized loss \\hat{ell}_B (used by core/theory.py):
    -exp(F(x_i)^T G(y_i)) / (1/B sum_k exp(F(x_i)^T G(y_k))).

    Returns the per-example vector (B,)."""
    s = jnp.einsum("id,jd->ij", x_emb, y_emb)          # (B, B), tau = 1
    num = jnp.exp(jnp.diagonal(s))
    den = jnp.mean(jnp.exp(s), axis=1)
    return -num / den
