"""Paper Algorithm 1: GradAccum for the contrastive loss.

The contrastive loss needs the entire B×B similarity matrix, so per-microbatch
losses cannot be formed independently. Algorithm 1 instead:

  pass 1  (lines 2-5):  forward each microbatch through F, G keeping ONLY the
                        embeddings X, Y (activations discarded),
  lines 6-12:           full-batch loss on (X, Y) and its gradient (dX, dY),
  pass 2  (lines 13-16): re-run each microbatch forward, back-prop the dX/dY
                        slice into the weights, accumulate.

In JAX both passes are ``lax.scan`` over microbatches; pass 2 uses ``jax.vjp``
of the tower forward. The result is the EXACT full-batch gradient (asserted in
tests/test_gradaccum.py), with peak memory Θ(M·Mem(tower)) instead of
Θ(B·Mem(F+G)).

``microbatch_grads`` is the streaming form (paper "Yields" line): it emits the
per-microbatch gradient stream c_1..c_K consumed by core/moment_accum.py.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.contrastive import contrastive_loss


def _split(tree, k):
    """Reshape every leaf (B, ...) -> (k, B//k, ...)."""
    return jax.tree.map(lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]),
                        tree)


def contrastive_step(encode_image: Callable, encode_text: Callable,
                     params, batch, num_micro: int,
                     loss_fn: Callable = contrastive_loss,
                     loss_opts: dict | None = None,
                     emb_sharding=None):
    """Exact full-batch contrastive gradient via Algorithm 1.

    encode_image(params, images_mb) -> (M, D) embeddings (unit-norm)
    encode_text(params, texts_mb)   -> (M, D)
    params must contain 'log_tau'. batch = {'images': ..., 'texts': ...} with
    leading batch dim B on every leaf; num_micro must divide B.

    ``loss_opts`` is forwarded to ``loss_fn`` as keyword arguments — e.g.
    ``loss_fn=fused_kernel_loss, loss_opts={"interpret": True, "bm": 256}``
    plumbs explicit interpret/block overrides down to the Pallas kernels.

    ``loss_fn`` may also be a cross-shard GLOBAL-batch loss
    (``core.distributed_loss.make_global_loss_fn(mesh, ...)``); pass
    ``emb_sharding=distributed_loss.emb_sharding(mesh)`` with it, so the
    (B, D) embedding block and its dX/dY cotangents are pinned
    batch-sharded over the data axes between the tower scans and the
    shard_map'd loss — accumulation × data-parallel × tensor-parallel
    then compose under one jit (launch/train_distributed.py).

    Returns (loss, metrics, grads) with grads exactly equal to
    jax.grad of the monolithic loss (same contraction order).
    """
    images = _split(batch["images"], num_micro)
    texts = _split(batch["texts"], num_micro)

    def _pin(z):
        if emb_sharding is None:
            return z
        return jax.lax.with_sharding_constraint(z, emb_sharding)

    # ---- pass 1: embeddings only (lines 2-5) ----
    def fwd(_, mb):
        img, txt = mb
        return None, (encode_image(params, img), encode_text(params, txt))

    _, (X, Y) = jax.lax.scan(fwd, None, (images, texts))
    D = X.shape[-1]
    X = _pin(X.reshape(-1, D))
    Y = _pin(Y.reshape(-1, D))

    # ---- lines 6-12: loss on embeddings + d(loss)/d(X, Y, log_tau) ----
    def loss_on_emb(x, y, log_tau):
        tau = jnp.exp(log_tau)
        return loss_fn(x, y, tau, **(loss_opts or {}))

    (loss, metrics), (dX, dY, dlog_tau) = jax.value_and_grad(
        loss_on_emb, argnums=(0, 1, 2), has_aux=True)(
            X, Y, params["log_tau"])

    dXm = _pin(dX).reshape(num_micro, -1, D)
    dYm = _pin(dY).reshape(num_micro, -1, D)

    # ---- pass 2: rematerialize per microbatch, VJP into weights ----
    zero = jax.tree.map(jnp.zeros_like, params)

    def bwd(g, mb):
        img, txt, dx, dy = mb
        _, vjp_i = jax.vjp(lambda p: encode_image(p, img), params)
        _, vjp_t = jax.vjp(lambda p: encode_text(p, txt), params)
        gi, = vjp_i(dx)
        gt, = vjp_t(dy)
        g = jax.tree.map(lambda a, b, c: a + b + c, g, gi, gt)
        return g, None

    grads, _ = jax.lax.scan(bwd, zero, (images, texts, dXm, dYm))
    # the embedding VJPs contribute nothing to log_tau; add the direct term
    grads["log_tau"] = grads["log_tau"] + dlog_tau
    return loss, metrics, grads


def microbatch_grads(encode_image: Callable, encode_text: Callable,
                     params, batch, num_micro: int,
                     loss_fn: Callable = contrastive_loss,
                     loss_opts: dict | None = None):
    """Streaming form: returns (loss, metrics, c) where c is the stacked
    per-microbatch gradient stream, leaves (K, ...); mean over K equals the
    exact full-batch gradient (up to the 1/K normalization, paper §4.1)."""
    images = _split(batch["images"], num_micro)
    texts = _split(batch["texts"], num_micro)

    def fwd(_, mb):
        img, txt = mb
        return None, (encode_image(params, img), encode_text(params, txt))

    _, (X, Y) = jax.lax.scan(fwd, None, (images, texts))
    D = X.shape[-1]
    Xf, Yf = X.reshape(-1, D), Y.reshape(-1, D)

    def loss_on_emb(x, y, log_tau):
        tau = jnp.exp(log_tau)
        return loss_fn(x, y, tau, **(loss_opts or {}))

    (loss, metrics), (dX, dY, dlog_tau) = jax.value_and_grad(
        loss_on_emb, argnums=(0, 1, 2), has_aux=True)(
            Xf, Yf, params["log_tau"])
    dXm = dX.reshape(num_micro, -1, D)
    dYm = dY.reshape(num_micro, -1, D)

    def one(mb):
        img, txt, dx, dy = mb
        _, vjp_i = jax.vjp(lambda p: encode_image(p, img), params)
        _, vjp_t = jax.vjp(lambda p: encode_text(p, txt), params)
        gi, = vjp_i(dx)
        gt, = vjp_t(dy)
        g = jax.tree.map(lambda a, b: a + b, gi, gt)
        # K * grad-share so that mean_K(c_i) == exact full gradient
        g = jax.tree.map(lambda a: a * num_micro, g)
        g["log_tau"] = g["log_tau"] + dlog_tau
        return g

    _, c = jax.lax.scan(lambda _, mb: (None, one(mb)), None,
                        (images, texts, dXm, dYm))
    return loss, metrics, c
