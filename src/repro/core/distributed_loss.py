"""Cross-shard global-batch contrastive loss (DESIGN.md §7).

The paper's quality driver is the GLOBAL contrastive batch (B = 65536):
every example must see every other example in the batch as a negative,
across all data-parallel shards. This module computes exactly that from
per-shard embedding blocks, two ways:

``all_gather_loss``
    Gather X and Y over the data axis, run the single-pass fused Pallas
    loss (kernels/contrastive_loss) on the full (B_global, D) arrays on
    every device, pmean. Simple and exact — autodiff through the
    collectives yields the correct per-shard dX/dY (transpose of the
    tiled all-gather is a psum-scatter) — but every device does the full
    O(B_global²·D) similarity work, redundantly R times.

``chunked_loss``
    The per-shard scheme: each shard keeps only its local X block and
    streams the R gathered Y chunks through the fused kernel, one square
    (B_local, B_local) launch at a time. Each shard therefore computes
    only its row block (local rows × all columns) and the matching
    column partials; partial column log-sum-exps are psum-combined
    across shards. Per-device similarity work drops to
    O(B_local·B_global·D) — an R/2× saving over ``all_gather_loss`` at
    the same answer — and no device ever holds a (B_global, B_global)
    logit matrix, not even blockwise: the largest live tile is
    (bm, bn) ⊂ (B_local, B_local) in VMEM. The backward is a custom VJP
    that streams the same chunks through the no-diagonal fused backward
    (ops.chunk_grads) and psum-scatters the dY partials back to their
    owning shards (gradient-reduction correctness argument: DESIGN.md
    §7.3).

Both are shard-level functions: call them inside ``shard_map`` (or any
context where ``axis`` is a bound mesh axis name). ``make_global_loss_fn``
wraps either into a jit-level ``loss_fn(x, y, tau) -> (loss, metrics)``
drop-in for ``core.gradaccum.contrastive_step``, so Algorithm-1 gradient
accumulation, data parallelism, and tensor-parallel towers compose under
one jit (launch/train_distributed.py --objective contrastive).

shard_map runs with ``check_rep=False`` (Pallas calls have no replication
rule), which fixes the AD boundary convention this module compensates
for: the cotangent of the replicated P() loss arrives at each shard
scaled by 1/R, per-shard cotangents returned for P(data) inputs are used
as the local blocks directly, and cotangents returned for replicated P()
inputs are psum'd by the unmapping. ``_chunked_bwd`` therefore scales
dX/dY/dτ by R and does NOT psum dτ itself. Pinned by
tests/test_distributed_loss.py against the single-device oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import sharding as shd
from repro.kernels.contrastive_loss import ops


def _linear_axis_index(axis):
    """Shard's linear position over ``axis`` (name or tuple of names),
    major-to-minor in tuple order — matches the concatenation order of
    ``all_gather``/``psum_scatter`` over the same tuple."""
    if not isinstance(axis, tuple):
        return jax.lax.axis_index(axis)
    idx = jnp.zeros((), jnp.int32)
    for name in axis:
        idx = idx * jax.lax.psum(1, name) + jax.lax.axis_index(name)
    return idx


def _zero_metrics():
    zero = jnp.zeros((), jnp.float32)
    return {"row_loss": zero, "col_loss": zero, "i2t_top1": zero}


# ---------------------------------------------------------------------------
# all-gather variant
# ---------------------------------------------------------------------------


def all_gather_loss(x_l, y_l, log_tau, *, axis, interpret=None,
                    bm=None, bn=None):
    """Global-batch contrastive loss from per-shard embedding blocks by
    gathering both sides (shard-level; call inside shard_map).

    x_l, y_l: (B_local, D) fp32/bf16 unit-norm local blocks, row i of
    each being the two views of the same pair; log_tau: scalar fp32;
    axis: mesh axis name (or tuple) the batch is sharded over. Returns
    the replicated scalar fp32 loss of the full (B_global, B_global)
    problem. Differentiable: gradients flow through the collectives
    (all-gather transposes to psum-scatter), so jax.grad inside the
    enclosing jit returns per-shard dX/dY blocks and the psum'd dτ."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    x_g = jax.lax.all_gather(x_l, axis, tiled=True)
    y_g = jax.lax.all_gather(y_l, axis, tiled=True)
    loss = ops.fused_contrastive_loss(x_g, y_g, log_tau, interpret, bm, bn)
    return jax.lax.pmean(loss, axis)


# ---------------------------------------------------------------------------
# chunked-negatives variant
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def chunked_loss(x_l, y_l, log_tau, axis, interpret=None, bm=None, bn=None):
    """Global-batch contrastive loss, per-shard chunked-negatives scheme
    (shard-level; call inside shard_map — see module docstring).

    x_l, y_l: (B_local, D) fp32/bf16 unit-norm local blocks; log_tau:
    scalar fp32; axis: mesh axis name (or tuple). Each shard computes
    its row block of the global similarity structure by streaming the R
    gathered Y chunks through the single-pass fused kernel; column LSEs
    are psum-combined. Returns the replicated scalar fp32 loss; value
    and gradients match ``all_gather_loss`` (and the single-device fused
    loss at the same global batch) to fp32 tolerance, with per-device
    similarity work reduced R/2× and no (B_global, B_global) residency."""
    loss, _ = _chunked_fwd(x_l, y_l, log_tau, axis, interpret, bm, bn)
    return loss


def _chunked_fwd(x_l, y_l, log_tau, axis, interpret, bm, bn):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b_l = x_l.shape[0]
    inv_tau = jnp.exp(-log_tau)
    y_all = jax.lax.all_gather(y_l, axis, tiled=False)   # (R, B_local, D)
    if isinstance(axis, tuple):                          # (R1, R2, ...) -> (R,)
        y_all = y_all.reshape((-1,) + y_l.shape)

    def chunk(row_lse, y_r):
        rl_r, cl_r = ops.chunk_row_col_lse(x_l, y_r, inv_tau,
                                           interpret=interpret, bm=bm, bn=bn)
        return jnp.logaddexp(row_lse, rl_r), cl_r

    row_lse0 = jnp.full((b_l,), -jnp.inf, jnp.float32)
    row_lse, col_parts = jax.lax.scan(chunk, row_lse0, y_all)

    # combine partial col LSEs across shards: col_parts[r] holds, for the
    # columns of chunk r, log sum over THIS shard's rows; the global col
    # LSE is the stable log-psum-exp over shards
    m = jax.lax.pmax(col_parts, axis)
    col_lse = m + jnp.log(jax.lax.psum(jnp.exp(col_parts - m), axis))

    r_own = _linear_axis_index(axis)
    diag = jnp.sum(x_l.astype(jnp.float32) * y_l.astype(jnp.float32),
                   axis=1) * inv_tau
    col_own = jax.lax.dynamic_index_in_dim(col_lse, r_own, 0, keepdims=False)
    row_term = jax.lax.pmean(jnp.mean(row_lse - diag), axis)
    col_term = jax.lax.pmean(jnp.mean(col_own - diag), axis)
    loss = 0.5 * (row_term + col_term)
    return loss, (x_l, y_l, log_tau, row_lse, col_lse)


def _chunked_bwd(axis, interpret, bm, bn, res, g):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    x_l, y_l, log_tau, row_lse, col_lse = res
    b_l, d = x_l.shape
    inv_tau = jnp.exp(-log_tau)
    r_own = _linear_axis_index(axis)
    y_all = jax.lax.all_gather(y_l, axis, tiled=False)
    if isinstance(axis, tuple):
        y_all = y_all.reshape((-1,) + y_l.shape)
    n_shards = y_all.shape[0]                 # static: from the gathered shape
    b_g = n_shards * b_l

    def chunk(_, inp):
        y_r, cl_r = inp
        dx_r, dy_r, dtau_r = ops.chunk_grads(
            x_l, y_r, inv_tau, row_lse, cl_r, b_norm=b_g, with_diag=False,
            interpret=interpret, bm=bm, bn=bn)
        return None, (dx_r, dy_r, dtau_r)

    _, (dx_parts, dy_parts, dtau_parts) = jax.lax.scan(
        chunk, None, (y_all, col_lse))
    dx = jnp.sum(dx_parts, axis=0)
    dtau = jnp.sum(dtau_parts)

    # positive-pair (shard-diagonal) correction, fully local: the kernels
    # ran with with_diag=False, so add the -δ_ij/B_global term for the own
    # chunk: dA_ii -= 1/B_g  =>  dX_i -= y_i·τ⁻¹/B_g, dY_i -= x_i·τ⁻¹/B_g,
    # dτ_log += Σ_i a_ii/B_g
    xf = x_l.astype(jnp.float32)
    yf = y_l.astype(jnp.float32)
    diag = jnp.sum(xf * yf, axis=1) * inv_tau
    dx = dx - (inv_tau / b_g) * yf
    dy_parts = dy_parts.at[r_own].add(-(inv_tau / b_g) * xf)
    dtau = dtau + jnp.sum(diag) / b_g

    # each shard holds dY partials for ALL columns (from its rows);
    # psum-scatter sums across shards and hands each shard its own block
    dy = jax.lax.psum_scatter(dy_parts.reshape(b_g, d), axis, tiled=True)

    # check_rep=False boundary compensation (module docstring): the
    # incoming replicated-loss cotangent g is scaled 1/R per shard, and
    # the replicated log_tau's cotangent is psum'd by the unmapping — so
    # scale everything by R and return the LOCAL dτ contribution unpsum'd
    r = n_shards
    return ((r * g * dx).astype(x_l.dtype), (r * g * dy).astype(y_l.dtype),
            r * g * dtau)


chunked_loss.defvjp(_chunked_fwd, _chunked_bwd)


# ---------------------------------------------------------------------------
# jit-level drop-in for core.gradaccum
# ---------------------------------------------------------------------------

METHODS = ("allgather", "chunked")


def make_global_loss_fn(mesh, method: str = "chunked", *, data_axes=None,
                        interpret=None, bm=None, bn=None):
    """Build a ``loss_fn(x, y, tau) -> (loss, metrics)`` computing the
    cross-shard GLOBAL-batch contrastive loss, drop-in for
    ``core.gradaccum.contrastive_step(loss_fn=...)``.

    mesh: the jax Mesh the step runs under; method: 'allgather' or
    'chunked' (see module docstring); data_axes: mesh axis names the
    batch dim is sharded over (default: sharding.data_axes(mesh),
    restricted to axes present in the mesh). x, y are the logical
    (B_global, D) embedding arrays — GSPMD keeps them sharded over the
    data axes, shard_map hands each device its local block, and the
    collectives above do the rest. When the data extent is 1 the
    shard_map is skipped entirely and the single-device fused loss is
    returned (identical value/gradients — the distributed paths reduce
    to it). Metrics are zeros (same contract as fused_kernel_loss: the
    full-matrix argmax metric has no blockwise form).
    """
    if method not in METHODS:
        raise ValueError(f"method must be one of {METHODS}, got {method!r}")
    if data_axes is None:
        data_axes = tuple(a for a in shd.data_axes(mesh) if a in mesh.shape)
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]

    if n_shards == 1:
        from repro.core.contrastive import fused_kernel_loss

        def loss_fn_single(x, y, tau):
            return fused_kernel_loss(x, y, tau, interpret=interpret,
                                     bm=bm, bn=bn)
        return loss_fn_single

    axis = data_axes if len(data_axes) > 1 else data_axes[0]

    def local_fn(x_l, y_l, log_tau):
        if method == "allgather":
            return all_gather_loss(x_l, y_l, log_tau, axis=axis,
                                   interpret=interpret, bm=bm, bn=bn)
        return chunked_loss(x_l, y_l, log_tau, axis, interpret, bm, bn)

    mapped = shard_map(local_fn, mesh=mesh,
                       in_specs=(P(data_axes), P(data_axes), P()),
                       out_specs=P(), check_rep=False)

    def loss_fn(x, y, tau):
        loss = mapped(x, y, jnp.log(tau))
        return loss, _zero_metrics()

    return loss_fn


def emb_sharding(mesh, data_axes=None):
    """NamedSharding for (B, D) embedding blocks: batch over the data
    axes, D replicated — the layout ``make_global_loss_fn`` expects and
    ``gradaccum.contrastive_step(emb_sharding=...)`` pins between the
    tower pass and the loss so GSPMD cannot re-gather the embeddings."""
    if data_axes is None:
        data_axes = tuple(a for a in shd.data_axes(mesh) if a in mesh.shape)
    return jax.sharding.NamedSharding(mesh, P(data_axes, None))
