"""Paper §5.2 rematerialization heuristic as jax.checkpoint policies.

BASIC's rule: *keep* every output of a weight-bearing op (conv/attention/dense
— expensive to recompute under weight sharding because the all-gather of the
sharded weight would re-run), *remat* everything that has no weights (norms,
activations, softmax, SE blocks). Model code tags weight-op outputs with
``checkpoint_name(..., layers.SAVE)``; the policy saves exactly those.
"""
from __future__ import annotations

import jax

from repro.models.layers import SAVE

POLICIES = {}


def _register(name):
    def deco(fn):
        POLICIES[name] = fn
        return fn
    return deco


@_register("basic")
def basic_policy():
    """Paper §5.2: save weight-op outputs, remat norms/activations."""
    return jax.checkpoint_policies.save_only_these_names(SAVE)


@_register("none")
def no_remat_policy():
    """Save everything (vanilla; maximal memory)."""
    return jax.checkpoint_policies.everything_saveable


@_register("full")
def full_remat_policy():
    """Save nothing — recompute the whole block in the backward pass."""
    return jax.checkpoint_policies.nothing_saveable


@_register("dots")
def dots_policy():
    """XLA-classic: save matmul outputs except embedding-sized ones."""
    return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims


def get_policy(name: str):
    if name is None or name == "off":
        return None
    return POLICIES[name]()
