"""Sharding rules: paper-faithful SPMD weight sharding + beyond-paper TP/EP.

Modes
-----
``basic_ws`` (paper §5.1, the BASELINE):
    Activations are purely data-parallel: the global batch is split over ALL
    cores ("each of our 2048 cores processes B/2048 examples, regardless of
    R"), here over ('pod','data'). Weights — and their two optimizer moments —
    are split over the 'model' axis on their largest shardable dim and
    all-gathered on use (XLA inserts the gathers; Fig. 1 semantics). 1-D
    params (norm scales, biases; paper §5.2 exception 1) stay replicated.

``tp`` (beyond-paper optimization):
    Megatron-style tensor parallelism: attention q/k/v and FFN-in shard their
    output dim over 'model', o/FFN-out shard their input dim, so each block
    needs one reduction instead of per-weight all-gathers. MoE experts shard
    over 'model' (expert parallelism) when num_experts divides the axis,
    falling back to intra-expert TP otherwise (Mixtral's 8 experts on a
    16-way axis). Embedding/LM head shard the vocab when divisible.

Both modes are pure metadata: functions here map a param/batch/cache pytree to
``PartitionSpec`` trees; ``jax.jit(in_shardings=...)`` does the rest.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

POD, DATA, MODEL = "pod", "data", "model"


def mesh_axis_size(mesh, name):
    """Extent of mesh axis ``name`` (int), 1 when the mesh lacks it."""
    return mesh.shape[name] if name in mesh.shape else 1


def data_axes(mesh):
    """The batch-distribution axes of ``mesh``: ('pod', 'data') on
    multi-pod meshes, ('data',) otherwise. Returns a tuple of str."""
    return (POD, DATA) if POD in mesh.shape else (DATA,)


# ---------------------------------------------------------------------------
# generic helpers
# ---------------------------------------------------------------------------


def _shard_largest(shape, axis_size: int, skip=frozenset()) -> Optional[int]:
    """Index of the largest dim divisible by axis_size, or None."""
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if i in skip:
            continue
        if shape[i] % axis_size == 0 and shape[i] >= axis_size:
            return i
    return None


def _spec_with(ndim: int, axis: Optional[int], name) -> P:
    if axis is None:
        return P()
    parts = [None] * ndim
    parts[axis] = name
    return P(*parts)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


# ---------------------------------------------------------------------------
# param specs
# ---------------------------------------------------------------------------


def params_specs(params, mesh, mode: str = "basic_ws"):
    """PartitionSpec tree matching ``params`` (works for LM and dual-encoder
    pytrees; stacked block leaves are detected via the 'blocks' path and their
    leading scan axis is never sharded)."""
    msize = mesh_axis_size(mesh, MODEL)

    def leaf_spec(path, x):
        name = _path_str(path)
        shape = np.shape(x)
        stacked = "blocks/" in name + "/"
        skip = {0} if ("blocks" in name.split("/")) else set()
        del stacked
        if np.ndim(x) <= 1 or msize == 1:
            return P()
        if mode == "basic_ws":
            ax = _shard_largest(shape, msize, skip)
            return _spec_with(len(shape), ax, MODEL)
        if mode == "tp":
            return _tp_leaf_spec(name, shape, msize, skip)
        if mode == "replicated":
            return P()
        raise ValueError(f"unknown sharding mode {mode!r}")

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


_TP_OUT = re.compile(r"(wq|wk|wv|wi|wg|in_z|in_x|in_B|in_C|in_dt|proj"
                     r"|dense_wi|dense_wg|lm_head)$")
_TP_IN = re.compile(r"(wo|out|dense_wo)$")


def _tp_leaf_spec(name: str, shape, msize: int, skip) -> P:
    last = name.rsplit("/", 1)[-1]
    nd = len(shape)
    is_moe = "/moe/" in f"/{name}/" and last in ("wi", "wg", "wo")
    if is_moe:
        # expert axis is right after the (optional) stacked scan axis
        e_ax = 1 if 0 in skip else 0
        if shape[e_ax] % msize == 0:
            return _spec_with(nd, e_ax, MODEL)          # expert parallel
        # fall back to intra-expert TP on the ff dim
        ff_ax = nd - 1 if last in ("wi", "wg") else nd - 2
        if shape[ff_ax] % msize == 0:
            return _spec_with(nd, ff_ax, MODEL)
        return P()
    if last == "router":
        return P()
    if last == "embed":
        ax = 0 if shape[0] % msize == 0 else (1 if shape[1] % msize == 0
                                              else None)
        return _spec_with(nd, ax, MODEL)
    if last == "conv_w":
        ax = nd - 1 if shape[-1] % msize == 0 else None
        return _spec_with(nd, ax, MODEL)
    if _TP_OUT.search(last):
        ax = nd - 1 if shape[-1] % msize == 0 else None
        if ax is None:  # fall back: shard input dim
            ax = nd - 2 if nd >= 2 and shape[-2] % msize == 0 else None
        return _spec_with(nd, ax, MODEL)
    if _TP_IN.search(last):
        ax = nd - 2 if shape[-2] % msize == 0 else None
        if ax is None:
            ax = nd - 1 if shape[-1] % msize == 0 else None
        return _spec_with(nd, ax, MODEL)
    # unknown 2D+ leaf: basic_ws-style largest-dim fallback
    ax = _shard_largest(shape, msize, skip)
    return _spec_with(nd, ax, MODEL)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(batch, mesh, *, batch_axes=None):
    """Shard the leading (batch) dim of every input leaf over the data axes,
    dropping axes that don't divide."""
    if batch_axes is None:
        batch_axes = data_axes(mesh)

    def leaf(x):
        shape = np.shape(x)
        if not shape:
            return P()
        b = shape[0]
        axes = []
        prod = 1
        for a in batch_axes:
            n = mesh_axis_size(mesh, a)
            if b % (prod * n) == 0:
                axes.append(a)
                prod *= n
        if not axes:
            return P()
        return P(tuple(axes), *([None] * (len(shape) - 1)))

    return jax.tree.map(leaf, batch)


def cache_specs(caches, mesh, *, seq_axis_names=(MODEL,)):
    """Decode caches: batch dim over data axes when divisible; otherwise
    (long_500k batch=1) shard the cache sequence axis (context parallel).

    KV cache leaves: (n_periods, b, kv_heads, S, hd)
    SSM state leaves: (n_periods, b, heads, p, n) / conv (n_periods, b, cw-1, c)
    """
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh_axis_size(mesh, a) for a in daxes]))
    msize = mesh_axis_size(mesh, MODEL)

    def leaf(x):
        shape = np.shape(x)
        nd = len(shape)
        if nd < 2:
            return P()
        parts = [None] * nd
        b = shape[1]
        if b % dsize == 0:
            parts[1] = daxes if len(daxes) > 1 else daxes[0]
            # additionally shard the longest remaining dim over model
            rest = sorted(range(2, nd), key=lambda i: -shape[i])
            for i in rest:
                if shape[i] % msize == 0 and shape[i] >= 16:
                    parts[i] = MODEL
                    break
        else:
            # batch too small: context-parallel the biggest axis over
            # (data, model) combined when divisible, else over model only
            rest = sorted(range(2, nd), key=lambda i: -shape[i])
            for i in rest:
                if shape[i] % (dsize * msize) == 0 and shape[i] >= dsize * msize:
                    parts[i] = (*daxes, MODEL)
                    break
                if shape[i] % msize == 0 and shape[i] >= msize:
                    parts[i] = MODEL
                    break
        return P(*parts)

    return jax.tree.map(leaf, caches)


def to_named(tree_of_specs, mesh):
    """Wrap every PartitionSpec leaf into a NamedSharding on ``mesh`` —
    the form ``jax.jit(in_shardings=...)`` accepts."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda s: isinstance(s, P))
