"""Paper §6 / Theorems 1-2: the generalization-gap bound and its terms.

The bound (Thm 1, deep-net case):

    E[ell_M] - Ê_S[ell_B]  ≤  Q1/√m + Q2/√(2B) + c2·√(ln(2/δ)/2m)

We provide (a) the bound terms computed from an actual trained dual encoder
(Frobenius-norm products over tower weights stand in for the M_l), and (b) the
*empirical* normalized-loss gap measured on held-out data — the benchmark
shows both decrease with B at the predicted O(1/√B) rate.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.contrastive import normalized_train_loss


def _weight_matrices(tower_params):
    """All >=2D leaves of a tower, with stacked scan leaves unstacked."""
    mats = []

    def visit(x):
        x = np.asarray(x)
        if x.ndim == 2:
            mats.append(x)
        elif x.ndim > 2:
            for sub in x.reshape(-1, *x.shape[-2:]):
                mats.append(sub)

    jax.tree.map(visit, tower_params)
    return mats


def norm_product(tower_params) -> dict:
    """prod_l ||W_l||_F (log-space for stability) and the last-layer row sums
    used by Q1/Q2. Returns dict with log_prod, depth."""
    mats = _weight_matrices(tower_params)
    logs = [float(np.log(np.linalg.norm(m) + 1e-12)) for m in mats]
    return {"log_prod": float(np.sum(logs)), "depth": len(mats)}


def bound_terms(cfg, image_params_and_proj, text_params_and_proj,
                *, m: int, B: int, delta: float = 0.05,
                c_consts: dict = None) -> dict:
    """Evaluate Thm 1's three terms. The norm products are astronomically
    loose in absolute value (as Rademacher bounds are); the *informative*
    output is the B- and m-dependence, so we also return the normalized
    shape  gap_shape = 1/√m + 1/√(2B)."""
    c = {"c1": math.e, "c2": 10.0, "c3": 1.0, "c7": 1.0, "c8": 1.0,
         "c9": 1.0, "kappa": 64}
    if c_consts:
        c.update(c_consts)
    img = norm_product(image_params_and_proj)
    txt = norm_product(text_params_and_proj)

    L, Lp = txt["depth"], img["depth"]
    # log-space Q terms (Thm 1): keep logs; report both log and clipped value
    log_q11 = math.log(c["c7"] * (math.sqrt(2 * math.log(2) * L) + 1)) \
        + txt["log_prod"]
    log_q12 = math.log(c["c8"] * (math.sqrt(2 * math.log(2) * Lp) + 1)) \
        + img["log_prod"]
    q21 = 2 * math.sqrt(2) * c["c8"] * c["c9"] + c["c1"] * math.sqrt(
        c["kappa"] * math.log(math.sqrt(c["kappa"] * B) / delta))
    term_m = math.exp(min(log_q11, 700)) + math.exp(min(log_q12, 700))
    term_b = q21  # the norm part of Q2 shares the same product structure

    return {
        "term_1_over_sqrt_m": term_m / math.sqrt(m),
        "term_1_over_sqrt_2B": term_b / math.sqrt(2 * B),
        "term_conf": c["c2"] * math.sqrt(math.log(2 / delta) / (2 * m)),
        "gap_shape": 1 / math.sqrt(m) + 1 / math.sqrt(2 * B),
        "log_norm_product_text": txt["log_prod"],
        "log_norm_product_image": img["log_prod"],
    }


def empirical_gap(x_train, y_train, x_test, y_test) -> float:
    """Empirical E[ell_M] - Ê_S[ell_B] using the paper's normalized losses.

    x/y_*: (N, D) unit-norm embeddings. The test expectation E_y[exp(...)] is
    estimated with the full test set (the M→∞ surrogate)."""
    train = float(jnp.mean(normalized_train_loss(x_train, y_train)))
    s = jnp.einsum("id,jd->ij", x_test, y_test)
    num = jnp.exp(jnp.diagonal(s))
    den = jnp.mean(jnp.exp(s), axis=1)
    test = float(jnp.mean(-num / den))
    return test - train
