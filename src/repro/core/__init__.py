"""BASIC's primary contributions (paper §3-§6) as composable JAX modules."""
from repro.core.contrastive import contrastive_loss, similarity  # noqa: F401
from repro.core.distributed_loss import make_global_loss_fn  # noqa: F401
from repro.core.gradaccum import contrastive_step, microbatch_grads  # noqa: F401
from repro.core.remat import get_policy, list_policies  # noqa: F401
