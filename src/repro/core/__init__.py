"""BASIC's primary contributions (paper §3-§6) as composable JAX modules."""
from repro.core.contrastive import contrastive_loss, similarity  # noqa: F401
from repro.core.gradaccum import contrastive_step, microbatch_grads  # noqa: F401
from repro.core.remat import get_policy  # noqa: F401
