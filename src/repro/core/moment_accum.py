"""Paper §4.2: accumulate microbatch gradients directly into optimizer
moment slots — no extra ḡ buffer is ever allocated.

First moment (exact):  v1 ← β1·v1 + (1-β1)·ḡ  is decomposed into K sequential
updates  v1 ← k_i·v1 + ((1-β1)/K)·c_i  with k_1 = β1 and k_i = 1 otherwise.
(The paper's displayed k_i has a typo — "1/K" as the *carry* factor would
geometrically shrink the history; the correct decomposition scales the
*increment* by 1/K. Verified exact in tests.)

Second moment (approximate):  we can only accumulate Σc_i²/K = E[c²], but Adam
wants ḡ² = E[c]². The gap is Var[c] (paper Eq. 4), estimated from per-replica
gradients d_1..d_R of each microbatch:  Var[c] = Var[d]/R.  So

    v2 ← β2·v2 + (1-β2)·( E[c²] − VarHat[c] )

This module is optimizer-agnostic: it operates on (m1, m2) slot pytrees and a
stream of microbatch gradients; optim/adafactorw.py wires it into AdaFactorW.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def accumulate_first_moment(v1, c_stream, beta1: float):
    """v1 slots + stacked microbatch grads c_stream (K, ...) -> new v1.
    Exactly equals beta1*v1 + (1-beta1)*mean_K(c)."""
    K = jax.tree.leaves(c_stream)[0].shape[0]

    def step(v, i_c):
        i, c = i_c
        carry = jnp.where(i == 0, beta1, 1.0)
        return jax.tree.map(
            lambda vv, cc: carry * vv + ((1 - beta1) / K) * cc, v, c), None

    idx = jnp.arange(K)
    v1, _ = jax.lax.scan(step, v1, (idx, c_stream))
    return v1


def accumulate_second_moment(v2, c_stream, beta2: float, var_hat=None):
    """v2 slots + c_stream (K, ...) -> new v2 using the paper's estimator:
    beta2*v2 + (1-beta2)*(mean_K(c²) − var_hat).  var_hat defaults to 0
    (uncorrected); pass ``replica_variance`` output for the corrected form."""
    K = jax.tree.leaves(c_stream)[0].shape[0]

    def step(v, c):
        return jax.tree.map(lambda vv, cc: vv + (cc * cc) / K, v, c), None

    zero = jax.tree.map(jnp.zeros_like, v2)
    e_c2, _ = jax.lax.scan(step, zero, c_stream)
    if var_hat is not None:
        e_c2 = jax.tree.map(lambda a, b: jnp.maximum(a - b, 0.0), e_c2, var_hat)
    return jax.tree.map(lambda vv, ee: beta2 * vv + (1 - beta2) * ee, v2, e_c2)


def replica_variance(d_stream, R: int):
    """Per-replica gradients d_stream with leaves (K, R, ...) -> VarHat[c]
    (paper Eq. 4 applied twice: Var[c] = Var[g]/M = Var[d]·(M/R)/M/R... i.e.
    Var[c] = Var[d]/R), averaged over the K microbatches."""
    def per_leaf(d):
        c = jnp.mean(d, axis=1, keepdims=True)          # (K, 1, ...)
        var_d = jnp.mean((d - c) ** 2, axis=1)          # (K, ...)
        return jnp.mean(var_d, axis=0) / R
    return jax.tree.map(per_leaf, d_stream)


def exact_second_moment(v2, c_stream, beta2: float):
    """Ground truth (allocates ḡ): beta2*v2 + (1-beta2)*mean_K(c)²."""
    K = jax.tree.leaves(c_stream)[0].shape[0]
    gbar = jax.tree.map(lambda c: jnp.mean(c, axis=0), c_stream)
    del K
    return jax.tree.map(lambda vv, g: beta2 * vv + (1 - beta2) * g * g,
                        v2, gbar)
