from repro.eval.zero_shot import (  # noqa: F401
    class_embeddings,
    classify,
    evaluate_benchmark,
    evaluate_with_service,
    mean_per_class_recall,
    retrieval_recall_at_k,
    topk_accuracy,
)
