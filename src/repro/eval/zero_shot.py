"""Zero-shot open-vocabulary evaluation (paper §9.2 machinery).

Implements what the paper's eval actually does:
  - CLIP-style PROMPT ENSEMBLING: each class is rendered through several
    templates; the class embedding is the normalized mean of the prompt
    embeddings (Radford et al. §3.1.4, used by BASIC for comparability).
  - top-1 / top-5 accuracy and mean per-class recall (the paper's metric for
    Caltech/Flowers/Pets, App. C).
  - image<->text retrieval recall@K for contrastive sanity checks.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

DEFAULT_TEMPLATES = (
    "a photo of a {} {}",
    "a picture showing a {} {}",
    "the {} {}",
    "one {} {}, outdoors",
)


def class_embeddings(encode_text: Callable, tok, class_names: Sequence[str],
                     templates: Sequence[str] = DEFAULT_TEMPLATES,
                     text_len: int = 16, chunk_size: int = 512):
    """Prompt-ensembled class embeddings: (n_classes, D), unit norm.

    All classes × templates are tokenized up front and encoded in a few
    chunked batched passes (`chunk_size` prompts each, rounded down to a
    whole number of classes) instead of one ``encode_text`` per class —
    same returned shape and values as the per-class loop it replaced.
    """
    n_t = len(templates)
    ids = []
    for name in class_names:
        parts = name.split(" ", 1)
        ids.extend(tok.encode(t.format(*parts), max_len=text_len)
                   for t in templates)
    tokens, mask = tok.pad_batch(ids, max_len=text_len)
    chunk = max(n_t, chunk_size // n_t * n_t)
    embs = [encode_text({"tokens": jnp.asarray(tokens[s:s + chunk]),
                         "attn_mask": jnp.asarray(mask[s:s + chunk])})
            for s in range(0, len(ids), chunk)]
    emb = jnp.concatenate(embs, axis=0) if len(embs) > 1 else embs[0]
    mean = jnp.mean(emb.reshape(len(class_names), n_t, -1), axis=1)
    norm = jnp.linalg.norm(mean, axis=1, keepdims=True).clip(1e-6)
    return mean / norm


def classify(image_emb, class_emb):
    """Returns predicted class ids (b,) and the full logit matrix."""
    logits = image_emb @ class_emb.T
    return jnp.argmax(logits, axis=1), logits


def topk_accuracy(logits, labels, k: int = 1) -> float:
    logits = np.asarray(logits)
    labels = np.asarray(labels)
    k = min(k, logits.shape[1])
    top = np.argpartition(-logits, k - 1, axis=1)[:, :k]
    return float(np.mean(np.any(top == labels[:, None], axis=1)))


def mean_per_class_recall(logits, labels) -> float:
    pred = np.asarray(jnp.argmax(logits, axis=1))
    labels = np.asarray(labels)
    recalls = []
    for c in np.unique(labels):
        m = labels == c
        recalls.append(float(np.mean(pred[m] == c)))
    return float(np.mean(recalls))


def retrieval_recall_at_k(x_emb, y_emb, ks=(1, 5)) -> dict:
    """Paired retrieval: row i's positive is column i (both directions).
    The positive's rank is the count of strictly-better candidates in its
    row (vectorized; exact ties rank optimistically)."""
    sim = np.asarray(x_emb @ y_emb.T)
    out = {}
    for name, mat in (("i2t", sim), ("t2i", sim.T)):
        pos = np.diagonal(mat)
        ranks = np.sum(mat > pos[:, None], axis=1)
        for k in ks:
            out[f"{name}@{k}"] = float(np.mean(ranks < k))
    return out


def evaluate_benchmark(encode_image: Callable, encode_text: Callable, tok,
                       class_names: Sequence[str], images, labels,
                       templates: Sequence[str] = DEFAULT_TEMPLATES,
                       metric: str = "accuracy") -> dict:
    """One paper-style benchmark row. metric: 'accuracy' or 'recall'
    (mean per-class recall, App. C)."""
    cemb = class_embeddings(encode_text, tok, class_names, templates)
    iemb = encode_image(images)
    _, logits = classify(iemb, cemb)
    out = {
        "top1": topk_accuracy(logits, labels, 1),
        "top5": topk_accuracy(logits, labels, 5),
        "mean_per_class_recall": mean_per_class_recall(logits, labels),
        "n": int(np.shape(labels)[0]),
    }
    out["headline"] = out["top1"] if metric == "accuracy" else \
        out["mean_per_class_recall"]
    return out


def evaluate_with_service(service, class_names: Sequence[str], images,
                          labels, templates: Sequence[str] | None = None,
                          metric: str = "accuracy") -> dict:
    """Same benchmark row as ``evaluate_benchmark`` but served through a
    ``ZeroShotService`` (DESIGN.md §6): class embeddings come from its
    registry (computed once, persisted), image embeddings from the
    micro-batcher, and the metrics from the fused similarity→top-k kernel's
    indices — the (b, n_classes) logit matrix is never materialized."""
    labels = np.asarray(labels)
    res = service.classify(images, class_names, templates=templates,
                           k=min(5, len(class_names)))
    idx = np.asarray(res.indices)
    pred = idx[:, 0]
    recalls = [float(np.mean(pred[labels == c] == c))
               for c in np.unique(labels)]
    out = {
        "top1": float(np.mean(pred == labels)),
        "top5": float(np.mean(np.any(idx == labels[:, None], axis=1))),
        "mean_per_class_recall": float(np.mean(recalls)),
        "n": int(labels.shape[0]),
        "class_matrix_version": res.version,
    }
    out["headline"] = out["top1"] if metric == "accuracy" else \
        out["mean_per_class_recall"]
    return out
