"""Zero-shot open-vocabulary evaluation (paper §9.2 machinery).

Implements what the paper's eval actually does:
  - CLIP-style PROMPT ENSEMBLING: each class is rendered through several
    templates; the class embedding is the normalized mean of the prompt
    embeddings (Radford et al. §3.1.4, used by BASIC for comparability).
  - top-1 / top-5 accuracy and mean per-class recall (the paper's metric for
    Caltech/Flowers/Pets, App. C).
  - image<->text retrieval recall@K for contrastive sanity checks.
"""
from __future__ import annotations

from typing import Callable, List, Sequence

import jax.numpy as jnp
import numpy as np

DEFAULT_TEMPLATES = (
    "a photo of a {} {}",
    "a picture showing a {} {}",
    "the {} {}",
    "one {} {}, outdoors",
)


def class_embeddings(encode_text: Callable, tok, class_names: Sequence[str],
                     templates: Sequence[str] = DEFAULT_TEMPLATES,
                     text_len: int = 16):
    """Prompt-ensembled class embeddings: (n_classes, D), unit norm."""
    per_class = []
    for name in class_names:
        parts = name.split(" ", 1)
        ids = [tok.encode(t.format(*parts), max_len=text_len)
               for t in templates]
        tokens, mask = tok.pad_batch(ids, max_len=text_len)
        emb = encode_text({"tokens": jnp.asarray(tokens),
                           "attn_mask": jnp.asarray(mask)})
        mean = jnp.mean(emb, axis=0)
        per_class.append(mean / jnp.linalg.norm(mean).clip(1e-6))
    return jnp.stack(per_class)


def classify(image_emb, class_emb):
    """Returns predicted class ids (b,) and the full logit matrix."""
    logits = image_emb @ class_emb.T
    return jnp.argmax(logits, axis=1), logits


def topk_accuracy(logits, labels, k: int = 1) -> float:
    top = np.asarray(jnp.argsort(logits, axis=1))[:, ::-1][:, :k]
    labels = np.asarray(labels)
    return float(np.mean([labels[i] in top[i] for i in range(len(labels))]))


def mean_per_class_recall(logits, labels) -> float:
    pred = np.asarray(jnp.argmax(logits, axis=1))
    labels = np.asarray(labels)
    recalls = []
    for c in np.unique(labels):
        m = labels == c
        recalls.append(float(np.mean(pred[m] == c)))
    return float(np.mean(recalls))


def retrieval_recall_at_k(x_emb, y_emb, ks=(1, 5)) -> dict:
    """Paired retrieval: row i's positive is column i (both directions)."""
    sim = np.asarray(x_emb @ y_emb.T)
    n = sim.shape[0]
    out = {}
    for name, mat in (("i2t", sim), ("t2i", sim.T)):
        order = np.argsort(-mat, axis=1)
        ranks = np.array([np.where(order[i] == i)[0][0] for i in range(n)])
        for k in ks:
            out[f"{name}@{k}"] = float(np.mean(ranks < k))
    return out


def evaluate_benchmark(encode_image: Callable, encode_text: Callable, tok,
                       class_names: Sequence[str], images, labels,
                       templates: Sequence[str] = DEFAULT_TEMPLATES,
                       metric: str = "accuracy") -> dict:
    """One paper-style benchmark row. metric: 'accuracy' or 'recall'
    (mean per-class recall, App. C)."""
    cemb = class_embeddings(encode_text, tok, class_names, templates)
    iemb = encode_image(images)
    _, logits = classify(iemb, cemb)
    out = {
        "top1": topk_accuracy(logits, labels, 1),
        "top5": topk_accuracy(logits, labels, 5),
        "mean_per_class_recall": mean_per_class_recall(logits, labels),
        "n": int(np.shape(labels)[0]),
    }
    out["headline"] = out["top1"] if metric == "accuracy" else \
        out["mean_per_class_recall"]
    return out
