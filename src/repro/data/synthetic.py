"""Synthetic open-vocabulary image-text world (the ALIGN/JFT simulation).

repro=2 gate: the real 6.6B-pair dataset is proprietary, so we build a
*controllable* joint distribution whose zero-shot transfer is measurable:

- A latent concept space: ``n_classes`` concepts, each a unit vector in R^k
  plus attribute words drawn from a template grammar.
- Images: RAW PIXELS (b, H, W, C). Per patch, concept vector + noise is
  pushed through a fixed random "camera" map into ``patch_size²·C`` pixel
  values and the patch grid is assembled into the image — the inverse of
  the model's patchify frontend, so class evidence survives patchification
  exactly.
- Captions: templated natural-ish text ("a photo of a red tabby cat") using
  the concept's name words + sampled attributes — noisy, like alt-text.
- JFT analog: (image, class-id) pairs over the same concepts with multi-label
  class-name strings, enabling the paper's pretrain→contrastive recipe (§8).

Held-out concepts (never seen in contrastive training) measure
open-vocabulary generalization; benchmark tables are built on this.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

ADJECTIVES = ["red", "blue", "green", "small", "large", "striped", "spotted",
              "shiny", "old", "young", "wild", "fluffy", "sleek", "bright"]
NOUNS = ["cat", "dog", "bird", "fish", "tree", "car", "boat", "house",
         "flower", "horse", "plane", "train", "apple", "chair", "clock",
         "river", "mountain", "beetle", "lamp", "guitar", "violin", "drum",
         "bridge", "tower", "island", "lizard", "rabbit", "wolf", "bear",
         "eagle", "shark", "whale", "rose", "oak", "pine", "truck", "bicycle",
         "kettle", "mirror", "ladder"]
TEMPLATES = ["a photo of a {} {}", "the {} {}", "{} {} in the wild",
             "a picture showing a {} {}", "my {} {}", "one {} {}, outdoors"]


@dataclasses.dataclass
class World:
    """The synthetic joint distribution: latent concept vectors, the fixed
    camera map that renders them to pixels, class-name strings, and the
    image geometry every render matches (see module docstring)."""
    concept_vecs: np.ndarray      # (n_classes, k)
    camera: np.ndarray            # (k, patch_size²·channels) latent -> pixels
    class_names: List[str]
    image_size: int
    patch_size: int
    channels: int = 3
    noise: float = 0.35

    @property
    def n_classes(self):
        return self.concept_vecs.shape[0]

    @property
    def n_patches(self):
        return (self.image_size // self.patch_size) ** 2


def make_world(rng: np.random.Generator, n_classes=64, latent=32,
               image_size=16, patch_size=4, channels=3,
               noise=0.35) -> World:
    """Concepts are COMPOSITIONAL: class 'red cat' = v(red) + v(cat) in the
    latent space, so a model that learns the factors from seen classes can
    zero-shot transfer to unseen adjective-noun combinations — the toy analog
    of open-vocabulary generalization."""
    adj_vecs = rng.standard_normal((len(ADJECTIVES), latent))
    noun_vecs = rng.standard_normal((len(NOUNS), latent))
    names, vecs = [], []
    for i in range(n_classes):
        ai = (i * 5 + i // len(ADJECTIVES)) % len(ADJECTIVES)
        ni = i % len(NOUNS)
        names.append(f"{ADJECTIVES[ai]} {NOUNS[ni]}")
        vecs.append(adj_vecs[ai] + noun_vecs[ni])
    v = np.stack(vecs)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    pix = patch_size * patch_size * channels
    cam = rng.standard_normal((latent, pix)) / np.sqrt(latent)
    return World(v, cam, names, image_size, patch_size, channels, noise)


def world_for_tower(rng: np.random.Generator, tower, n_classes=64,
                    latent=32, noise=0.35) -> World:
    """A World whose image geometry matches a vision ArchConfig (the image
    tower of a dual encoder): same image_size/patch_size/channels, so
    rendered images feed the tower's patchify frontend directly."""
    return make_world(rng, n_classes=n_classes, latent=latent,
                      image_size=tower.image_size,
                      patch_size=tower.patch_size,
                      channels=tower.channels, noise=noise)


def render_images(world: World, cls: np.ndarray, rng: np.random.Generator):
    """cls: (b,) int -> RAW images (b, H, W, C) float32: per-patch noisy
    concept latents through the camera map, assembled on the patch grid."""
    b = cls.shape[0]
    g = world.image_size // world.patch_size
    ps, c = world.patch_size, world.channels
    z = world.concept_vecs[cls]                                  # (b, k)
    z = z[:, None, :] + world.noise * rng.standard_normal(
        (b, world.n_patches, z.shape[-1]))
    pix = (z @ world.camera).astype(np.float32)   # (b, P, ps*ps*C)
    pix = pix.reshape(b, g, g, ps, ps, c).transpose(0, 1, 3, 2, 4, 5)
    return np.ascontiguousarray(pix.reshape(b, g * ps, g * ps, c))


def render_captions(world: World, cls: np.ndarray, rng: np.random.Generator,
                    class_names: Optional[List[str]] = None) -> List[str]:
    """Noisy alt-text analog: one templated caption per class id in
    ``cls``, templates sampled from the grammar."""
    names = class_names or world.class_names
    out = []
    for c in cls:
        t = TEMPLATES[rng.integers(len(TEMPLATES))]
        out.append(t.format(*names[int(c)].split(" ", 1)))
    return out


def caption_corpus(world: World, rng: np.random.Generator, n=2000):
    """n sampled captions over the world's classes (tokenizer training /
    per-run corpora; the committed artifact trains on ``grammar_corpus``)."""
    cls = rng.integers(0, world.n_classes, n)
    return render_captions(world, cls, rng)


def grammar_corpus() -> List[str]:
    """EVERY caption the template grammar can produce: all adjective ×
    noun × template combinations, in a fixed deterministic order. No rng,
    no World — the closure of the caption language — so a tokenizer trained
    on it covers any world's captions and retrains bit-identically
    (the corpus behind ``artifacts/tokenizer_v1.json``)."""
    return [t.format(a, n) for a in ADJECTIVES for n in NOUNS
            for t in TEMPLATES]


def contrastive_batch(world: World, tok, batch: int, rng: np.random.Generator,
                      text_len=16, classes: Optional[np.ndarray] = None):
    """Returns ({'images': {...}, 'texts': {...}}, cls)."""
    pool = classes if classes is not None else np.arange(world.n_classes)
    cls = pool[rng.integers(0, len(pool), batch)]
    imgs = render_images(world, cls, rng)
    caps = render_captions(world, cls, rng)
    ids = [tok.encode(c, max_len=text_len) for c in caps]
    tokens, mask = tok.pad_batch(ids, max_len=text_len)
    return ({"images": {"image": imgs},
             "texts": {"tokens": tokens, "attn_mask": mask}}, cls)


def classification_prompts(world: World, tok, text_len=16,
                           template="a photo of a {} {}"):
    """CLIP-style class prompts for zero-shot eval."""
    ids = [tok.encode(template.format(*n.split(" ", 1)), max_len=text_len)
           for n in world.class_names]
    tokens, mask = tok.pad_batch(ids, max_len=text_len)
    return {"tokens": tokens, "attn_mask": mask}


def jft_batch(world: World, batch: int, rng: np.random.Generator,
              classes: Optional[np.ndarray] = None):
    """Labeled pretraining pairs (paper §8): (raw image, class id)."""
    pool = classes if classes is not None else np.arange(world.n_classes)
    cls = pool[rng.integers(0, len(pool), batch)]
    return {"image": render_images(world, cls, rng),
            "labels": cls.astype(np.int32)}, cls
