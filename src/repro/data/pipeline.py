"""Input pipeline: deterministic shard-aware batching with prefetch.

Host-side (numpy) generation, double-buffered via a background thread, with
per-host sharding (each host draws its slice of the global batch from a
host-indexed PRNG stream — the multi-host analog of the paper's input
distribution where "B examples are distributed equally to all cores").
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import numpy as np


class Prefetcher:
    """Wrap a batch-producing callable into a prefetching iterator.

    ``close()`` is idempotent and fully shuts the pipeline down: the worker
    thread exits, already-prefetched batches remain consumable, and once
    the queue drains ``__next__`` raises ``StopIteration``. ``__next__``
    waits with a timed get so a consumer blocked on an empty queue wakes
    up and terminates — after ``close()``, or when the worker died —
    instead of hanging forever (the historical deadlock); a worker killed
    by a ``make_batch`` exception re-raises it at the consumer."""

    def __init__(self, make_batch: Callable[[int], object], depth: int = 2,
                 start: int = 0):
        self._make = make_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._start = start
        self._stop = threading.Event()
        self._error: BaseException = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._start
        try:
            while not self._stop.is_set():
                try:
                    self._q.put(self._make(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue
        except BaseException as e:  # noqa: BLE001 — surfaced in __next__
            self._error = e

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            try:
                return self._q.get(timeout=0.2)
            except queue.Empty:
                if self._thread.is_alive():
                    continue
                # producer gone for good: surface its crash, else end
                if self._error is not None:
                    raise self._error
                raise StopIteration from None

    def close(self):
        """Stop prefetching (idempotent). Already-queued batches stay
        readable; after them, iteration ends with StopIteration."""
        if self._stop.is_set():
            return
        self._stop.set()
        self._thread.join()


def host_rng(seed: int, host_id: int, step: int) -> np.random.Generator:
    """Deterministic per-(host, step) stream."""
    return np.random.default_rng(
        np.random.SeedSequence([seed, host_id, step]))


def contrastive_stream(world, tok, global_batch: int, *, seed=0, host_id=0,
                       n_hosts=1, text_len=16, classes=None, depth=2):
    """Prefetching stream of host ``host_id``'s slice of the global batch
    (the legacy single-knob entry; ``data.sharded.ShardedLoader`` adds
    augmentation, resumable state, and device assembly on the same
    layout)."""
    if global_batch % n_hosts:
        raise ValueError(
            f"global batch {global_batch} must be divisible by n_hosts "
            f"{n_hosts} — each host draws an equal slice; a remainder "
            f"would silently shrink the global batch to "
            f"{global_batch // n_hosts * n_hosts}")
    local = global_batch // n_hosts
    from repro.data.synthetic import contrastive_batch

    def make(step):
        rng = host_rng(seed, host_id, step)
        batch, _ = contrastive_batch(world, tok, local, rng,
                                     text_len=text_len, classes=classes)
        return batch

    return Prefetcher(make, depth=depth)
