"""Input pipeline: deterministic shard-aware batching with prefetch.

Host-side (numpy) generation, double-buffered via a background thread, with
per-host sharding (each host draws its slice of the global batch from a
host-indexed PRNG stream — the multi-host analog of the paper's input
distribution where "B examples are distributed equally to all cores").
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import numpy as np


class Prefetcher:
    """Wrap a batch-producing callable into a prefetching iterator."""

    def __init__(self, make_batch: Callable[[int], object], depth: int = 2,
                 start: int = 0):
        self._make = make_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._start = start
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._start
        while not self._stop.is_set():
            try:
                self._q.put(self._make(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()


def host_rng(seed: int, host_id: int, step: int) -> np.random.Generator:
    """Deterministic per-(host, step) stream."""
    return np.random.default_rng(
        np.random.SeedSequence([seed, host_id, step]))


def contrastive_stream(world, tok, global_batch: int, *, seed=0, host_id=0,
                       n_hosts=1, text_len=16, classes=None, depth=2):
    local = global_batch // n_hosts
    from repro.data.synthetic import contrastive_batch

    def make(step):
        rng = host_rng(seed, host_id, step)
        batch, _ = contrastive_batch(world, tok, local, rng,
                                     text_len=text_len, classes=classes)
        return batch

    return Prefetcher(make, depth=depth)
