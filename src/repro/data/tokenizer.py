"""A small deterministic word-piece-style tokenizer (sentencepiece stand-in).

The paper trains a 32K sentencepiece model on 200M sampled captions and
filters sequences > 64 tokens (§7.1). We reproduce the *interface*: a
trainable vocab built from caption word frequencies, greedy longest-match
piece segmentation, and the 64-token length filter.

Identity: ``content_hash()`` fingerprints the piece inventory (sha256), so
two tokenizers that segment identically hash identically and a retrained
vocab is detectable everywhere the hash travels — checkpoints, the
class-embedding registry key, and resumable loader state. The committed
versioned artifact machinery lives in ``repro.data.sharded.artifact``.
"""
from __future__ import annotations

import collections
import hashlib
import re
from typing import Iterable, List

PAD, UNK, BOS, EOS = 0, 1, 2, 3
SPECIALS = ["<pad>", "<unk>", "<bos>", "<eos>"]
_WORD = re.compile(r"[a-z0-9]+|[^\sa-z0-9]")


class Tokenizer:
    """Greedy longest-match word-piece tokenizer over a trained piece list.

    ``version`` names the artifact the pieces came from ("v1" when loaded
    via ``repro.data.sharded.artifact``, "unversioned" for per-run
    training); it travels with the hash so provenance survives reload."""

    def __init__(self, pieces: List[str], version: str = "unversioned"):
        self.pieces = list(SPECIALS) + [p for p in pieces if p not in SPECIALS]
        self.index = {p: i for i, p in enumerate(self.pieces)}
        self.version = version

    @property
    def vocab_size(self) -> int:
        """Number of pieces including the 4 specials."""
        return len(self.pieces)

    def content_hash(self) -> str:
        """sha256 hex over the ordered piece inventory — the tokenizer's
        identity. Equal hash ⇒ identical segmentation of every input."""
        h = hashlib.sha256()
        for p in self.pieces:
            h.update(p.encode())
            h.update(b"\x00")
        return h.hexdigest()

    @classmethod
    def train(cls, corpus: Iterable[str], vocab_size: int = 32768,
              max_piece_len: int = 8) -> "Tokenizer":
        """Frequency-based piece selection: whole words first, then character
        n-grams of frequent words (a cheap BPE surrogate, deterministic)."""
        counts = collections.Counter()
        for text in corpus:
            for w in _WORD.findall(text.lower()):
                counts[w] += 1
        pieces = collections.Counter()
        for w, c in counts.items():
            pieces[w] += c
            for n in range(2, min(len(w), max_piece_len)):
                for i in range(len(w) - n + 1):
                    pieces[w[i:i + n]] += c // 4
        for ch in "abcdefghijklmnopqrstuvwxyz0123456789":
            pieces[ch] += 1  # guarantee coverage
        top = [p for p, _ in pieces.most_common(vocab_size - len(SPECIALS))]
        return cls(top)

    def _segment(self, word: str) -> List[int]:
        out, i = [], 0
        while i < len(word):
            for j in range(len(word), i, -1):
                piece = word[i:j]
                if piece in self.index:
                    out.append(self.index[piece])
                    i = j
                    break
            else:
                out.append(UNK)
                i += 1
        return out

    def encode(self, text: str, max_len: int = 64, add_special=True):
        """Token ids for ``text`` (lowercased, greedy longest-match pieces),
        truncated to ``max_len``. With ``add_special`` the sequence is
        BOS-prefixed and ALWAYS EOS-terminated — truncation keeps the final
        EOS (``ids[:max_len-1] + [EOS]``) instead of dropping it, so a
        pooled text tower never sees an unterminated caption."""
        ids: List[int] = [BOS] if add_special else []
        for w in _WORD.findall(text.lower()):
            ids.extend(self._segment(w))
        if add_special:
            ids.append(EOS)
        if len(ids) > max_len:   # paper §7.1: filter/truncate > 64 tokens
            ids = (ids[:max_len - 1] + [EOS]) if add_special \
                else ids[:max_len]
        return ids

    def pad_batch(self, seqs: List[List[int]], max_len: int = 64):
        """Right-pad id lists to ``(len(seqs), max_len)`` int32 plus the
        matching bool validity mask (True = real token)."""
        import numpy as np
        out = np.full((len(seqs), max_len), PAD, np.int32)
        mask = np.zeros((len(seqs), max_len), np.bool_)
        for i, s in enumerate(seqs):
            s = s[:max_len]
            out[i, :len(s)] = s
            mask[i, :len(s)] = True
        return out, mask
