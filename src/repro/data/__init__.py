from repro.data.pipeline import Prefetcher, contrastive_stream, host_rng  # noqa: F401
from repro.data.synthetic import (  # noqa: F401
    World,
    caption_corpus,
    classification_prompts,
    contrastive_batch,
    jft_batch,
    make_world,
    world_for_tower,
)
from repro.data.tokenizer import Tokenizer  # noqa: F401
