from repro.data.pipeline import Prefetcher, contrastive_stream, host_rng  # noqa: F401
from repro.data.sharded import (  # noqa: F401
    HostLayout,
    ShardedLoader,
    default_augmentations,
    load_tokenizer,
)
from repro.data.synthetic import (  # noqa: F401
    World,
    caption_corpus,
    classification_prompts,
    contrastive_batch,
    grammar_corpus,
    jft_batch,
    make_world,
    world_for_tower,
)
from repro.data.tokenizer import Tokenizer  # noqa: F401
