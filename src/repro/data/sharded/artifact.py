"""Versioned tokenizer artifact: train once, commit, load by version.

The paper trains its 32K sentencepiece model ONCE and ships it with the
model (§7.1); retraining the vocab changes every token id and silently
invalidates any checkpoint or cached class-embedding matrix built under the
old one. This module gives the repo's toy tokenizer the same lifecycle:

  build_default_tokenizer()   — deterministic training on the full caption
                                grammar (``synthetic.grammar_corpus``), so
                                rebuilding yields a byte-identical artifact
  save_tokenizer / load_tokenizer — JSON with the piece inventory + its
                                sha256; load verifies the hash and refuses
                                a tampered or hand-edited file
  artifacts/tokenizer_v1.json — the committed v1 artifact every launcher,
                                serving path, and eval harness loads

The artifact hash (``Tokenizer.content_hash``) is folded into the
class-embedding registry fingerprint (serving/embed/service.py) and into
resumable loader state (``sharded.loader.LoaderState``), so a vocab change
invalidates dependent artifacts BY CONSTRUCTION instead of by accident.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.data.synthetic import grammar_corpus
from repro.data.tokenizer import Tokenizer

FORMAT = "repro-tokenizer"
DEFAULT_VERSION = "v1"
DEFAULT_VOCAB = 512   # fits every smoke tower (vocab=min(cfg.vocab, 512))

# committed artifacts live at <repo>/artifacts/; overridable for tests and
# for deployments that ship artifacts separately from the source tree
ARTIFACTS_DIR = os.environ.get(
    "REPRO_ARTIFACTS_DIR",
    os.path.normpath(os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                  "..", "..", "..", "..", "artifacts")))


def artifact_path(version: str = DEFAULT_VERSION,
                  directory: Optional[str] = None) -> str:
    """Path of the ``tokenizer_<version>.json`` artifact under
    ``directory`` (default: the repo's committed ``artifacts/``)."""
    return os.path.join(directory or ARTIFACTS_DIR,
                        f"tokenizer_{version}.json")


def build_default_tokenizer(version: str = DEFAULT_VERSION) -> Tokenizer:
    """Train the canonical tokenizer: full grammar corpus, vocab 512.
    Pure function of the grammar — rebuilding cannot drift."""
    tok = Tokenizer.train(grammar_corpus(), vocab_size=DEFAULT_VOCAB)
    tok.version = version
    return tok


def save_tokenizer(tok: Tokenizer, path: str, *,
                   version: Optional[str] = None) -> str:
    """Serialize ``tok`` (pieces + sha256 + version) to ``path``; returns
    the path. The hash is stored so ``load_tokenizer`` can verify the file
    byte-for-byte reproduces the tokenizer that wrote it."""
    version = version or tok.version
    payload = {
        "format": FORMAT,
        "version": version,
        "vocab_size": tok.vocab_size,
        "sha256": tok.content_hash(),
        "pieces": tok.pieces,
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)
    return path


def load_tokenizer(version: str = DEFAULT_VERSION, *,
                   directory: Optional[str] = None,
                   path: Optional[str] = None) -> Tokenizer:
    """Load a versioned artifact (default: the committed v1). Verifies the
    stored sha256 against the reloaded piece inventory — a corrupted or
    hand-edited artifact fails loudly rather than mis-tokenizing."""
    path = path or artifact_path(version, directory)
    try:
        with open(path) as f:
            payload = json.load(f)
    except FileNotFoundError:
        raise FileNotFoundError(
            f"no tokenizer artifact at {path}; build it with "
            f"`python scripts/build_tokenizer.py`") from None
    if payload.get("format") != FORMAT:
        raise ValueError(f"{path} is not a {FORMAT} artifact "
                         f"(format={payload.get('format')!r})")
    tok = Tokenizer(payload["pieces"], version=payload["version"])
    if tok.content_hash() != payload["sha256"]:
        raise ValueError(
            f"{path} hash mismatch: artifact says {payload['sha256'][:16]}…"
            f" but pieces hash to {tok.content_hash()[:16]}… — the file was"
            f" edited or truncated; rebuild with scripts/build_tokenizer.py")
    if tok.vocab_size != payload["vocab_size"]:
        raise ValueError(f"{path} vocab_size {payload['vocab_size']} != "
                         f"reloaded {tok.vocab_size}")
    return tok
