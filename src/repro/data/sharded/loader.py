"""Multi-host sharded loader: each process draws its slice of the global
batch; slices reassemble bit-exactly (DESIGN.md §9).

The paper feeds a 65536 global batch "distributed equally to all cores";
reproducibility at that scale hinges on the input layout being a pure
function of ``(seed, step, layout)`` and nothing else. The layout here is
the per-host block decomposition the repo's PRNG streams already define:

    global_batch(step) = concat_h  draw(host_rng(seed, h, step), B/H)

Host ``h`` materializes ONLY its block (``local_batch_at``); a single
process — the simulated-multi-host trainer, or a test oracle — materializes
every block and concatenates (``global_batch_at``). Because each block is
keyed by ``(seed, h, step)`` and augmentation runs per block on a tagged
sibling stream, the two paths are byte-identical: shard-exactness is a
property of the keying, not of which process ran the numpy.

``device_put_global`` turns the host-side numpy tree into globally-sharded
``jax.Array``s via ``jax.make_array_from_process_local_data`` against a
training mesh — the multi-host-correct assembly (on a real pod each process
passes only its addressable slice; in the single-process simulation the
local data IS the global batch and XLA splits it over the data axes).

Resume: ``state()`` snapshots (seed, next step, host layout, tokenizer
hash/version, augmentation policy); ``restore()`` validates every field —
a retrained tokenizer or changed layout fails loudly instead of silently
replaying a different batch sequence — and rewinds the cursor, after which
the loader replays the exact batch sequence a never-interrupted run would
have produced.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.data.pipeline import Prefetcher, host_rng
from repro.data.sharded.augment import apply_ops
from repro.data.synthetic import World, contrastive_batch
from repro.obs import trace as obs_trace

# tags the augmentation stream so it never collides with the batch-draw
# stream at the same (seed, host, step) key
_AUG_STREAM_TAG = 0xA06


def aug_rng(seed: int, host_id: int, step: int) -> np.random.Generator:
    """Deterministic per-(host, step) augmentation stream, disjoint from
    ``host_rng``'s batch-draw stream at the same key."""
    return np.random.default_rng(
        np.random.SeedSequence([seed, host_id, step, _AUG_STREAM_TAG]))


@dataclasses.dataclass(frozen=True)
class HostLayout:
    """One process's coordinates in the input decomposition: ``n_hosts``
    equal blocks per global batch, this process owning block ``host_id``.
    In the single-process simulation n_hosts tracks the mesh's data extent
    so block h lands on data shard h."""
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        if self.n_hosts < 1 or not 0 <= self.host_id < self.n_hosts:
            raise ValueError(f"invalid host layout: host {self.host_id} "
                             f"of {self.n_hosts}")


@dataclasses.dataclass(frozen=True)
class LoaderState:
    """Resumable input-state snapshot: everything needed to replay the
    exact batch sequence — persisted as checkpoint user-meta through
    ``checkpoint.io`` step dirs (``save(..., meta=...)``).

    ``augment`` stores op REPRS (e.g. ``"RandomCrop(pad=2)"``), not just
    names, so a resumed run with different op parameters fails validation;
    ``classes_sha`` digests an explicit class pool (empty = full world)."""
    seed: int
    step: int                 # next step the loader will produce
    global_batch: int
    text_len: int
    n_hosts: int
    host_id: int
    tokenizer_sha: str        # Tokenizer.content_hash() at save time
    tokenizer_version: str
    augment: Tuple[str, ...]  # op reprs, pipeline order
    classes_sha: str = ""     # sha256 of the classes array, "" when None

    def to_json(self) -> dict:
        """Plain-JSON form (for checkpoint user-meta)."""
        d = dataclasses.asdict(self)
        d["augment"] = list(self.augment)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "LoaderState":
        """Inverse of ``to_json``."""
        return cls(seed=int(d["seed"]), step=int(d["step"]),
                   global_batch=int(d["global_batch"]),
                   text_len=int(d["text_len"]),
                   n_hosts=int(d["n_hosts"]), host_id=int(d["host_id"]),
                   tokenizer_sha=str(d["tokenizer_sha"]),
                   tokenizer_version=str(d["tokenizer_version"]),
                   augment=tuple(d["augment"]),
                   classes_sha=str(d.get("classes_sha", "")))


class ShardedLoader:
    """Shard-exact contrastive input stream for one host of ``layout``.

    Iterating yields this host's local batches (advancing the cursor);
    ``global_batch_at`` materializes all blocks for single-process
    training/oracles. Batches are the standard contrastive tree
    ``{'images': {'image'}, 'texts': {'tokens', 'attn_mask'}}``.
    """

    def __init__(self, world: World, tok, global_batch: int, *,
                 layout: HostLayout = HostLayout(), seed: int = 0,
                 text_len: int = 16, classes: Optional[np.ndarray] = None,
                 augment: Sequence = (), start_step: int = 0,
                 registry=None, tracer=None):
        if global_batch % layout.n_hosts:
            raise ValueError(
                f"global batch {global_batch} must be divisible by "
                f"n_hosts {layout.n_hosts} (each host gets an equal block; "
                f"got remainder {global_batch % layout.n_hosts})")
        self.world, self.tok = world, tok
        self.global_batch = int(global_batch)
        self.layout = layout
        self.seed = int(seed)
        self.text_len = int(text_len)
        self.classes = classes
        self.augment = tuple(augment)
        self._step = int(start_step)
        # telemetry (DESIGN.md §11): per-host block-generation timing into
        # ``registry`` histograms and ``tracer`` spans on pid lane
        # 1+host_id (the trace's simulated-host lanes); both optional and
        # free when None
        self._registry = registry
        self._tracer = tracer
        self._h_gen = None if registry is None else {
            h: registry.histogram("data/gen_seconds", host=h)
            for h in range(layout.n_hosts)}
        self._h_global = None if registry is None else \
            registry.histogram("data/global_batch_seconds")

    @property
    def local_batch(self) -> int:
        """Rows this host contributes per step (B / n_hosts)."""
        return self.global_batch // self.layout.n_hosts

    # -- batch materialization --------------------------------------------
    def _block(self, step: int, host_id: int) -> dict:
        t0 = time.perf_counter()
        with obs_trace.span(self._tracer, "host_block", pid=1 + host_id,
                            step=step, host=host_id):
            rng = host_rng(self.seed, host_id, step)
            batch, _ = contrastive_batch(self.world, self.tok,
                                         self.local_batch, rng,
                                         text_len=self.text_len,
                                         classes=self.classes)
            if self.augment:
                batch["images"]["image"] = apply_ops(
                    self.augment, batch["images"]["image"],
                    aug_rng(self.seed, host_id, step))
        if self._h_gen is not None:
            self._h_gen[host_id].observe(time.perf_counter() - t0)
        return batch

    def local_batch_at(self, step: int) -> dict:
        """This host's block of step ``step`` (pure function of
        (seed, layout.host_id, step) — no cursor side effects)."""
        return self._block(step, self.layout.host_id)

    def global_batch_at(self, step: int) -> dict:
        """The full global batch of step ``step``: every host's block,
        concatenated in host order (the single-process materialization and
        the oracle the two-host test reassembles against)."""
        import jax
        t0 = time.perf_counter()
        blocks = [self._block(step, h) for h in range(self.layout.n_hosts)]
        out = jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *blocks)
        if self._h_global is not None:
            self._h_global.observe(time.perf_counter() - t0)
        return out

    # -- iteration ---------------------------------------------------------
    def __iter__(self):
        return self

    def __next__(self) -> dict:
        """The next LOCAL batch; advances the resumable cursor."""
        b = self.local_batch_at(self._step)
        self._step += 1
        return b

    def stream(self, *, global_batches: bool = False,
               depth: int = 2) -> "_CursorStream":
        """Background-prefetching iterator from the current cursor
        (local blocks, or full global batches for the single-process
        trainer). Each CONSUMED batch advances the loader's cursor — the
        Prefetcher may have produced further ahead, but ``state()`` after
        n ``next()`` calls snapshots exactly step ``cursor + n``, so a
        checkpoint taken mid-stream resumes without replaying or skipping
        batches."""
        make = self.global_batch_at if global_batches else self.local_batch_at
        return _CursorStream(self, Prefetcher(make, depth=depth,
                                              start=self._step))

    # -- resumable state ---------------------------------------------------
    def state(self, step: Optional[int] = None) -> LoaderState:
        """Snapshot at ``step`` (default: the cursor): seed, next step,
        batch geometry, host layout, tokenizer hash/version, augmentation
        policy (op reprs, so parameters are captured), class pool."""
        import hashlib
        classes_sha = "" if self.classes is None else hashlib.sha256(
            np.ascontiguousarray(np.asarray(self.classes)).tobytes()
        ).hexdigest()
        return LoaderState(
            seed=self.seed,
            step=self._step if step is None else int(step),
            global_batch=self.global_batch, text_len=self.text_len,
            n_hosts=self.layout.n_hosts, host_id=self.layout.host_id,
            tokenizer_sha=self.tok.content_hash(),
            tokenizer_version=getattr(self.tok, "version", "unversioned"),
            augment=tuple(repr(op) for op in self.augment),
            classes_sha=classes_sha)

    def restore(self, state: LoaderState) -> None:
        """Rewind to ``state`` after validating it belongs to THIS
        configuration — every field except the cursor must match: seed,
        batch geometry, host layout, augmentation policy (parameters
        included), class pool, and the tokenizer artifact hash. A mismatch
        means the resumed run would replay a DIFFERENT batch sequence than
        the one checkpointed (the failure mode versioned artifacts exist
        to prevent), so it raises instead."""
        mine = self.state(step=state.step)
        for field in ("seed", "global_batch", "text_len", "n_hosts",
                      "host_id", "tokenizer_sha", "augment", "classes_sha"):
            got, want = getattr(mine, field), getattr(state, field)
            if got != want:
                raise ValueError(
                    f"loader state mismatch on {field}: checkpoint has "
                    f"{want!r}, this loader has {got!r}"
                    + (" — the tokenizer artifact changed since the "
                       "checkpoint was written; load the matching version"
                       if field == "tokenizer_sha" else ""))
        self._step = state.step


class _CursorStream:
    """Prefetching iterator that advances its loader's resumable cursor on
    every CONSUMED batch (production may run ahead in the background;
    consumption is what a checkpoint must not replay)."""

    def __init__(self, loader: ShardedLoader, prefetcher: Prefetcher):
        self._loader = loader
        self._pf = prefetcher

    def __iter__(self):
        return self

    def __next__(self):
        batch = next(self._pf)           # raises StopIteration when closed
        self._loader._step += 1
        return batch

    def close(self):
        """Stop the underlying Prefetcher (idempotent)."""
        self._pf.close()


def device_put_global(batch, mesh, *, batch_axes=None):
    """Host-side numpy batch tree -> globally-sharded ``jax.Array``s laid
    out batch-over-data on ``mesh`` via
    ``jax.make_array_from_process_local_data`` (specs from
    ``core.sharding.batch_specs``; ``batch_axes`` overrides the data axes,
    e.g. §5.1 batch-over-all-cores). In multi-process each host passes its
    local rows; single-process, the local data is the whole batch."""
    import jax

    from repro.core import sharding as shd
    specs = shd.batch_specs(batch, mesh, batch_axes=batch_axes)
    return jax.tree.map(
        lambda x, spec: jax.make_array_from_process_local_data(
            jax.NamedSharding(mesh, spec), np.asarray(x)),
        batch, specs)
