"""Host-side image augmentation ops (paper §7.1 crop/flip analog).

ALIGN/BASIC train on noisy web pairs with light augmentation; CLIP uses
random-crop only. Here each op is a frozen dataclass acting on a RAW image
batch ``(b, H, W, C)`` float32 with an explicit ``np.random.Generator`` —
no global state — so an augmented batch is a pure function of
``(ops, images, rng)``. The sharded loader derives that rng from the SAME
``(seed, host, step)`` key family as the batch draw (tagged so the two
streams stay disjoint), which gives the two properties the input subsystem
guarantees (DESIGN.md §9):

  determinism  — same (seed, host, step) ⇒ bit-identical augmented batch,
  shard-exactness — augmentation is applied per host block with that
      block's rng, so a multi-host run and a single-process run that
      materializes all blocks produce byte-identical global batches.

Ops are composed with ``apply_ops`` in list order. ``from_names`` rebuilds
a default-parameter pipeline from op names (e.g. a CLI flag); resumable
``LoaderState`` persists full op REPRS so restore validation catches
parameter changes, not just pipeline membership.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class RandomCrop:
    """Random crop/patch jitter: edge-pad by ``pad`` pixels on each side,
    then crop back to the original size at a per-image random offset in
    ``[0, 2·pad]²`` — image content shifts by up to ±pad pixels, the toy
    analog of CLIP's random square crop."""
    pad: int = 2

    name = "random_crop"

    def __call__(self, images: np.ndarray,
                 rng: np.random.Generator) -> np.ndarray:
        """images: (b, H, W, C) -> same shape, per-image jittered."""
        b, hh, ww, _ = images.shape
        p = int(self.pad)
        if p == 0:
            return images
        padded = np.pad(images, ((0, 0), (p, p), (p, p), (0, 0)),
                        mode="edge")
        oy = rng.integers(0, 2 * p + 1, b)
        ox = rng.integers(0, 2 * p + 1, b)
        out = np.empty_like(images)
        for i in range(b):
            out[i] = padded[i, oy[i]:oy[i] + hh, ox[i]:ox[i] + ww]
        return out


@dataclasses.dataclass(frozen=True)
class HorizontalFlip:
    """Mirror each image left-right with probability ``prob``."""
    prob: float = 0.5

    name = "hflip"

    def __call__(self, images: np.ndarray,
                 rng: np.random.Generator) -> np.ndarray:
        """images: (b, H, W, C) -> same shape, a random subset mirrored."""
        flip = rng.random(images.shape[0]) < self.prob
        out = images.copy()
        out[flip] = out[flip, :, ::-1, :]
        return out


@dataclasses.dataclass(frozen=True)
class ChannelNoise:
    """Photometric jitter: per-image-per-channel gain ``1 ± scale`` plus
    i.i.d. gaussian pixel noise of the same scale — the 'noisy alt-text
    pair' analog on the image side."""
    scale: float = 0.05

    name = "channel_noise"

    def __call__(self, images: np.ndarray,
                 rng: np.random.Generator) -> np.ndarray:
        """images: (b, H, W, C) -> same shape, jittered float32."""
        b, _, _, c = images.shape
        gain = 1.0 + self.scale * rng.standard_normal((b, 1, 1, c))
        noise = self.scale * rng.standard_normal(images.shape)
        return (images * gain + noise).astype(images.dtype)


_OPS = {op.name: op for op in (RandomCrop, HorizontalFlip, ChannelNoise)}


def default_augmentations() -> Tuple:
    """The standard train-time pipeline: crop jitter → flip → noise."""
    return (RandomCrop(), HorizontalFlip(), ChannelNoise())


def from_names(names: Sequence[str]) -> Tuple:
    """Rebuild a default-parameter pipeline from persisted op names (the
    inverse of ``[op.name for op in ops]``; unknown names raise)."""
    try:
        return tuple(_OPS[n]() for n in names)
    except KeyError as e:
        raise KeyError(f"unknown augmentation {e.args[0]!r}; "
                       f"have {sorted(_OPS)}") from None


def apply_ops(ops: Sequence, images: np.ndarray,
              rng: np.random.Generator) -> np.ndarray:
    """Run ``ops`` over ``images`` in order with one shared rng stream.
    Empty ``ops`` returns the input unchanged (and un-copied)."""
    for op in ops:
        images = op(images, rng)
    return images
