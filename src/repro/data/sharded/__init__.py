from repro.data.sharded.artifact import (  # noqa: F401
    build_default_tokenizer,
    load_tokenizer,
    save_tokenizer,
)
from repro.data.sharded.augment import (  # noqa: F401
    ChannelNoise,
    HorizontalFlip,
    RandomCrop,
    apply_ops,
    default_augmentations,
)
from repro.data.sharded.loader import (  # noqa: F401
    HostLayout,
    LoaderState,
    ShardedLoader,
    aug_rng,
    device_put_global,
)
