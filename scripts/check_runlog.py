"""Runlog schema gate: validate a runlog JSONL against obs schema v1.

Checks every record of a runlog (committed sample or fresh run output)
with ``repro.obs.runlog.validate_record`` — schema version, known kinds,
required per-kind keys (``anomaly`` records included: detector, step,
severity, value) — plus file-level structure: the first record must be
``run_start``, step records must carry the full time-breakdown
(``data_wait_s`` / ``device_step_s`` / ``ckpt_stall_s``), and resumed
segments must be announced by ``resume`` markers (step numbers may only
restart right after one).

  PYTHONPATH=src python scripts/check_runlog.py <runlog.jsonl> [...]

Exit 1 with one line per offender; exit 0 with a summary when clean.
Wired into tier-1 via tests/test_obs.py (the committed
``artifacts/runlog_sample.jsonl``) and tests/test_train_distributed.py
(a fresh smoke run's output).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(_ROOT, "src") not in sys.path:
    sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.obs import runlog as rl  # noqa: E402


def check_file(path: str) -> list[str]:
    """All schema violations in ``path`` as '<path>:<line>: <error>'
    lines (empty = valid)."""
    failures = []
    records = []
    with open(path) as f:
        lines = f.read().splitlines()
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            if i == len(lines) - 1:
                continue            # torn final line: crash mid-write
            failures.append(f"{path}:{i + 1}: unparseable JSON ({e})")
            continue
        for err in rl.validate_record(rec):
            failures.append(f"{path}:{i + 1}: {err}")
        records.append((i + 1, rec))
    if not records:
        failures.append(f"{path}:1: empty runlog")
        return failures
    if records[0][1].get("kind") != "run_start":
        failures.append(f"{path}:{records[0][0]}: first record is "
                        f"{records[0][1].get('kind')!r}, not 'run_start'")
    prev_step, resume_pending = None, False
    for lineno, rec in records:
        kind = rec.get("kind")
        if kind == "resume":
            resume_pending = True
        elif kind == "step":
            step = rec.get("step")
            if prev_step is not None and isinstance(step, int) \
                    and step <= prev_step and not resume_pending:
                failures.append(
                    f"{path}:{lineno}: step {step} after {prev_step} "
                    f"without a resume marker (interleaved runs?)")
            if isinstance(step, int):
                prev_step = step
            resume_pending = False
    return failures


def main(argv=None) -> int:
    """CLI entry: validate each runlog path; 0 = all clean."""
    ap = argparse.ArgumentParser(
        description="validate runlog JSONL files against the obs schema "
                    "(v%d)" % rl.SCHEMA_VERSION)
    ap.add_argument("paths", nargs="+", help="runlog.jsonl file(s)")
    args = ap.parse_args(argv)
    failed = 0
    for path in args.paths:
        failures = check_file(path)
        for line in failures:
            print(f"check_runlog: INVALID {line}", file=sys.stderr)
        if failures:
            failed += 1
        else:
            recs = list(rl.iter_runlog(path))
            n_anom = sum(1 for r in recs if r["kind"] == "anomaly")
            anom = f", {n_anom} anomalies" if n_anom else ""
            print(f"check_runlog: OK {path} ({len(recs)} records, schema v"
                  f"{rl.SCHEMA_VERSION}{anom})")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
