"""(Re)build the committed tokenizer artifact (DESIGN.md §9).

Trains the canonical tokenizer on the FULL caption grammar (every
adjective × noun × template — deterministic, no sampling) and writes
``artifacts/tokenizer_<version>.json``. Rebuilding from an unchanged
grammar is byte-identical, so a dirty ``git diff`` after running this
script means the caption grammar or the trainer changed — i.e. the vocab
really is a new version and should be committed as one (bump --version
and keep the old artifact for checkpoints trained under it).

  python scripts/build_tokenizer.py [--version v1] [--check]

``--check`` verifies the committed artifact matches a fresh rebuild
(exit 1 on drift) without writing anything.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.data.sharded import artifact  # noqa: E402


def main(argv=None) -> int:
    """Build (or --check) the versioned tokenizer artifact; returns rc."""
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--version", default=artifact.DEFAULT_VERSION)
    ap.add_argument("--out", default=None,
                    help="output path (default: artifacts/tokenizer_<v>.json)")
    ap.add_argument("--check", action="store_true",
                    help="verify the committed artifact matches a fresh "
                         "rebuild; write nothing")
    args = ap.parse_args(argv)

    tok = artifact.build_default_tokenizer(args.version)
    path = args.out or artifact.artifact_path(args.version)
    if args.check:
        committed = artifact.load_tokenizer(args.version, path=path)
        if committed.content_hash() != tok.content_hash():
            print(f"build_tokenizer: DRIFT — {path} hashes "
                  f"{committed.content_hash()[:16]}… but a fresh rebuild "
                  f"hashes {tok.content_hash()[:16]}…; the grammar or "
                  f"trainer changed, bump --version", file=sys.stderr)
            return 1
        print(f"build_tokenizer: OK ({path} matches rebuild, "
              f"vocab {tok.vocab_size}, sha {tok.content_hash()[:16]}…)")
        return 0
    artifact.save_tokenizer(tok, path, version=args.version)
    print(f"build_tokenizer: wrote {path} (vocab {tok.vocab_size}, "
          f"sha {tok.content_hash()[:16]}…)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
