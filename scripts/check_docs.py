"""Docstring gate for the public API surface (ISSUE-3 satellite).

Fails (exit 1, one line per offender) when a public symbol in the covered
modules lacks a docstring:

  - every module under src/repro/core/
  - every kernels public-op module src/repro/kernels/*/ops.py
  - every module under src/repro/serving/embed/ and serving/retrieval/
  - every module under src/repro/models/ (the tower runtime)
  - every module under src/repro/data/ incl. data/sharded/ (the input
    subsystem, ISSUE-5)
  - every module under src/repro/checkpoint/ (ISSUE-6)
  - every module under src/repro/obs/ (the telemetry subsystem, ISSUE-7)

"Public" = top-level ``def``/``class`` whose name has no leading
underscore, plus the module itself (module docstring required). Purely
AST-based — nothing is imported, so the gate runs on hosts without jax.

Wired into tier-1 as tests/test_docs.py; run standalone with

  python scripts/check_docs.py [--root PATH]
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
from glob import glob

_DEFAULT_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

COVERED_GLOBS = (
    os.path.join("src", "repro", "core", "*.py"),
    os.path.join("src", "repro", "kernels", "*", "ops.py"),
    os.path.join("src", "repro", "serving", "*.py"),
    os.path.join("src", "repro", "serving", "embed", "*.py"),
    os.path.join("src", "repro", "serving", "retrieval", "*.py"),
    os.path.join("src", "repro", "models", "*.py"),
    os.path.join("src", "repro", "data", "*.py"),
    os.path.join("src", "repro", "data", "sharded", "*.py"),
    os.path.join("src", "repro", "checkpoint", "*.py"),
    os.path.join("src", "repro", "obs", "*.py"),
)


def covered_files(root: str = _DEFAULT_ROOT) -> list[str]:
    """The source files the gate covers, sorted, as paths under ``root``."""
    out = []
    for pat in COVERED_GLOBS:
        out.extend(glob(os.path.join(root, pat)))
    return sorted(out)


def missing_docstrings(path: str, root: str = _DEFAULT_ROOT) -> list[str]:
    """Public symbols in ``path`` lacking docstrings, as
    '<relpath-under-root>:<line>: <kind> <name>' lines (empty = clean)."""
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    rel = os.path.relpath(path, root)
    failures = []
    if not ast.get_docstring(tree) and os.path.basename(path) != "__init__.py":
        failures.append(f"{rel}:1: module {os.path.basename(path)}")
    for node in tree.body:
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            continue
        if node.name.startswith("_"):
            continue
        if not ast.get_docstring(node):
            kind = "class" if isinstance(node, ast.ClassDef) else "function"
            failures.append(f"{rel}:{node.lineno}: {kind} {node.name}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail when a public symbol in core/, kernels/*/ops.py, "
                    "serving/embed/ or models/ lacks a docstring")
    ap.add_argument("--root", default=_DEFAULT_ROOT,
                    help="repo root (default: this script's parent)")
    args = ap.parse_args(argv)

    files = covered_files(args.root)
    if not files:
        print(f"check_docs: no covered files under {args.root}",
              file=sys.stderr)
        return 1
    failures = []
    for path in files:
        failures.extend(missing_docstrings(path, args.root))
    for line in failures:
        print(f"check_docs: MISSING DOCSTRING {line}", file=sys.stderr)
    if failures:
        print(f"check_docs: {len(failures)} public symbols undocumented "
              f"across {len(files)} files", file=sys.stderr)
        return 1
    print(f"check_docs: OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
