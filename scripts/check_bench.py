"""Perf-regression gate: diff a fresh bench JSON (BENCH_kernels.json,
BENCH_serving.json) against the committed baseline and fail on >1.3×
slowdown of any entry.

Used standalone (``python scripts/check_bench.py NEW.json --baseline X``)
and by ``benchmarks/run.py --json``, which regenerates each committed bench
file and then compares it to the previously committed content (DESIGN.md
§5, §6.4). Entries present on only one side are reported but never fail the
check (new shapes or paths are allowed to appear/retire); only matched
entries gate.

Entries may additionally carry ``"must_beat": "<other entry>"`` — an
intra-run invariant (e.g. the fused similarity→top-k kernel must beat the
materializing reference at 100k classes) that fails whenever the entry is
not strictly faster than its target in the FRESH run, host speed
notwithstanding.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys

THRESHOLD = 1.3

# Shared bench hosts drift globally (noisy neighbors, turbo state): every
# entry — including the code-stable jnp reference paths — can shift 1.5-2x
# between runs. The median new/baseline ratio over the reference entries
# (first path segment ``ref`` or ``*_ref``, whose implementation no kernel
# change touches) estimates that host factor and is divided out, so the
# gate fires on *relative* regressions — which a kernel change actually
# causes, even when it hits both Pallas paths through a shared helper. The
# anchor uses ALL matched ref entries (no floor: it is a median, and small
# files like BENCH_serving.json have few refs). When too few ref entries
# match, the median over all gated entries is the (weaker) fallback anchor.
_MIN_REF_ENTRIES_FOR_NORMALIZATION = 3
_MIN_ENTRIES_FOR_NORMALIZATION = 6

# Sub-50ms calls on CPU-interpret hosts jitter 2-3x run to run even with
# min-of-N timing; gating them would make the check flappy. Entries below
# the floor are reported but never fail (the ≥50ms entries — the large
# shapes the perf work actually targets — carry the gate). On compiled
# accelerator baselines (meta.interpret false on both sides) timings are
# stable at sub-ms scale, so no floor applies — otherwise a fast-TPU
# baseline would silently gate nothing.
_MIN_GATED_BASELINE_US = 50_000.0


def _floor(new: dict, baseline: dict) -> float:
    interp = (new.get("meta", {}).get("interpret", True)
              or baseline.get("meta", {}).get("interpret", True))
    return _MIN_GATED_BASELINE_US if interp else 0.0


def _is_ref(name: str) -> bool:
    head = name.split("/", 1)[0]
    return head == "ref" or head.endswith("_ref")


def _gated_ratios(new: dict, baseline: dict) -> dict:
    base_entries = baseline.get("entries", {})
    new_entries = new.get("entries", {})
    floor = _floor(new, baseline)
    return {name: new_entries[name]["us"] / base_entries[name]["us"]
            for name in sorted(new_entries)
            if name in base_entries and base_entries[name]["us"] >= floor
            and base_entries[name]["us"] > 0
            and not base_entries[name].get("ungated")
            and not new_entries[name].get("ungated")}


def compare(new: dict, baseline: dict,
            threshold: float = THRESHOLD) -> list[str]:
    """Returns a list of human-readable regression failures (empty = pass)."""
    ratios = _gated_ratios(new, baseline)

    base_entries = baseline.get("entries", {})
    new_entries = new.get("entries", {})
    floor = _floor(new, baseline)
    ref_all = {name: new_entries[name]["us"] / base_entries[name]["us"]
               for name in sorted(new_entries)
               if name in base_entries and _is_ref(name)
               and base_entries[name]["us"] > 0}
    # prefer above-floor refs (sub-floor timings jitter 2-3x, see _floor);
    # small files with few refs fall back to every matched ref — a median
    # over all of them still beats no anchor at all
    ref_above = [r for name, r in ref_all.items()
                 if base_entries[name]["us"] >= floor]
    ref_ratios = ref_above if \
        len(ref_above) >= _MIN_REF_ENTRIES_FOR_NORMALIZATION \
        else list(ref_all.values())
    if len(ref_ratios) >= _MIN_REF_ENTRIES_FOR_NORMALIZATION:
        host_factor = statistics.median(ref_ratios)
    elif len(ratios) >= _MIN_ENTRIES_FOR_NORMALIZATION:
        host_factor = statistics.median(ratios.values())
    else:
        host_factor = 1.0

    failures = []
    for name, ratio in ratios.items():
        if ratio > threshold * host_factor:
            failures.append(
                f"{name}: {new_entries[name]['us']:.1f}us vs baseline "
                f"{base_entries[name]['us']:.1f}us ({ratio:.2f}x > "
                f"{threshold}x with host factor {host_factor:.2f})")
    failures.extend(must_beat_failures(new))
    return failures


def must_beat_failures(new: dict) -> list[str]:
    """Intra-run invariants: entry X must be strictly faster than entry Y."""
    entries = new.get("entries", {})
    failures = []
    for name, e in sorted(entries.items()):
        target = e.get("must_beat")
        if target is None:
            continue
        if target not in entries:
            failures.append(f"{name}: must_beat target {target} missing "
                            f"from this run")
        elif e["us"] >= entries[target]["us"]:
            failures.append(
                f"{name}: {e['us']:.1f}us does not beat {target} "
                f"({entries[target]['us']:.1f}us)")
    return failures


def summarize(new: dict, baseline: dict) -> str:
    base_keys = set(baseline.get("entries", {}))
    new_keys = set(new.get("entries", {}))
    gated = len(_gated_ratios(new, baseline))
    lines = [f"gating {gated} of {len(base_keys & new_keys)} matched entries"]
    if new_keys - base_keys:
        lines.append(f"new (ungated): {sorted(new_keys - base_keys)}")
    if base_keys - new_keys:
        lines.append(f"missing vs baseline: {sorted(base_keys - new_keys)}")
    return "; ".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new", help="freshly generated bench JSON")
    ap.add_argument("--baseline", default="BENCH_kernels.json")
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    args = ap.parse_args(argv)

    with open(args.new) as f:
        new = json.load(f)
    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"check_bench: no baseline at {args.baseline}; gating only "
              f"intra-run must_beat invariants")
        failures = must_beat_failures(new)
        for line in failures:
            print(f"check_bench: REGRESSION {line}", file=sys.stderr)
        if not failures:
            print("check_bench: OK")
        return 1 if failures else 0

    print(f"check_bench: {summarize(new, baseline)}")
    failures = compare(new, baseline, args.threshold)
    for line in failures:
        print(f"check_bench: REGRESSION {line}", file=sys.stderr)
    if not failures:
        print("check_bench: OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
