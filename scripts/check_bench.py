"""Perf-regression gate: diff a fresh BENCH_kernels.json against the
committed baseline and fail on >1.3× slowdown of any kernel entry.

Used standalone (``python scripts/check_bench.py NEW.json``) and by
``benchmarks/run.py --json``, which regenerates BENCH_kernels.json and then
compares it to the previously committed content (DESIGN.md §5). Entries
present on only one side are reported but never fail the check (new shapes
or paths are allowed to appear/retire); only matched entries gate.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys

THRESHOLD = 1.3

# Shared bench hosts drift globally (noisy neighbors, turbo state): every
# entry — including the code-stable jnp ``ref`` path — can shift 1.5-2x
# between runs. The median new/baseline ratio over the ``ref/`` entries
# (whose implementation no kernel change touches) estimates that host
# factor and is divided out, so the gate fires on *relative* regressions —
# which a kernel change actually causes, even when it hits both Pallas
# paths through a shared helper. When too few ref entries match, the
# median over all gated entries is the (weaker) fallback anchor.
_MIN_REF_ENTRIES_FOR_NORMALIZATION = 3
_MIN_ENTRIES_FOR_NORMALIZATION = 6

# Sub-50ms calls on CPU-interpret hosts jitter 2-3x run to run even with
# min-of-N timing; gating them would make the check flappy. Entries below
# the floor are reported but never fail (the ≥50ms entries — the large
# shapes the perf work actually targets — carry the gate). On compiled
# accelerator baselines (meta.interpret false on both sides) timings are
# stable at sub-ms scale, so no floor applies — otherwise a fast-TPU
# baseline would silently gate nothing.
_MIN_GATED_BASELINE_US = 50_000.0


def _floor(new: dict, baseline: dict) -> float:
    interp = (new.get("meta", {}).get("interpret", True)
              or baseline.get("meta", {}).get("interpret", True))
    return _MIN_GATED_BASELINE_US if interp else 0.0


def _gated_ratios(new: dict, baseline: dict) -> dict:
    base_entries = baseline.get("entries", {})
    new_entries = new.get("entries", {})
    floor = _floor(new, baseline)
    return {name: new_entries[name]["us"] / base_entries[name]["us"]
            for name in sorted(new_entries)
            if name in base_entries and base_entries[name]["us"] >= floor
            and base_entries[name]["us"] > 0}


def compare(new: dict, baseline: dict,
            threshold: float = THRESHOLD) -> list[str]:
    """Returns a list of human-readable regression failures (empty = pass)."""
    ratios = _gated_ratios(new, baseline)

    ref_ratios = [r for name, r in ratios.items() if name.startswith("ref/")]
    if len(ref_ratios) >= _MIN_REF_ENTRIES_FOR_NORMALIZATION:
        host_factor = statistics.median(ref_ratios)
    elif len(ratios) >= _MIN_ENTRIES_FOR_NORMALIZATION:
        host_factor = statistics.median(ratios.values())
    else:
        host_factor = 1.0

    base_entries = baseline.get("entries", {})
    new_entries = new.get("entries", {})
    failures = []
    for name, ratio in ratios.items():
        if ratio > threshold * host_factor:
            failures.append(
                f"{name}: {new_entries[name]['us']:.1f}us vs baseline "
                f"{base_entries[name]['us']:.1f}us ({ratio:.2f}x > "
                f"{threshold}x with host factor {host_factor:.2f})")
    return failures


def summarize(new: dict, baseline: dict) -> str:
    base_keys = set(baseline.get("entries", {}))
    new_keys = set(new.get("entries", {}))
    gated = len(_gated_ratios(new, baseline))
    lines = [f"gating {gated} of {len(base_keys & new_keys)} matched entries"]
    if new_keys - base_keys:
        lines.append(f"new (ungated): {sorted(new_keys - base_keys)}")
    if base_keys - new_keys:
        lines.append(f"missing vs baseline: {sorted(base_keys - new_keys)}")
    return "; ".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new", help="freshly generated bench JSON")
    ap.add_argument("--baseline", default="BENCH_kernels.json")
    ap.add_argument("--threshold", type=float, default=THRESHOLD)
    args = ap.parse_args(argv)

    with open(args.new) as f:
        new = json.load(f)
    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"check_bench: no baseline at {args.baseline}; nothing to gate")
        return 0

    print(f"check_bench: {summarize(new, baseline)}")
    failures = compare(new, baseline, args.threshold)
    for line in failures:
        print(f"check_bench: REGRESSION {line}", file=sys.stderr)
    if not failures:
        print("check_bench: OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
