"""Regenerate the §Dry-run / §Roofline markdown tables from the JSON
artifacts under experiments/. Writes experiments/tables.md, which
EXPERIMENTS.md references (and inlines at authoring time)."""
import glob
import json
import os
import sys


def load(d):
    out = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        out.append(json.load(open(f)))
    return out


def fmt_ms(s):
    return f"{s*1e3:.1f}"


def roofline_table(rows):
    lines = [
        "| arch | shape | mesh | sharding | compute ms | memory ms | "
        "collective ms | bottleneck | useful FLOPs | peak GB/dev |",
        "|---|---|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for r in rows:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r.get('sharding','?')} | FAIL | | | | | |")
            continue
        t = r["roofline"]
        u = r.get("useful_flops_ratio")
        extra = []
        if r.get("attn") and r["attn"] != "naive":
            extra.append(r["attn"])
        shard = r.get("sharding", "?") + ("+" + "+".join(extra) if extra
                                          else "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {shard} | "
            f"{fmt_ms(t['compute_s'])} | {fmt_ms(t['memory_s'])} | "
            f"{fmt_ms(t['collective_s'])} | {t['bottleneck']} | "
            f"{u and round(u, 3)} | "
            f"{r['memory']['peak_gb_per_device']:.1f} |")
    return "\n".join(lines)


def dryrun_table(rows):
    lines = ["| arch | shape | mesh | compile | peak GB/dev | collectives |",
             "|---|---|---|---|---:|---:|"]
    for r in rows:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"**FAIL** | | |")
            continue
        c = r.get("collectives", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"({r['compile_s'] + r['lower_s']:.0f}s) | "
            f"{r['memory']['peak_gb_per_device']:.1f} | {c.get('count', 0)} |")
    return "\n".join(lines)


def main():
    base = load("experiments/baseline")
    mp = load("experiments/validate_mp")
    perf = load("experiments/perf") if os.path.isdir("experiments/perf") \
        else []
    out = ["# Generated tables (scripts/build_reports.py)", ""]
    out += ["## Baseline roofline (single-pod 16x16, basic_ws, remat=basic)",
            "", roofline_table(base), ""]
    out += ["## Multi-pod compile check (2x16x16)", "", dryrun_table(mp), ""]
    if perf:
        out += ["## Perf variants", "", roofline_table(perf), ""]
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/tables.md", "w") as f:
        f.write("\n".join(out))
    print("wrote experiments/tables.md",
          f"({len(base)} base, {len(mp)} mp, {len(perf)} perf)")


if __name__ == "__main__":
    main()
