"""Quickstart: train a small BASIC dual encoder with Algorithm-1 GradAccum
and use it as an open-vocabulary classifier — the whole paper in ~80 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.core.gradaccum import contrastive_step
from repro.data import (classification_prompts, contrastive_batch,
                        load_tokenizer, world_for_tower)
from repro.models import dual_encoder as de
from repro.optim import AdaFactorW, apply_updates, warmup_cosine

STEPS, BATCH, MICRO = 120, 32, 4

# 1. a small BASIC-S variant (vision frontend stubbed per DESIGN.md)
cfg = get_arch("basic-s")
cfg = dataclasses.replace(cfg,
                          image_tower=smoke_variant(cfg.image_tower),
                          text_tower=smoke_variant(cfg.text_tower),
                          embed_dim=64)

# 2. synthetic open-vocabulary image-text world + tokenizer (paper §7.1)
rng = np.random.default_rng(0)
from repro.data import world_for_tower  # noqa: E402
world = world_for_tower(rng, cfg.image_tower, n_classes=16, noise=0.25)
tok = load_tokenizer()     # the committed versioned artifact (v1)

# 3. dual encoder + AdaFactorW (paper App. B)
params = de.init_params(cfg, jax.random.key(0))
opt = AdaFactorW(weight_decay=0.0025)
opt_state = opt.init(params)
lr = warmup_cosine(2e-3, 2e-5, 10, STEPS)

enc_i = lambda p, im: de.encode_image(cfg, p, im)   # noqa: E731
enc_t = lambda p, tx: de.encode_text(cfg, p, tx)    # noqa: E731


@jax.jit
def train_step(params, opt_state, batch, step):
    # Algorithm 1: exact contrastive gradient from MICRO microbatches
    loss, metrics, grads = contrastive_step(enc_i, enc_t, params, batch, MICRO)
    updates, opt_state = opt.update(grads, opt_state, params, lr(step))
    return apply_updates(params, updates), opt_state, loss, metrics


for i in range(STEPS):
    batch, _ = contrastive_batch(world, tok, BATCH, rng)
    params, opt_state, loss, metrics = train_step(
        params, opt_state, jax.tree.map(jnp.asarray, batch), jnp.asarray(i))
    if i % 20 == 0 or i == STEPS - 1:
        print(f"step {i:4d}  loss {float(loss):.3f}  "
              f"in-batch i2t@1 {float(metrics['i2t_top1']):.2f}")

# 4. zero-shot classification with CLIP-style prompts
prompts = classification_prompts(world, tok)
temb = enc_t(params, jax.tree.map(jnp.asarray, prompts))
test, cls = contrastive_batch(world, tok, 128, rng)
iemb = enc_i(params, jax.tree.map(jnp.asarray, test["images"]))
acc = float(np.mean(np.asarray(jnp.argmax(iemb @ temb.T, 1)) == cls))
print(f"\nzero-shot top-1 over {world.n_classes} classes: "
      f"{acc:.3f} (chance {1/world.n_classes:.3f})")
