"""Open-vocabulary evaluation harness: train briefly, then evaluate zero-shot
transfer to UNSEEN classes and under distribution shift, and demonstrate
prompt sensitivity (paper §11 / App. G).

  PYTHONPATH=src python examples/zero_shot_eval.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.core.gradaccum import contrastive_step
from repro.data import (classification_prompts, contrastive_batch,
                        load_tokenizer, world_for_tower)
from repro.data.synthetic import render_images
from repro.models import dual_encoder as de
from repro.optim import AdaFactorW, apply_updates

cfg = get_arch("basic-s")
cfg = dataclasses.replace(cfg,
                          image_tower=smoke_variant(cfg.image_tower),
                          text_tower=smoke_variant(cfg.text_tower),
                          embed_dim=64)
rng = np.random.default_rng(1)
world = world_for_tower(rng, cfg.image_tower, n_classes=24, noise=0.25)
tok = load_tokenizer()     # the committed versioned artifact (v1)
seen, unseen = np.arange(16), np.arange(16, 24)

params = de.init_params(cfg, jax.random.key(1))
opt = AdaFactorW()
st = opt.init(params)
enc_i = lambda p, im: de.encode_image(cfg, p, im)   # noqa: E731
enc_t = lambda p, tx: de.encode_text(cfg, p, tx)    # noqa: E731


@jax.jit
def step(params, st, batch):
    loss, _, g = contrastive_step(enc_i, enc_t, params, batch, 4)
    up, st = opt.update(g, st, params, 2e-3)
    return apply_updates(params, up), st


print("training on the 16 SEEN classes only ...")
for i in range(100):
    batch, _ = contrastive_batch(world, tok, 32, rng, classes=seen)
    params, st = step(params, st, jax.tree.map(jnp.asarray, batch))


def evaluate(pool, template, noise_mult=1.0, n=128):
    prompts = classification_prompts(world, tok, template=template)
    temb = np.asarray(enc_t(params, jax.tree.map(jnp.asarray, prompts)))
    cls = pool[rng.integers(0, len(pool), n)]
    old = world.noise
    world.noise = old * noise_mult
    imgs = render_images(world, cls, rng)
    world.noise = old
    iemb = np.asarray(enc_i(params, {"image": jnp.asarray(imgs)}))
    return float(np.mean(np.argmax(iemb @ temb.T, 1) == cls))


T = "a photo of a {} {}"
print(f"\nseen classes                     top-1 = {evaluate(seen, T):.3f}")
print(f"UNSEEN classes (open-vocab)      top-1 = {evaluate(unseen, T):.3f}")
print(f"seen, 2x noise (robustness)      top-1 = {evaluate(seen, T, 2.0):.3f}")
print(f"chance                                  = {1/world.n_classes:.3f}")

print("\nprompt sensitivity (paper App. G):")
for t in ("a photo of a {} {}", "{} {}", "a bad photo of the {} {}"):
    print(f"  {t!r:35s} -> {evaluate(seen, t):.3f}")
